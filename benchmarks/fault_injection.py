"""Paper Table 2: accuracy drop under memory faults, per protection scheme.

{faulty, parity-zero, secded72, in-place} x fault rates {1e-6..1e-3} (+ an
amplified 3e-3 row where small-model effects are visible), multiple trials,
on WOT-trained CNNs.  Since PR 2 the grid runs through the compiled
on-device campaign engine (``repro.protection.campaign``): one encode and
one jit compile per (model, scheme), then the whole (trial x rate) sweep
executes inside a single device program — Table 2 in seconds instead of one
host round-trip per cell.  ``--batch scan`` trades the vmap grid's speed for
constant memory; ``--json`` dumps every ``CampaignResult`` for BENCH_*.json
artifacts; ``--compute`` adds the ABFT compute-fault coverage rows
(accumulator/decoded-weight corruption detected by the fused kernel's
checksums — docs/abft.md).  See ``docs/table2.md`` for the full
reproduction walkthrough.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro import protection
from repro.training.cnn_experiments import (eval_policy, run_scheme_campaign,
                                            train_cnn_wot)

RATES = (1e-6, 1e-5, 1e-4, 1e-3, 3e-3)
SCHEMES = ("faulty", "parity-zero", "secded72", "in-place")


def run(models=("resnet18",), trials=5, rates=RATES, verbose=True,
        batch="scan", json_path=None, policy=None, compute=False):
    """``policy`` (a ``protection.POLICY_PRESETS`` name) adds one extra
    campaign row under that mixed-scheme preset — the per-layer
    heterogeneous deployment the ProtectionPlan serves. ``compute`` adds
    the COMPUTE-fault rows (``protection.compute_campaign``, targets
    ``acc`` and ``wdec``): instead of accuracy drop under memory faults,
    they report the in-kernel ABFT check's detection coverage of silent
    matmul corruption — the fault class ECC cannot see (docs/abft.md)."""
    results = {}
    campaigns = {}
    rows = list(SCHEMES)
    for name in models:
        params, fwd, tmpl = train_cnn_wot(name)
        for i, scheme in enumerate(SCHEMES):
            res = run_scheme_campaign(params, fwd, tmpl, scheme, rates=rates,
                                      trials=trials, batch=batch,
                                      key=jax.random.PRNGKey(i))
            campaigns[(name, scheme)] = res
            results[(name, scheme)] = (res.space_overhead, res.row(),
                                       res.clean)
        if policy:
            pol = protection.get_policy_preset(
                policy, predicate=lambda p, l: getattr(l, "ndim", 0) >= 2)
            res = run_scheme_campaign(params, fwd, tmpl, None, policy=pol,
                                      rates=rates, trials=trials, batch=batch,
                                      key=jax.random.PRNGKey(len(SCHEMES)))
            row_id = f"policy:{policy}"
            campaigns[(name, row_id)] = res
            results[(name, row_id)] = (res.space_overhead, res.row(),
                                       res.clean)
            rows = list(SCHEMES) + [row_id]
        if compute:
            # per-element perturb rates over the probe surface — a CNN's
            # only matmul leaf is its tiny classifier head, so the memory
            # grid's rates would inject ~nothing. Not merged into
            # ``results``: these rows report detection coverage, not
            # accuracy drop.
            crates = (1e-3, 1e-2, 1e-1)
            for j, tgt in enumerate(("acc", "wdec")):
                res = protection.compute_campaign(
                    params, rates=crates, trials=trials, batch=batch,
                    key=jax.random.PRNGKey(100 + j), target=tgt,
                    probe_m=64)
                campaigns[(name, f"compute:{tgt}")] = res
        clean = campaigns[(name, SCHEMES[0])].clean
        if verbose:
            report = protection.coverage(params, eval_policy("in-place"))
            print(f"# {name}: clean int8+WOT accuracy {clean:.3f}")
            print("# " + report.summary().replace("\n", "\n# "))
            sweep = sum(c.wall_clock_s for (m, _), c in campaigns.items()
                        if m == name)
            comp = sum(c.compile_s for (m, _), c in campaigns.items()
                       if m == name)
            dev = campaigns[(name, SCHEMES[0])]
            print(f"# campaign [{dev.platform}/{dev.batch}]: "
                  f"{len(SCHEMES)} compiles {comp:.1f}s, "
                  f"full grid sweep {sweep:.2f}s")
            print(f"# {'scheme':11s} {'ovh%':5s} " +
                  " ".join(f"{r:>13.0e}" for r in rates))
            for scheme in rows:
                res = campaigns[(name, scheme)]
                cells = " ".join(f"{d * 100:6.2f}±{s * 100:4.1f}"
                                 for d, s in res.row())
                print(f"# {scheme:11s} {res.space_overhead * 100:4.1f}%  "
                      f"{cells}")
            if compute:
                for tgt in ("acc", "wdec"):
                    res = campaigns[(name, f"compute:{tgt}")]
                    cov = " ".join(f"{r:.0e}:{m * 100:6.2f}%"
                                   for r, m in zip(res.rates, res.mean()))
                    print(f"# abft-coverage target={tgt}: {cov}  "
                          f"(checksum false positives at rate 0: "
                          f"{res.clean:.0f})")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({f"{m}/{s}": c.to_dict()
                       for (m, s), c in campaigns.items()}, f, indent=2)
        if verbose:
            print(f"# wrote {json_path}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", nargs="+", default=["resnet18"])
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--batch", default="scan", choices=("vmap", "scan"),
                    help="grid layout: scan compiles ~3x faster on CPU, "
                         "vmap sweeps fastest on accelerators")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump all CampaignResults (BENCH_*.json format)")
    ap.add_argument("--policy", default=None,
                    choices=sorted(protection.POLICY_PRESETS),
                    help="extra row: campaign under a named mixed-scheme "
                         "ProtectionPlan preset")
    ap.add_argument("--compute", action="store_true",
                    help="extra rows: ABFT detection coverage of injected "
                         "COMPUTE faults (accumulator SDCs and decoded-"
                         "weight corruption), per target")
    args = ap.parse_args(argv)
    t0 = time.time()
    results = run(models=tuple(args.models), trials=args.trials,
                  batch=args.batch, json_path=args.json, policy=args.policy,
                  compute=args.compute)
    us = (time.time() - t0) * 1e6
    for (name, scheme), (ovh, row, clean) in results.items():
        drops = "/".join(f"{d * 100:.2f}" for d, _ in row)
        print(f"table2_{name}_{scheme},{us:.0f},ovh={ovh:.3f}_drops={drops}")


if __name__ == "__main__":
    main()
