"""Paper Table 2: accuracy drop under memory faults, per protection scheme.

{faulty, parity-zero, secded72, in-place} x fault rates {1e-6..1e-3} (+ an
amplified 3e-3 row where small-model effects are visible), multiple trials,
on WOT-trained CNNs. Each trial runs the ``repro.protection`` policy
pipeline (encode -> inject into the stored image -> decode); the
space-overhead column comes from the same encoded trees."""
from __future__ import annotations

import time

import numpy as np

from repro import protection
from repro.training.cnn_experiments import (eval_policy, eval_with_scheme,
                                            train_cnn_wot)

RATES = (1e-6, 1e-5, 1e-4, 1e-3, 3e-3)
SCHEMES = ("faulty", "parity-zero", "secded72", "in-place")


def run(models=("resnet18",), trials=5, rates=RATES, verbose=True):
    results = {}
    for name in models:
        params, fwd, tmpl = train_cnn_wot(name)
        clean, _ = eval_with_scheme(params, fwd, tmpl, "faulty", 0.0, 0)
        if verbose:
            report = protection.coverage(params, eval_policy("in-place"))
            print(f"# {name}: clean int8+WOT accuracy {clean:.3f}")
            print("# " + report.summary().replace("\n", "\n# "))
            print(f"# {'scheme':11s} {'ovh%':5s} " +
                  " ".join(f"{r:>13.0e}" for r in rates))
        for scheme in SCHEMES:
            row = []
            for rate in rates:
                accs = [eval_with_scheme(params, fwd, tmpl, scheme, rate,
                                         1000 * t + 1)[0]
                        for t in range(trials)]
                row.append((clean - float(np.mean(accs)),
                            float(np.std(accs))))
            _, ovh = eval_with_scheme(params, fwd, tmpl, scheme, 0.0, 0)
            results[(name, scheme)] = (ovh, row, clean)
            if verbose:
                cells = " ".join(f"{d * 100:6.2f}±{s * 100:4.1f}"
                                 for d, s in row)
                print(f"# {scheme:11s} {ovh * 100:4.1f}%  {cells}")
    return results


def main():
    t0 = time.time()
    results = run()
    us = (time.time() - t0) * 1e6
    for (name, scheme), (ovh, row, clean) in results.items():
        drops = "/".join(f"{d * 100:.2f}" for d, _ in row)
        print(f"table2_{name}_{scheme},{us:.0f},ovh={ovh:.3f}_drops={drops}")


if __name__ == "__main__":
    main()
