"""Paper §4.1 comparison: ADMM-based WOT vs QATT.

The paper rejects ADMM because it "cannot help reduce the number of large
values in the first seven positions" and the final hard clamp costs
accuracy. This benchmark reproduces that comparison on the reduced-scale
CNN setup: both start from the same pretrained model; we report the
large-value count trajectory and final (post-clamp) accuracy."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.data import synthetic
from repro.training import admm, train
from repro.training.cnn_experiments import (_norm, accuracy, large_count,
                                            pretrain, wot_finetune)


def run(name="resnet18", steps=25, verbose=True):
    params0, fwd, tmpl = pretrain(name, steps=80)
    acc0 = accuracy(params0, fwd, tmpl, quantized=True)
    n0 = large_count(params0)

    # --- QATT (the paper's adopted method) ---
    p_qatt, tmpl, _ = wot_finetune(params0, fwd, tmpl, steps=steps)
    qatt_acc = accuracy(p_qatt, fwd, tmpl, quantized=True)
    qatt_large = large_count(p_qatt)

    # --- ADMM (the paper's rejected method) ---
    def loss_fn(p, batch):
        lg = fwd(p, _norm(batch["images"]), wt=train.qat_wt).astype(jnp.float32)
        return jnp.mean(jax.nn.logsumexp(lg, -1) - jnp.take_along_axis(
            lg, batch["labels"][:, None], 1)[:, 0])

    step = admm.make_admm_step(loss_fn, lr=1e-3, gamma=1e-3)
    state = admm.admm_init(params0)
    p = params0
    curve = []
    for s in range(steps):
        b, tmpl = synthetic.image_batch(4, 64, 32, seed=0, step=2000 + s,
                                        templates=tmpl)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        p, state, _ = step(p, state, b)
        curve.append(large_count(p))
    admm_large_pre = large_count(p)
    p_admm = admm.finalize(p)  # lossy hard clamp (paper)
    admm_acc = accuracy(p_admm, fwd, tmpl, quantized=True)

    if verbose:
        print(f"# {name}: pretrain acc={acc0:.3f}, large values={n0}")
        print(f"# QATT : final acc={qatt_acc:.3f}, large-before-clamp ~0 "
              f"(post {qatt_large})")
        print(f"# ADMM : final acc={admm_acc:.3f}, large-before-clamp "
              f"{admm_large_pre} (trajectory {curve[::5]})")
    return acc0, qatt_acc, admm_acc, admm_large_pre


def main():
    t0 = time.time()
    acc0, qatt_acc, admm_acc, admm_large = run()
    print(f"admm_vs_qatt,{(time.time() - t0) * 1e6:.0f},"
          f"qatt={qatt_acc:.3f}_admm={admm_acc:.3f}"
          f"_admm_residual_large={admm_large}")


if __name__ == "__main__":
    main()
