"""Roofline analysis from the dry-run JSONL (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh):
  compute    = HLO_FLOPs / (chips * 197 TFLOP/s bf16)
  memory     = HLO_bytes / (chips * 819 GB/s)
  collective = wire_bytes / (chips * 50 GB/s/link ... per-device program, so
               per-chip wire bytes / 50 GB/s)
plus MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the useful-compute
ratio. HLO numbers come from the trip-count-aware HLO parser (per-device
program), so terms are already per-chip.
"""
from __future__ import annotations

import json
import sys

import numpy as np

from repro import configs
from repro.models.config import SHAPES

PEAK_FLOPS = 197e12   # bf16/chip
PEAK_INT8 = 394e12    # int8/chip
HBM_BW = 819e9        # B/s/chip
LINK_BW = 50e9        # B/s/link ICI


def param_counts(cfg):
    """(total_params, active_params) analytic."""
    d, v = cfg.d_model, cfg.vocab_padded
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    per_layer_total = per_layer_active = 0
    f = cfg.family
    if f in ("dense", "vlm"):
        attn = d * cfg.n_heads * cfg.head_dim + 2 * d * cfg.n_kv_heads * cfg.head_dim \
            + cfg.n_heads * cfg.head_dim * d
        mlp = 3 * d * cfg.d_ff
        per_layer_total = per_layer_active = attn + mlp
        n_layers = cfg.n_layers
    elif f == "moe":
        r, qr, qn, vd, h = (cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim,
                            cfg.v_head_dim, cfg.n_heads)
        attn = d * (r + qr) + r * h * qn + r * h * vd + h * vd * d
        attn += (d * cfg.q_lora_rank + cfg.q_lora_rank * h * (qn + qr)) \
            if cfg.q_lora_rank else d * h * (qn + qr)
        experts = cfg.n_experts * 3 * d * cfg.moe_d_ff
        active = cfg.top_k * 3 * d * cfg.moe_d_ff
        shared = cfg.n_shared_experts * 3 * d * cfg.moe_d_ff
        router = d * cfg.n_experts
        per_layer_total = attn + experts + shared + router
        per_layer_active = attn + active + shared + router
        n_layers = cfg.n_layers
    elif f == "ssm":
        di, n, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
        h = di // hd
        per_layer_total = per_layer_active = \
            d * (2 * di + 2 * n + h) + di * d
        n_layers = cfg.n_layers
    elif f == "hybrid":
        w = cfg.lru_width or d
        rg = d * w * 2 + 2 * w * w + w * d
        attn = d * cfg.n_heads * cfg.head_dim + 2 * d * cfg.n_kv_heads * cfg.head_dim \
            + cfg.n_heads * cfg.head_dim * d
        mlp = 3 * d * cfg.d_ff
        per_layer_total = per_layer_active = \
            (2 * (rg + mlp) + attn + mlp) / 3  # per-layer average
        n_layers = cfg.n_layers
    elif f == "encdec":
        attn = 4 * d * d
        per_layer_total = per_layer_active = attn * 2 + 2 * d * cfg.d_ff
        n_layers = cfg.n_layers + cfg.enc_layers
    total = emb + n_layers * per_layer_total
    active = emb + n_layers * per_layer_active
    return total, active


def model_flops(cfg, shape):
    total, active = param_counts(cfg)
    non_emb = active - cfg.vocab_padded * cfg.d_model * \
        (1 if cfg.tie_embeddings else 2)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * active * tokens
    return 2 * active * shape.global_batch  # decode: one token per seq


def analyze(rec):
    cfg = configs.get(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec.get("n_devices", 256)
    flops = rec.get("hlo_flops", 0.0)           # per-device program
    bytes_ = rec.get("hlo_buffer_bytes", 0.0)
    wire = rec.get("collectives", {}).get("total_wire_bytes", 0.0)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = wire / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / chips / flops if flops else 0.0
    bound_time = max(terms.values())
    frac = (mf / chips / PEAK_FLOPS) / bound_time if bound_time else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf, "hlo_flops_per_chip": flops,
        "useful_flops_ratio": round(useful, 4),
        "roofline_fraction": round(frac, 4),
    }


def fused_vs_decode_rows(bench_path="BENCH_kernels.json", m=128):
    """Structural roofline bound for the fused decode+matmul vs the XLA
    decode-then-matmul path, per autotune shape — the bound the measured
    BENCH_kernels ``fused_us`` / ``fused_ref_us`` / ``fused_int8_us``
    numbers compare against.

    fused (raw int8): HBM traffic = a (M*K int8) + enc (K*N uint8) +
            out (M*N*4); decode never round-trips through HBM.
    decode-then-matmul: adds a full decoded-weight write + read (2*K*N),
            the exact per-step cost the decode-at-use serve step deletes.
    float serving path: bf16 activations (2*M*K) + f32 out, bf16 MXU peak.
    int8 fused epilogue: int8 activations (M*K — HALF the float path's
            activation traffic) + bf16 out (M*N*2 — half the f32 out),
            int8 MXU peak (2x the bf16 MACs/s).
    """
    shapes = [(1024, 1024), (2048, 4096)]
    try:
        with open(bench_path) as f:
            shapes = [tuple(e["shape"]) for e in json.load(f)["entries"]]
    except (OSError, KeyError, ValueError):
        pass
    rows = []
    for k, n in shapes:
        flops = 2 * m * k * n
        fused_bytes = m * k + k * n + m * n * 4
        split_bytes = fused_bytes + 2 * k * n
        t_fused = max(flops / PEAK_INT8, fused_bytes / HBM_BW) * 1e6
        t_split = max(flops / PEAK_INT8, split_bytes / HBM_BW) * 1e6
        # serving-path structural rows: float (bf16 a, f32 out, bf16 MXU)
        # vs the int8 epilogue (int8 a, bf16 out, int8 MXU)
        float_bytes = 2 * m * k + k * n + 4 * m * n
        int8_bytes = m * k + k * n + 2 * m * n
        t_float = max(flops / PEAK_FLOPS, float_bytes / HBM_BW) * 1e6
        t_int8 = max(flops / PEAK_INT8, int8_bytes / HBM_BW) * 1e6
        r = {"shape": [k, n], "fused_roof_us": round(t_fused, 2),
             "decode_then_matmul_roof_us": round(t_split, 2),
             "traffic_ratio": round(split_bytes / fused_bytes, 3),
             "float_fused_roof_us": round(t_float, 2),
             "int8_fused_roof_us": round(t_int8, 2),
             "int8_speedup": round(t_float / t_int8, 3),
             "int8_traffic_ratio": round(float_bytes / int8_bytes, 3)}
        rows.append(r)
        print(f"roofline_fused_qmatmul_{k}x{n},{t_fused:.1f},"
              f"decode_then_matmul_us={t_split:.1f}"
              f"_traffic_ratio={r['traffic_ratio']}")
        print(f"roofline_int8_fused_{k}x{n},{t_int8:.1f},"
              f"float_us={t_float:.1f}_speedup={r['int8_speedup']}"
              f"_traffic_ratio={r['int8_traffic_ratio']}")
    return rows


def kv_traffic_rows(arch="deepseek-7b", batch=8, seqs=(4096, 32768)):
    """Structural per-decode-step KV-cache HBM traffic for the paged
    protected cache, per KV scheme, vs the dense bf16 ring buffer.

    Every decode step reads the whole cached history once (decode-at-use:
    stored int8 pages + parity checks + per-token scales) and writes one
    token per layer. The dense baseline reads bf16 K/V — 2x the int8
    bytes — so every protected scheme is *less* HBM traffic than dense
    bf16 serving, and in-place's check overhead is exactly zero (the
    zero-space claim, as bytes on the wire per step).
    """
    import jax

    from repro.serving import kvcache
    cfg = configs.get_smoke(arch)
    nl, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    rows = []
    for s in seqs:
        dense = 2 * 2 * batch * s * kv * hd * nl       # bf16 K+V read
        for scheme in kvcache.KV_SCHEMES:
            pol = kvcache.KVProtectionPolicy(scheme=scheme)
            cache = jax.eval_shape(
                lambda: kvcache.init_paged_cache(cfg, batch, s, pol))
            kb = kvcache.kv_bytes(cache)
            read = kb["stored"] + kb["checks"] + kb["scales"]
            r = {"arch": arch, "seq": s, "scheme": scheme,
                 "read_bytes_per_step": read,
                 "check_bytes": kb["checks"],
                 "dense_bf16_bytes": dense,
                 "vs_dense_ratio": round(read / dense, 4),
                 "kv_roof_us": round(read / HBM_BW * 1e6, 2)}
            rows.append(r)
            print(f"roofline_kv_{arch}_{s}_{scheme},{r['kv_roof_us']},"
                  f"read={read}_checks={kb['checks']}"
                  f"_vs_dense={r['vs_dense_ratio']}")
    return rows


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_16x16.jsonl"
    rows = []
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            if rec.get("status") != "ok":
                if rec.get("status") == "skipped":
                    print(f"roofline_{rec['arch']}_{rec['shape']},0,skipped")
                continue
            r = analyze(rec)
            rows.append(r)
            print(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
                  f"{max(r['compute_s'], r['memory_s'], r['collective_s']) * 1e6:.0f},"
                  f"dom={r['dominant']}_frac={r['roofline_fraction']}"
                  f"_useful={r['useful_flops_ratio']}")
    fused_vs_decode_rows()
    kv_traffic_rows()
    return rows


if __name__ == "__main__":
    main()
