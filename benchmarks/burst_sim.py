"""Burst-load serving benchmark: seeded request waves through the
continuous-batching front-end, with an SLO comparison against the
unprotected-KV twin.

Replays a deterministic wave workload (``repro.serving.frontend.
make_waves``) through the request-level front-end under one or more KV
protection policies and fault rates, and emits:

* ``telemetry_<policy>_r<rate>.jsonl`` — the raw event stream
* ``requests_<policy>_r<rate>.csv``   — one row per request
* ``summary.json``                    — per-cell roll-ups (throughput,
  p50/p95/p99 TTFT + per-token latency, queue depth, DUE-per-request,
  page-pool accounting) plus an ``slo`` section comparing each protected
  cell's p99 per-token latency against the unprotected twin at the same
  fault rate.

  PYTHONPATH=src python benchmarks/burst_sim.py --smoke \
      --out-dir results/burst [--kv-policies unprotected,in-place] \
      [--fault-rates 0,1e-3] [--seed 0]

``--shared-prefix-len N`` prepends one common N-token prefix to every
prompt and serves with the front-end's prefix cache on — the summary's
``sharing`` section then reports pages shared, CoW copies, and pages
allocated vs what solo (no-sharing) admissions would have cost.

``--abft`` runs a checksum-guarded twin (``plan.with_abft()`` — in-kernel
ABFT over every protected matmul, see docs/abft.md) of every no-scrub
cell and prices it in the summary's ``abft_slo`` section: p99 per-token
ratio vs the unguarded twin, mismatch/clamp totals (zero here — the
burst injects MEMORY faults, which ECC absorbs before the MXU sees
them), and a token cross-check. The guarded cells' ``abft_mismatches`` /
``clamp_hits`` step fields carry no wall suffix, so they sit inside the
deterministic view and ABFT-enabled cells replay bit for bit.

``--smoke`` is the CI micro-run: 2 waves x 3 requests on the
deepseek-7b smoke config — small enough to compile and drain on a CPU
runner, large enough to exercise admission, queueing, eviction, and page
reuse. Determinism contract: for a fixed ``--seed`` the deterministic
view of every telemetry stream (wall-clock fields stripped) and every
token stream is bit-identical run-to-run; CI asserts the SLO envelope on
top (see .github/workflows/ci.yml).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs, protection  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serving import frontend, kvcache, protected  # noqa: E402
from repro.serving import telemetry  # noqa: E402


def _cell_tag(policy: str, rate: float, scrub_every: int = 0,
              abft: bool = False) -> str:
    tag = f"{policy}_r{rate:g}"
    if scrub_every:
        tag = f"{tag}_scrub{scrub_every}"
    return f"{tag}_abft" if abft else tag


def run_grid(cfg, enc, plan, waves, *, kv_policies, fault_rates,
             slots, max_len, n_pages, seed, out_dir=None,
             prefix_sharing=False, scrub_every=0, repair=False,
             weight_fault_rate=0.0, abft_plan=None):
    """(policy x rate) grid over one workload; shares one jitted serve
    step per policy across its rate axis (and across twin comparisons) so
    wall-clock cells differ by faults, not compile noise.

    ``scrub_every > 0`` runs every (policy, rate) cell TWICE — a no-scrub
    baseline and a self-healing twin with the budgeted scrubber on (tag
    suffix ``_scrubN``) ending in a full at-rest pass — so the
    ``scrub_slo`` section can price healing against its own baseline.

    ``abft_plan`` (the plan with ``with_abft()`` applied) additionally
    runs an ABFT-guarded twin of every no-scrub cell (tag suffix
    ``_abft``, its own jitted step) so ``abft_slo`` can price the
    checksum-guarded matmuls against the unguarded twin — same workload,
    same faults, value paths identical by construction."""
    import dataclasses
    cells = {}
    for pol_name in kv_policies:
        kvp = kvcache.get_kv_policy(pol_name)
        # per-request attribution on every path (fused/chunked kernels
        # reduce flags per batch row in-grid since bench_kernels/v5)
        kvp = dataclasses.replace(kvp, per_slot_flags=True)
        step = jax.jit(protected.make_serve_step(
            cfg, plan=plan, with_flags=True, kv_policy=kvp))
        step_abft = (jax.jit(protected.make_serve_step(
            cfg, plan=abft_plan, with_flags=True, kv_policy=kvp))
            if abft_plan is not None else None)
        for rate in fault_rates:
            variants = [(s, False)
                        for s in ([0, scrub_every] if scrub_every else [0])]
            if abft_plan is not None:
                variants.append((0, True))
            for scrub, abft_on in variants:
                tag = _cell_tag(pol_name, rate, scrub, abft_on)
                tpath = (os.path.join(out_dir, f"telemetry_{tag}.jsonl")
                         if out_dir else None)
                kw = dict(plan=abft_plan if abft_on else plan,
                          waves=waves, slots=slots,
                          max_len=max_len, n_pages=n_pages, kv_policy=kvp,
                          fault_rate=rate, fault_seed=seed,
                          serve_step=step_abft if abft_on else step,
                          prefix_sharing=prefix_sharing,
                          scrub_every=scrub, repair=repair and scrub > 0,
                          # weight faults ride the cell's fault-rate axis:
                          # the rate-0 scrub twin stays fault-free so its
                          # SLO row prices PURE scrub overhead (the ratio
                          # CI gates), while faulted cells demonstrate
                          # healing (final at-rest DUE pinned to zero)
                          weight_fault_rate=(weight_fault_rate
                                             if scrub and rate > 0
                                             else 0.0))
                # run every cell three times: the first eats serve-step
                # and injection compiles (keeping them out of the latency
                # percentiles); the two measured runs double as the
                # bit-determinism check, and each wall-clock percentile
                # takes the min of the pair — a scheduler hiccup in one
                # run cannot flip the SLO gate.
                warm_ev, _, warm_res = frontend.run_burst(cfg, enc, **kw)
                ev_a, summ_a, res_a = frontend.run_burst(cfg, enc, **kw)
                events, summ, results = frontend.run_burst(
                    cfg, enc, telemetry_path=tpath, **kw)
                det_views = [telemetry.deterministic_view(e)
                             for e in (warm_ev, ev_a, events)]
                deterministic = (det_views[0] == det_views[1]
                                 == det_views[2]
                                 and warm_res == res_a == results)
                for sect in ("per_token_ms", "ttft_s"):
                    summ[sect] = {k: (min(v, summ_a[sect][k])
                                      if v is not None
                                      and summ_a[sect][k] is not None
                                      else v)
                                  for k, v in summ[sect].items()}
                summ["cell"] = {"kv_policy": pol_name, "fault_rate": rate,
                                "seed": seed, "slots": slots,
                                "max_len": max_len,
                                "prefix_sharing": prefix_sharing,
                                "scrub_every": scrub,
                                "repair": repair and scrub > 0,
                                "abft": abft_on,
                                "weight_fault_rate": kw[
                                    "weight_fault_rate"],
                                "bit_deterministic": deterministic}
                if out_dir:
                    telemetry.write_requests_csv(
                        events,
                        os.path.join(out_dir, f"requests_{tag}.csv"))
                cells[tag] = {"summary": summ, "results": results}
                p99 = summ["per_token_ms"]["p99"]
                p99s = f"{p99:.2f}ms" if p99 is not None else "n/a"
                heal = summ["healing"]
                print(f"[burst] {tag}: {summ['requests']['finished']}/"
                      f"{summ['requests']['submitted']} finished in "
                      f"{summ['steps']} steps, "
                      f"{summ['throughput']['tokens_per_step']:.2f} "
                      f"tok/step, p99 per-token {p99s}, "
                      f"DUE total {summ['due']['total']}, "
                      f"leaked pages {summ['pool']['leaked_pages']}"
                      + (f", shared pages "
                         f"{summ['sharing']['pages_shared']}, "
                         f"cow {summ['sharing']['cow_copies']}, "
                         f"alloc {summ['sharing']['pages_allocated_total']}"
                         f"/{summ['sharing']['solo_pages_total']} solo"
                         if prefix_sharing else "")
                      + (f", scrub corrected w={heal['w_corrected']} "
                         f"kv={heal['kv_corrected']}, final DUE "
                         f"{heal['final_due']['w']}w/"
                         f"{heal['final_due']['kv']}kv"
                         if scrub and heal["final_due"] else ""))
    return cells


def slo_section(cells, kv_policies, fault_rates):
    """Per (protected policy, rate): p99 per-token latency ratio vs the
    unprotected twin at the same rate — the envelope CI asserts."""
    slo = []
    if "unprotected" not in kv_policies:
        return slo
    for pol in kv_policies:
        if pol == "unprotected":
            continue
        for rate in fault_rates:
            base = cells[_cell_tag("unprotected", rate)]["summary"]
            prot = cells[_cell_tag(pol, rate)]["summary"]
            b99 = base["per_token_ms"]["p99"]
            p99 = prot["per_token_ms"]["p99"]
            slo.append({
                "kv_policy": pol, "fault_rate": rate,
                "p99_per_token_ms": p99,
                "unprotected_p99_per_token_ms": b99,
                "p99_ratio": (p99 / b99) if (p99 and b99) else None,
                "due_total": prot["due"]["total"],
                "leaked_pages": prot["pool"]["leaked_pages"],
                "tokens_match_unprotected":
                    cells[_cell_tag(pol, rate)]["results"] ==
                    cells[_cell_tag("unprotected", rate)]["results"]
                    if rate == 0 else None,
            })
    return slo


def scrub_slo_section(cells, kv_policies, fault_rates, scrub_every):
    """Per (policy, rate): the self-healing twin priced against ITS OWN
    no-scrub baseline — p99 per-token ratio, scrub totals, and the
    residual at-rest DUE state CI pins to zero."""
    rows = []
    if not scrub_every:
        return rows
    for pol in kv_policies:
        for rate in fault_rates:
            base = cells[_cell_tag(pol, rate)]["summary"]
            scrub = cells[_cell_tag(pol, rate, scrub_every)]["summary"]
            b99 = base["per_token_ms"]["p99"]
            s99 = scrub["per_token_ms"]["p99"]
            heal = scrub["healing"]
            rows.append({
                "kv_policy": pol, "fault_rate": rate,
                "scrub_every": scrub_every,
                "p99_per_token_ms": s99,
                "noscrub_p99_per_token_ms": b99,
                "p99_ratio": (s99 / b99) if (s99 and b99) else None,
                "scrub_passes": heal["scrub_passes"],
                "w_corrected": heal["w_corrected"],
                "kv_corrected": heal["kv_corrected"],
                "final_due": heal["final_due"],
                "leaked_pages": scrub["pool"]["leaked_pages"],
                "tokens_match_noscrub":
                    cells[_cell_tag(pol, rate, scrub_every)]["results"]
                    == cells[_cell_tag(pol, rate)]["results"],
            })
    return rows


def abft_slo_section(cells, kv_policies, fault_rates):
    """Per (policy, rate): the ABFT-guarded twin priced against ITS OWN
    unguarded baseline — p99 per-token ratio, the checksum/clamp totals
    (both must be zero here: the burst injects MEMORY faults, which ECC
    absorbs before the MXU ever sees them), and the token cross-check
    (guarded and unguarded value paths are identical by construction)."""
    rows = []
    for pol in kv_policies:
        for rate in fault_rates:
            twin = cells.get(_cell_tag(pol, rate, abft=True))
            if twin is None:
                continue
            base = cells[_cell_tag(pol, rate)]["summary"]
            summ = twin["summary"]
            b99 = base["per_token_ms"]["p99"]
            a99 = summ["per_token_ms"]["p99"]
            rows.append({
                "kv_policy": pol, "fault_rate": rate,
                "p99_per_token_ms": a99,
                "noabft_p99_per_token_ms": b99,
                "p99_ratio": (a99 / b99) if (a99 and b99) else None,
                "abft_mismatches": summ["abft"]["mismatches_total"],
                "clamp_hits": summ["abft"]["clamp_hits_total"],
                "leaked_pages": summ["pool"]["leaked_pages"],
                "bit_deterministic": summ["cell"]["bit_deterministic"],
                "tokens_match_noabft":
                    twin["results"] == cells[_cell_tag(pol, rate)]["results"],
            })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="CI micro-run: 2 waves x 3 requests, tiny dims")
    ap.add_argument("--waves", type=int, default=4)
    ap.add_argument("--wave-size", type=int, default=6)
    ap.add_argument("--gap-steps", type=int, default=8)
    ap.add_argument("--prompt-len", default="4,12",
                    help="lo,hi prompt-length range (the per-request "
                         "suffix when --shared-prefix-len is set)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="prepend ONE common prefix of this many tokens "
                         "to every prompt and serve with the front-end's "
                         "prefix cache (page sharing + copy-on-write)")
    ap.add_argument("--max-new", default="4,8",
                    help="lo,hi generation-length range")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--pages", type=int, default=None,
                    help="pool size incl. per-slot parking pages "
                         "(default: full occupancy)")
    ap.add_argument("--kv-policies", default="unprotected,in-place")
    ap.add_argument("--fault-rates", default="0",
                    help="comma list of per-bit KV fault rates")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="all-in-place",
                    choices=sorted(protection.POLICY_PRESETS),
                    help="weight-protection preset")
    ap.add_argument("--scrub-every", type=int, default=0,
                    help="run a self-healing twin of every cell with a "
                         "budgeted scrub pass every N steps (plus a full "
                         "at-rest pass after drain)")
    ap.add_argument("--repair", action="store_true",
                    help="attach a MILR repair kit to the scrub twins "
                         "(weight-DUE reconstruction + quarantine)")
    ap.add_argument("--weight-fault-rate", type=float, default=0.0,
                    help="per-bit weight fault rate injected into the "
                         "scrub twins on the KV injection cadence")
    ap.add_argument("--abft", action="store_true",
                    help="run an ABFT-guarded twin of every no-scrub cell "
                         "(plan.with_abft(): in-kernel checksum-guarded "
                         "matmuls) and price it in the abft_slo section")
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args(argv)

    if args.smoke:
        # one page per slot (prompt+gen <= 10 < page_size 16): keeps the
        # KV-decode fraction of step time small enough that the protected
        # twin's p99 per-token SLO ratio has real margin under 1.10 on a
        # noisy CPU runner
        args.waves, args.wave_size, args.gap_steps = 2, 3, 4
        args.slots, args.max_len = 2, 16
        args.prompt_len, args.max_new = "3,6", "2,4"
    if args.out_dir:
        os.makedirs(args.out_dir, exist_ok=True)

    cfg = configs.get_smoke(args.arch)
    kv_policies = args.kv_policies.split(",")
    fault_rates = [float(r) for r in args.fault_rates.split(",")]
    p_lo, p_hi = (int(x) for x in args.prompt_len.split(","))
    n_lo, n_hi = (int(x) for x in args.max_new.split(","))

    print(f"[burst] {cfg.name} smoke config, {args.waves} waves x "
          f"{args.wave_size} reqs, slots={args.slots}, "
          f"kv={kv_policies}, rates={fault_rates}, seed={args.seed}")
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    policy = protection.get_policy_preset(args.policy)
    plan = policy.plan(params)
    enc = plan.encode_tree(params)

    sharing = args.shared_prefix_len > 0
    waves = frontend.make_waves(
        seed=args.seed, n_waves=args.waves, wave_size=args.wave_size,
        vocab=cfg.vocab, prompt_len=(p_lo, p_hi), max_new=(n_lo, n_hi),
        gap_steps=args.gap_steps,
        shared_prefix_len=args.shared_prefix_len)
    cells = run_grid(cfg, enc, plan, waves, kv_policies=kv_policies,
                     fault_rates=fault_rates, slots=args.slots,
                     max_len=args.max_len, n_pages=args.pages,
                     seed=args.seed, out_dir=args.out_dir,
                     prefix_sharing=sharing, scrub_every=args.scrub_every,
                     repair=args.repair,
                     weight_fault_rate=args.weight_fault_rate,
                     abft_plan=plan.with_abft() if args.abft else None)
    out = {
        "schema": telemetry.SUMMARY_SCHEMA,
        "arch": cfg.name,
        "workload": {"seed": args.seed, "waves": args.waves,
                     "wave_size": args.wave_size,
                     "gap_steps": args.gap_steps,
                     "prompt_len": [p_lo, p_hi], "max_new": [n_lo, n_hi],
                     "shared_prefix_len": args.shared_prefix_len,
                     "prefix_sharing": sharing,
                     "scrub_every": args.scrub_every,
                     "repair": args.repair,
                     "weight_fault_rate": args.weight_fault_rate,
                     "abft": args.abft},
        "cells": {tag: c["summary"] for tag, c in cells.items()},
        "slo": slo_section(cells, kv_policies, fault_rates),
        "scrub_slo": scrub_slo_section(cells, kv_policies, fault_rates,
                                       args.scrub_every),
        "abft_slo": abft_slo_section(cells, kv_policies, fault_rates),
    }
    for row in out["slo"]:
        ratio = row["p99_ratio"]
        print(f"[burst] SLO {row['kv_policy']} @rate {row['fault_rate']}: "
              f"p99 ratio {ratio:.3f}x vs unprotected"
              if ratio is not None else
              f"[burst] SLO {row['kv_policy']}: no latency samples")
    for row in out["scrub_slo"]:
        ratio = row["p99_ratio"]
        fd = row["final_due"]
        print(f"[burst] scrub SLO {row['kv_policy']} @rate "
              f"{row['fault_rate']}: "
              + (f"p99 ratio {ratio:.3f}x vs no-scrub" if ratio is not None
                 else "no latency samples")
              + (f", final DUE {fd['w']}w/{fd['kv']}kv" if fd else ""))
    for row in out["abft_slo"]:
        ratio = row["p99_ratio"]
        print(f"[burst] ABFT SLO {row['kv_policy']} @rate "
              f"{row['fault_rate']}: "
              + (f"p99 ratio {ratio:.3f}x vs unguarded" if ratio is not None
                 else "no latency samples")
              + f", mismatches {row['abft_mismatches']}, tokens match "
              + str(row["tokens_match_noabft"]))
    if args.out_dir:
        path = os.path.join(args.out_dir, "summary.json")
        telemetry.write_summary(out, path)
        print(f"[burst] wrote {path}")
    return out


if __name__ == "__main__":
    main()
