"""Kernel micro-benchmarks (CPU timings are for the pure-jnp reference path;
Pallas kernels run in interpret mode here — TPU perf comes from the roofline
analysis, not wall-clock on this host).

Reports, per kernel: reference-path us/call and the STRUCTURAL cost of the
kernel on TPU v5e (bytes moved, flops, roofline-bound time).

``--json BENCH_kernels.json`` additionally times the in-place decode on BOTH
backends per weight shape and writes the ``bench_kernels/v1`` artifact that
``protection.AutotuneTable`` consumes — the per-leaf backend choice is then
reproducible from a checked-in file instead of a policy-wide default.  On a
CPU host the Pallas timings are interpret-mode (always slower — recorded,
with ``pallas_interpret: true``, so a TPU re-run can overwrite them).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import protection
from repro.core import ecc
from repro.kernels import ref

PEAK_BW = 819e9        # v5e HBM B/s
PEAK_FLOPS = 197e12    # v5e bf16 FLOP/s
PEAK_INT8 = 394e12


def _time(f, *args, reps=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6


def bench_decode(n_weights=2 ** 22):
    rng = np.random.default_rng(0)
    w = rng.integers(-64, 64, size=(n_weights // 8, 8)).astype(np.int8)
    enc = ecc.encode64(jnp.asarray(w.view(np.uint8)))
    f = jax.jit(ref.ecc_decode_ref)
    us = _time(f, enc)
    # structural: reads n bytes, writes n bytes + n/8 flags
    bytes_moved = 2 * n_weights + n_weights // 8
    roof_us = bytes_moved / PEAK_BW * 1e6
    return us, bytes_moved, roof_us


def bench_qmatmul(m=512, k=1024, n=1024):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
    w = rng.integers(-64, 64, size=(k, n)).astype(np.int8)
    enc = jnp.asarray(np.asarray(ecc.encode64(jnp.asarray(
        w.view(np.uint8).reshape(k, n // 8, 8)))).reshape(k, n))
    f = jax.jit(ref.ecc_qmatmul_ref)
    us = _time(f, a, enc)
    flops = 2 * m * k * n
    bytes_moved = m * k + k * n + m * n * 4
    roof_us = max(flops / PEAK_INT8, bytes_moved / PEAK_BW) * 1e6
    return us, flops, roof_us


def bench_throttle(n=2 ** 22):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.integers(-128, 128, size=(n // 8, 8)).astype(np.int8))
    f = jax.jit(ref.throttle_ref)
    us = _time(f, q)
    roof_us = 2 * n / PEAK_BW * 1e6
    return us, 2 * n, roof_us


# Weight shapes the autotune table covers: decode-serving projections from
# small attention heads up to MLP blocks. Keep the list short — Pallas
# interpret mode on CPU makes each cell cost real seconds.
AUTOTUNE_SHAPES = ((256, 256), (256, 1024), (1024, 1024), (2048, 4096))


def bench_backend_decode(shapes=AUTOTUNE_SHAPES, reps=3):
    """Per-shape in-place decode timings on both backends -> autotune
    entries (the ``bench_kernels/v1`` schema)."""
    rng = np.random.default_rng(7)
    entries = []
    for k, n in shapes:
        w = rng.integers(-64, 64, size=(k, n)).astype(np.int8)
        enc = jnp.asarray(np.asarray(ecc.encode64(jnp.asarray(
            w.view(np.uint8).reshape(k, n // 8, 8)))).reshape(k, n))
        us = {}
        for name in ("xla", "pallas"):
            be = protection.get_backend(name)
            f = jax.jit(lambda e, be=be: be.decode64(
                e.reshape(k, n // 8, 8))[0])
            us[name] = _time(f, enc, reps=reps)
        entries.append({"shape": [k, n], "nblocks": k * n // 8,
                        "xla_us": round(us["xla"], 1),
                        "pallas_us": round(us["pallas"], 1),
                        "best": min(us, key=us.get)})
    return entries


def write_bench_kernels(path, entries=None) -> dict:
    """Write BENCH_kernels.json in the schema ``protection.AutotuneTable``
    loads (validated by round-tripping through it before writing)."""
    platform = jax.devices()[0].platform
    payload = {"schema": protection.BENCH_KERNELS_SCHEMA,
               "platform": platform,
               "pallas_interpret": platform != "tpu",
               "op": "in-place-decode64",
               "entries": entries if entries is not None
               else bench_backend_decode()}
    protection.AutotuneTable.from_dict(payload)  # schema self-check
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the per-shape xla-vs-pallas decode "
                         "table (BENCH_kernels.json, bench_kernels/v1)")
    args = ap.parse_args(argv)
    us, b, r = bench_decode()
    print(f"kernel_ecc_decode,{us:.0f},tpu_roofline_us={r:.1f}_bytes={b}")
    us, fl, r = bench_qmatmul()
    print(f"kernel_ecc_qmatmul,{us:.0f},tpu_roofline_us={r:.1f}_flops={fl}")
    us, b, r = bench_throttle()
    print(f"kernel_throttle,{us:.0f},tpu_roofline_us={r:.1f}_bytes={b}")
    if args.json:
        payload = write_bench_kernels(args.json)
        for e in payload["entries"]:
            print(f"autotune_decode_{e['shape'][0]}x{e['shape'][1]},"
                  f"xla={e['xla_us']:.0f}us,pallas={e['pallas_us']:.0f}us,"
                  f"best={e['best']}")
        print(f"# wrote {args.json} ({payload['platform']}, "
              f"pallas_interpret={payload['pallas_interpret']})")


if __name__ == "__main__":
    main()
