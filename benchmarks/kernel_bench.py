"""Kernel micro-benchmarks (CPU timings are for the pure-jnp reference path;
Pallas kernels run in interpret mode here — TPU perf comes from the roofline
analysis, not wall-clock on this host).

Reports, per kernel: reference-path us/call and the STRUCTURAL cost of the
kernel on TPU v5e (bytes moved, flops, roofline-bound time).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ecc
from repro.kernels import ref

PEAK_BW = 819e9        # v5e HBM B/s
PEAK_FLOPS = 197e12    # v5e bf16 FLOP/s
PEAK_INT8 = 394e12


def _time(f, *args, reps=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6


def bench_decode(n_weights=2 ** 22):
    rng = np.random.default_rng(0)
    w = rng.integers(-64, 64, size=(n_weights // 8, 8)).astype(np.int8)
    enc = ecc.encode64(jnp.asarray(w.view(np.uint8)))
    f = jax.jit(ref.ecc_decode_ref)
    us = _time(f, enc)
    # structural: reads n bytes, writes n bytes + n/8 flags
    bytes_moved = 2 * n_weights + n_weights // 8
    roof_us = bytes_moved / PEAK_BW * 1e6
    return us, bytes_moved, roof_us


def bench_qmatmul(m=512, k=1024, n=1024):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
    w = rng.integers(-64, 64, size=(k, n)).astype(np.int8)
    enc = jnp.asarray(np.asarray(ecc.encode64(jnp.asarray(
        w.view(np.uint8).reshape(k, n // 8, 8)))).reshape(k, n))
    f = jax.jit(ref.ecc_qmatmul_ref)
    us = _time(f, a, enc)
    flops = 2 * m * k * n
    bytes_moved = m * k + k * n + m * n * 4
    roof_us = max(flops / PEAK_INT8, bytes_moved / PEAK_BW) * 1e6
    return us, flops, roof_us


def bench_throttle(n=2 ** 22):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.integers(-128, 128, size=(n // 8, 8)).astype(np.int8))
    f = jax.jit(ref.throttle_ref)
    us = _time(f, q)
    roof_us = 2 * n / PEAK_BW * 1e6
    return us, 2 * n, roof_us


def main():
    us, b, r = bench_decode()
    print(f"kernel_ecc_decode,{us:.0f},tpu_roofline_us={r:.1f}_bytes={b}")
    us, fl, r = bench_qmatmul()
    print(f"kernel_ecc_qmatmul,{us:.0f},tpu_roofline_us={r:.1f}_flops={fl}")
    us, b, r = bench_throttle()
    print(f"kernel_throttle,{us:.0f},tpu_roofline_us={r:.1f}_bytes={b}")


if __name__ == "__main__":
    main()
