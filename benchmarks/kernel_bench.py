"""Kernel micro-benchmarks (CPU timings are for the pure-jnp reference path;
Pallas kernels run in interpret mode here — TPU perf comes from the roofline
analysis, not wall-clock on this host).

Reports, per kernel: reference-path us/call and the STRUCTURAL cost of the
kernel on TPU v5e (bytes moved, flops, roofline-bound time).

``--json BENCH_kernels.json`` additionally times the in-place decode on BOTH
backends per weight shape, sweeps fused decode+matmul tiles for the float
path AND the int8 requantize-epilogue path, times fused page-attention
(decode-at-use over the protected KV cache) against its decode-then-attend
reference per KV scheme, times the page-chunked online-softmax kernel
against the whole-strip kernel at long contexts (with the strip kernel's
VMEM crossover and the chunked-vs-fp64-oracle error), re-times each path's
winning tiles with in-kernel ABFT checksums on (the overhead rows), and
writes the ``bench_kernels/v6`` artifact that
``protection.AutotuneTable`` consumes — per-leaf backend AND tile choices
(float ``tiles`` + ``int8_tiles``) are then reproducible from a checked-in
file instead of call-site defaults (``--tiles-smoke`` shrinks the sweep for
CI).  On a CPU host the Pallas timings are interpret-mode (always slower —
recorded, with ``pallas_interpret: true``, so a TPU re-run can overwrite
them).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import protection
from repro.core import ecc
from repro.kernels import ref

PEAK_BW = 819e9        # v5e HBM B/s
PEAK_FLOPS = 197e12    # v5e bf16 FLOP/s
PEAK_INT8 = 394e12


def _time(f, *args, reps=5):
    jax.block_until_ready(f(*args))  # ONE warmup call (compile + execute)
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6


def bench_decode(n_weights=2 ** 22):
    rng = np.random.default_rng(0)
    w = rng.integers(-64, 64, size=(n_weights // 8, 8)).astype(np.int8)
    enc = ecc.encode64(jnp.asarray(w.view(np.uint8)))
    f = jax.jit(ref.ecc_decode_ref)
    us = _time(f, enc)
    # structural: reads n bytes, writes n bytes + n/8 flags
    bytes_moved = 2 * n_weights + n_weights // 8
    roof_us = bytes_moved / PEAK_BW * 1e6
    return us, bytes_moved, roof_us


def bench_qmatmul(m=512, k=1024, n=1024):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
    w = rng.integers(-64, 64, size=(k, n)).astype(np.int8)
    enc = jnp.asarray(np.asarray(ecc.encode64(jnp.asarray(
        w.view(np.uint8).reshape(k, n // 8, 8)))).reshape(k, n))
    f = jax.jit(ref.ecc_qmatmul_ref)
    us = _time(f, a, enc)
    flops = 2 * m * k * n
    bytes_moved = m * k + k * n + m * n * 4
    roof_us = max(flops / PEAK_INT8, bytes_moved / PEAK_BW) * 1e6
    return us, flops, roof_us


def bench_throttle(n=2 ** 22):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.integers(-128, 128, size=(n // 8, 8)).astype(np.int8))
    f = jax.jit(ref.throttle_ref)
    us = _time(f, q)
    roof_us = 2 * n / PEAK_BW * 1e6
    return us, 2 * n, roof_us


# Weight shapes the autotune table covers: decode-serving projections from
# small attention heads up to MLP blocks. Keep the list short — Pallas
# interpret mode on CPU makes each cell cost real seconds.
AUTOTUNE_SHAPES = ((256, 256), (256, 1024), (1024, 1024), (2048, 4096))

# (bm, bn, bk) candidates for the fused decode+matmul sweep. bk=0 means
# full-K tiles (one dot per output tile — the serving default). The smoke
# grid keeps CI wall-clock tolerable in interpret mode.
TILE_SWEEP = ((128, 128, 0), (128, 128, 128), (128, 256, 128),
              (256, 128, 128), (64, 128, 256), (128, 512, 0))
TILE_SWEEP_SMOKE = ((128, 128, 0), (128, 128, 128))


def _enc_weight(rng, k, n):
    w = rng.integers(-64, 64, size=(k, n)).astype(np.int8)
    return jnp.asarray(np.asarray(ecc.encode64(jnp.asarray(
        w.view(np.uint8).reshape(k, n // 8, 8)))).reshape(k, n))


def bench_backend_decode(shapes=AUTOTUNE_SHAPES, reps=3):
    """Per-shape in-place decode timings on both backends -> autotune
    entries (without tile data; :func:`bench_fused_tiles` adds it)."""
    rng = np.random.default_rng(7)
    entries = []
    for k, n in shapes:
        enc = _enc_weight(rng, k, n)
        us = {}
        for name in ("xla", "pallas"):
            be = protection.get_backend(name)
            f = jax.jit(lambda e, be=be: be.decode64(
                e.reshape(k, n // 8, 8))[0])
            us[name] = _time(f, enc, reps=reps)
        entries.append({"shape": [k, n], "nblocks": k * n // 8,
                        "xla_us": round(us["xla"], 1),
                        "pallas_us": round(us["pallas"], 1),
                        "best": min(us, key=us.get)})
    return entries


def bench_fused_tiles(entries, m=128, tile_sweep=TILE_SWEEP, reps=3):
    """Sweep fused decode+matmul tiles per shape and record the winner into
    each entry (``tiles`` + ``fused_us``), plus the int8 requantize-epilogue
    sweep (``int8_tiles`` + ``fused_int8_us`` — the ``bench_kernels/v3``
    fields; the epilogue always runs full-K tiles, so only (bm, bn) sweep).
    Also times the XLA references: decode-then-matmul as ``fused_ref_us``
    and decode-then-matmul-then-requantize as ``int8_ref_us``; and the
    ABFT-on twins at each path's winning tiles (``fused_abft_us`` /
    ``fused_int8_abft_us`` — the ``bench_kernels/v6`` fields) so the
    in-kernel checksum overhead is priced next to the unguarded row."""
    from repro.kernels import ref
    from repro.kernels.ecc_qmatmul import ecc_qmatmul
    rng = np.random.default_rng(11)
    for e in entries:
        k, n = e["shape"]
        enc = _enc_weight(rng, k, n)
        a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
        a_scale = jnp.asarray(rng.uniform(0.005, 0.02, size=(m, 1))
                              .astype(np.float32))
        w_scale = jnp.float32(0.01)
        best_us, best_tiles = None, None
        for bm, bn, bk in tile_sweep:
            f = jax.jit(lambda a_, e_, t=(bm, bn, bk): ecc_qmatmul(
                a_, e_, bm=t[0], bn=t[1], bk=t[2]))
            us = _time(f, a, enc, reps=reps)
            if best_us is None or us < best_us:
                best_us, best_tiles = us, (bm, bn, bk)
        e["tiles"] = list(best_tiles)
        e["fused_us"] = round(best_us, 1)
        e["fused_ref_us"] = round(
            _time(jax.jit(ref.ecc_qmatmul_ref), a, enc, reps=reps), 1)
        # ABFT twin at the winning tiles: same call, checksum rows/cols
        # verified in-kernel. Return the full (out, (rows, col_mm)) tuple
        # so XLA can't dead-code the checksum outputs away.
        f_ab = jax.jit(lambda a_, e_, t=best_tiles: ecc_qmatmul(
            a_, e_, bm=t[0], bn=t[1], bk=t[2], with_abft=True))
        e["fused_abft_us"] = round(_time(f_ab, a, enc, reps=reps), 1)
        # int8 requantize epilogue: int32 acc * (a_scale*w_scale) -> bf16
        best_us, best_tiles = None, None
        for bm, bn in sorted({(t[0], t[1]) for t in tile_sweep}):
            f = jax.jit(lambda a_, e_, s_, t=(bm, bn): ecc_qmatmul(
                a_, e_, w_scale, a_scale=s_, bm=t[0], bn=t[1]))
            us = _time(f, a, enc, a_scale, reps=reps)
            if best_us is None or us < best_us:
                best_us, best_tiles = us, (bm, bn, 0)
        e["int8_tiles"] = list(best_tiles)
        e["fused_int8_us"] = round(best_us, 1)
        f_ab = jax.jit(lambda a_, e_, s_, t=best_tiles: ecc_qmatmul(
            a_, e_, w_scale, a_scale=s_, bm=t[0], bn=t[1], with_abft=True))
        e["fused_int8_abft_us"] = round(
            _time(f_ab, a, enc, a_scale, reps=reps), 1)
        ref_int8 = jax.jit(lambda a_, e_, s_: (
            ref.ecc_qmatmul_ref(a_, e_).astype(jnp.float32) *
            (s_ * w_scale)).astype(jnp.bfloat16))
        e["int8_ref_us"] = round(_time(ref_int8, a, enc, a_scale, reps=reps),
                                 1)
    return entries


# (batch, seq, kv_heads, head_dim) decode-attention shapes for the paged
# protected KV cache rows. Queries use 2x the kv heads (GQA rep=2).
ATTENTION_SHAPES = ((2, 128, 2, 32), (2, 256, 4, 64))


def bench_paged_attention(shapes=ATTENTION_SHAPES, reps=3):
    """Fused page-attention (decode-at-use over the protected KV cache) vs
    the XLA decode-then-attend reference, per shape and KV scheme — the
    ``bench_kernels/v4`` ``attention`` rows. Each row also records whether
    the two paths agreed bit-for-bit on this host (the kernel's contract)."""
    from repro.kernels import paged_attention
    from repro.serving import kvcache
    rng = np.random.default_rng(13)
    rows = []
    for b, s, kv, hd in shapes:
        h = 2 * kv
        q = jnp.asarray(rng.standard_normal((b, h, 1, hd)),
                        dtype=jnp.bfloat16)
        kf = jnp.asarray(rng.standard_normal((b, s, kv, hd)),
                         dtype=jnp.float32)
        vf = jnp.asarray(rng.standard_normal((b, s, kv, hd)),
                         dtype=jnp.float32)
        pos = jnp.full((b,), s - 1, jnp.int32)
        for scheme in kvcache.KV_SCHEMES:
            pol = kvcache.KVProtectionPolicy(scheme=scheme)
            ke, kch, ksc = kvcache._encode_kv(kf, pol)
            ve, vch, vsc = kvcache._encode_kv(vf, pol)

            def fused(q_, scheme=scheme, strips=(ke, kch, ksc, ve, vch, vsc)):
                return paged_attention.fused_page_attention(
                    q_, *strips, pos, scheme=scheme)[0]

            def ref(q_, pol=pol, strips=(ke, kch, ksc, ve, vch, vsc)):
                return kvcache._reference_paged_attention(
                    q_, *strips, pos, pol)[0]

            f, r = jax.jit(fused), jax.jit(ref)
            fused_us = _time(f, q, reps=reps)
            ref_us = _time(r, q, reps=reps)
            rows.append({"shape": [b, s, kv, hd], "scheme": scheme,
                         "fused_us": round(fused_us, 1),
                         "ref_us": round(ref_us, 1),
                         "bitexact": bool(np.array_equal(
                             np.asarray(f(q)), np.asarray(r(q))))})
    return rows


# Long-context single-sequence decode shapes (batch 1, one kv head, GQA
# rep 2, head_dim 128) for the chunked-vs-strip rows. The last length sits
# BEYOND the strip kernel's structural VMEM crossover (~8.1k tokens at
# head_dim 128), where the chunked kernel is the only honest TPU route.
ATTENTION_LONG_LENGTHS = (2048, 4096, 8192, 10240)
ATTENTION_LONG_LENGTHS_SMOKE = (512, 1024)


def bench_chunked_attention(lengths=ATTENTION_LONG_LENGTHS,
                            chunk_tokens=2048, hd=128, rep=2, reps=3):
    """Page-chunked online-softmax kernel vs the whole-strip kernel per
    sequence length and KV scheme — the ``bench_kernels/v5``
    ``attention_long`` rows. Each row records the strip kernel's VMEM
    working set against the per-core budget (``over_budget`` marks lengths
    where only the chunked kernel is deployable) and the chunked output's
    max abs error against the fp64 oracle with its tolerance gate.

    Returns ``(rows, crossover)`` where ``crossover`` pins the structural
    strip-VMEM crossover length per scheme for this (head_dim, rep)."""
    from repro.kernels import paged_attention
    from repro.serving import kvcache
    rng = np.random.default_rng(17)
    b, kv = 1, 1
    rows = []
    for s in lengths:
        q = jnp.asarray(rng.standard_normal((b, rep * kv, 1, hd)),
                        dtype=jnp.bfloat16)
        kf = jnp.asarray(rng.standard_normal((b, s, kv, hd)),
                         dtype=jnp.float32)
        vf = jnp.asarray(rng.standard_normal((b, s, kv, hd)),
                         dtype=jnp.float32)
        pos = jnp.full((b,), s - 1, jnp.int32)
        for scheme in kvcache.KV_SCHEMES:
            pol = kvcache.KVProtectionPolicy(scheme=scheme)
            ke, kch, ksc = kvcache._encode_kv(kf, pol)
            ve, vch, vsc = kvcache._encode_kv(vf, pol)

            def chunked(q_):
                return paged_attention.chunked_page_attention(
                    q_, ke, kch, ksc, ve, vch, vsc, pos, scheme=scheme,
                    chunk_tokens=chunk_tokens)[0]

            def strip(q_):
                return paged_attention.fused_page_attention(
                    q_, ke, kch, ksc, ve, vch, vsc, pos, scheme=scheme)[0]

            c, f = jax.jit(chunked), jax.jit(strip)
            chunked_us = _time(c, q, reps=reps)
            strip_us = _time(f, q, reps=reps)
            oracle = paged_attention.oracle_page_attention(
                q, ke, kch, ksc, ve, vch, vsc, pos, scheme=scheme)
            err = float(np.max(np.abs(
                np.asarray(c(q), np.float64) - oracle)))
            tol = 0.02 * (float(np.max(np.abs(oracle))) + 1e-6)
            vmem = paged_attention.strip_vmem_bytes(s, hd, rep, scheme)
            rows.append({
                "shape": [b, s, kv, hd], "scheme": scheme,
                "chunk_tokens": chunk_tokens,
                "chunked_us": round(chunked_us, 1),
                "strip_us": round(strip_us, 1),
                "strip_vmem_bytes": vmem,
                "chunked_vmem_bytes": paged_attention.chunked_vmem_bytes(
                    chunk_tokens, hd, rep, scheme),
                "over_budget":
                    vmem > paged_attention.VMEM_BUDGET_BYTES,
                "oracle_max_abs_err": err, "tol": tol,
                "within_tol": err <= tol,
            })
    crossover = {
        "head_dim": hd, "rep": rep,
        "vmem_budget_bytes": paged_attention.VMEM_BUDGET_BYTES,
        "chunk_tokens": chunk_tokens,
        "tokens_by_scheme": {
            scheme: paged_attention.strip_vmem_crossover(hd, rep, scheme)
            for scheme in kvcache.KV_SCHEMES},
    }
    return rows, crossover


def write_bench_kernels(path, entries=None, *, tile_sweep=TILE_SWEEP,
                        attention=None, attention_long=None,
                        crossover=None) -> dict:
    """Write BENCH_kernels.json in the ``bench_kernels/v6`` schema that
    ``protection.AutotuneTable`` loads (validated by round-tripping through
    it before writing)."""
    platform = jax.devices()[0].platform
    if entries is None:
        entries = bench_backend_decode()
        if tile_sweep:
            entries = bench_fused_tiles(entries, tile_sweep=tile_sweep)
    if attention is None:
        attention = bench_paged_attention()
    if attention_long is None:
        attention_long, crossover = bench_chunked_attention()
    payload = {"schema": protection.BENCH_KERNELS_SCHEMA,
               "platform": platform,
               "pallas_interpret": platform != "tpu",
               "op": "in-place-decode64+fused-qmatmul",
               "entries": entries,
               "attention": attention,
               "attention_long": attention_long}
    if crossover:
        payload["crossover"] = crossover
    protection.AutotuneTable.from_dict(payload)  # schema self-check
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the per-shape xla-vs-pallas decode + "
                         "fused-tile + paged-attention table "
                         "(BENCH_kernels.json, bench_kernels/v6)")
    ap.add_argument("--tiles-smoke", action="store_true",
                    help="tiny fused-tile sweep + short attention lengths "
                         "(CI smoke; interpret mode)")
    args = ap.parse_args(argv)
    us, b, r = bench_decode()
    print(f"kernel_ecc_decode,{us:.0f},tpu_roofline_us={r:.1f}_bytes={b}")
    us, fl, r = bench_qmatmul()
    print(f"kernel_ecc_qmatmul,{us:.0f},tpu_roofline_us={r:.1f}_flops={fl}")
    us, b, r = bench_throttle()
    print(f"kernel_throttle,{us:.0f},tpu_roofline_us={r:.1f}_bytes={b}")
    if args.json:
        sweep = TILE_SWEEP_SMOKE if args.tiles_smoke else TILE_SWEEP
        lengths = (ATTENTION_LONG_LENGTHS_SMOKE if args.tiles_smoke
                   else ATTENTION_LONG_LENGTHS)
        chunk = 256 if args.tiles_smoke else 2048
        attention_long, crossover = bench_chunked_attention(
            lengths=lengths, chunk_tokens=chunk)
        payload = write_bench_kernels(args.json, tile_sweep=sweep,
                                      attention_long=attention_long,
                                      crossover=crossover)
        for e in payload["entries"]:
            tiles = "x".join(str(t) for t in e.get("tiles", ()))
            i8 = "x".join(str(t) for t in e.get("int8_tiles", ()))
            print(f"autotune_decode_{e['shape'][0]}x{e['shape'][1]},"
                  f"xla={e['xla_us']:.0f}us,pallas={e['pallas_us']:.0f}us,"
                  f"best={e['best']},tiles={tiles},"
                  f"fused={e.get('fused_us', 0):.0f}us,"
                  f"abft={e.get('fused_abft_us', 0):.0f}us,int8_tiles={i8},"
                  f"fused_int8={e.get('fused_int8_us', 0):.0f}us,"
                  f"int8_abft={e.get('fused_int8_abft_us', 0):.0f}us")
        for r in payload.get("attention", ()):
            shp = "x".join(str(t) for t in r["shape"])
            print(f"paged_attention_{shp}_{r['scheme']},"
                  f"{r['fused_us']:.0f},ref_us={r['ref_us']:.0f}"
                  f"_bitexact={str(r['bitexact']).lower()}")
        for r in payload.get("attention_long", ()):
            shp = "x".join(str(t) for t in r["shape"])
            print(f"chunked_attention_{shp}_{r['scheme']},"
                  f"{r['chunked_us']:.0f},strip_us={r['strip_us']:.0f}"
                  f"_over_budget={str(r['over_budget']).lower()}"
                  f"_oracle_err={r['oracle_max_abs_err']:.2e}"
                  f"_within_tol={str(r['within_tol']).lower()}")
        if payload.get("crossover"):
            co = payload["crossover"]
            toks = ",".join(f"{k}={v}" for k, v in
                            sorted(co["tokens_by_scheme"].items()))
            print(f"# strip-VMEM crossover (hd={co['head_dim']} "
                  f"rep={co['rep']}): {toks} tokens "
                  f"@ {co['vmem_budget_bytes']} B budget")
        print(f"# wrote {args.json} ({payload['platform']}, "
              f"pallas_interpret={payload['pallas_interpret']})")


if __name__ == "__main__":
    main()
