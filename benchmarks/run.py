"""Benchmark runner: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import os
import sys


def main() -> None:
    from . import fault_injection, kernel_bench, weight_distribution, \
        wot_admm_compare, wot_training

    print("name,us_per_call,derived")
    kernel_bench.main()
    weight_distribution.main()
    wot_training.main()
    fault_injection.main([])  # explicit argv: don't inherit run.py's
    wot_admm_compare.main()

    # roofline rows if a dry-run result file exists
    for path in ("results/dryrun_16x16.jsonl", "results/dryrun_2x16x16.jsonl"):
        if os.path.exists(path):
            from . import roofline
            sys.argv = ["roofline", path]
            roofline.main()


if __name__ == "__main__":
    main()
