"""Paper Table 1 + Figure 1: weight distribution of 8-bit quantized CNNs.

Trains the paper's three CNNs (reduced scale, synthetic data; Adam pretrain
standing in for ImageNet pretraining) and reports
(a) % of |q| in [0,32) / [32,64) / [64,128]  (Table 1 'Percentage' rows)
(b) the position histogram of large values within 8-byte blocks (Figure 1)
(c) accuracy float32 vs int8 (Table 1 'Accuracy' rows).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import quant, wot
from repro.training.cnn_experiments import accuracy, pretrain


def weight_stats(params):
    qs = []
    for leaf in jax.tree.leaves(params):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2:
            q, _ = quant.quantize(leaf)
            qs.append(np.asarray(q).reshape(-1))
    q = np.concatenate(qs)
    import jax.numpy as jnp
    pct = wot.range_percentages(q)
    hist = np.asarray(wot.large_position_histogram(jnp.asarray(q)))
    return q.size, pct, hist


def run(steps=100, verbose=True):
    rows = []
    for name in ("vgg16", "resnet18", "squeezenet"):
        t0 = time.time()
        params, fwd, tmpl = pretrain(name, steps=steps)
        acc_f32 = accuracy(params, fwd, tmpl, quantized=False)
        acc_int8 = accuracy(params, fwd, tmpl, quantized=True)
        n, pct, hist = weight_stats(params)
        us = (time.time() - t0) * 1e6 / max(steps, 1)
        rows.append((name, us, n, acc_f32, acc_int8, pct, hist))
        if verbose:
            print(f"# {name}: {n} weights, acc f32={acc_f32:.3f} "
                  f"int8={acc_int8:.3f}")
            print(f"#   |q| pct (Table 1): {pct}")
            print(f"#   large-value position histogram (Fig 1): "
                  f"{hist.tolist()}")
    return rows


def main():
    for name, us, n, a32, a8, pct, hist in run():
        print(f"table1_{name},{us:.0f},"
              f"acc_f32={a32:.3f}_int8={a8:.3f}_small_pct="
              f"{pct['[0,32)'] + pct['[32,64)']:.2f}")


if __name__ == "__main__":
    main()
