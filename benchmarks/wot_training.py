"""Paper Figures 3 & 4: WOT/QATT convergence.

Tracks, per WOT iteration: (a) # of large values in protected positions
BEFORE throttling (Fig 3 — falls toward 0), and (b) accuracy before vs after
throttling (Fig 4 — the gap closes, recovering the quantized baseline)."""
from __future__ import annotations

import time

from repro.training.cnn_experiments import (accuracy, large_count, pretrain,
                                            wot_finetune)


def run(name="resnet18", pre_steps=100, wot_steps=40, verbose=True):
    params, fwd, tmpl = pretrain(name, steps=pre_steps)
    acc_base = accuracy(params, fwd, tmpl, quantized=True)
    n_large0 = large_count(params)

    t0 = time.time()
    params, tmpl, curve = wot_finetune(params, fwd, tmpl, steps=wot_steps,
                                       track=True)
    us = (time.time() - t0) * 1e6 / wot_steps
    final_acc = accuracy(params, fwd, tmpl, quantized=True)

    if verbose:
        print(f"# {name} baseline int8 accuracy: {acc_base:.3f}, "
              f"initial large values: {n_large0}")
        print("# iter  large_before_throttle  acc_before  acc_after (Fig3/4)")
        for s, pre, a, b in curve:
            if a is not None:
                print(f"#  {s:3d}  {pre:6d}  {a:.3f}  {b:.3f}")
        print(f"# final WOT accuracy: {final_acc:.3f} "
              f"(baseline {acc_base:.3f})")
    assert large_count(params) == 0, "WOT constraint violated"
    return us, acc_base, final_acc, curve, n_large0


def main():
    us, acc_base, final_acc, curve, n0 = run()
    print(f"fig3_fig4_wot,{us:.0f},final_acc={final_acc:.3f}"
          f"_baseline={acc_base:.3f}_large_init={n0}_large_final=0")


if __name__ == "__main__":
    main()
