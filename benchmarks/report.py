"""Generate the EXPERIMENTS.md roofline tables from dry-run JSONL files."""
from __future__ import annotations

import json
import sys

from . import roofline


def table(path: str, caption: str) -> str:
    rows = []
    skips = []
    for line in open(path):
        r = json.loads(line)
        if r["status"] == "ok":
            a = roofline.analyze(r)
            a["_peak"] = r.get("memory", {}).get("peak_memory_in_bytes", 0)
            a["_compile"] = r.get("compile_s", 0)
            rows.append(a)
        elif r["status"] == "skipped":
            skips.append((r["arch"], r["shape"]))
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    out = [f"**{caption}** ({len(rows)} cells ok, {len(skips)} skipped)\n"]
    out.append("| arch | shape | compute s | memory s | collective s | "
               "dominant | MODEL_FLOPS | useful | roofline frac | peak GB |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['model_flops']:.2e} | "
            f"{r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} | "
            f"{r['_peak'] / 1e9:.1f} |")
    if skips:
        out.append("")
        out.append("Skipped (per assignment — long_500k on pure "
                   "full-attention archs): " +
                   ", ".join(f"{a}/{s}" for a, s in skips))
    return "\n".join(out)


def main():
    for path, cap in [("results/dryrun_16x16.jsonl",
                       "Baseline, single-pod 16x16 (256 chips)"),
                      ("results/dryrun_2x16x16.jsonl",
                       "Baseline, multi-pod 2x16x16 (512 chips)"),
                      ("results/dryrun_16x16_opt.jsonl",
                       "Optimized, single-pod 16x16 (256 chips)"),
                      ("results/dryrun_2x16x16_opt.jsonl",
                       "Optimized, multi-pod 2x16x16 (512 chips)")]:
        try:
            print(table(path, cap))
            print()
        except FileNotFoundError:
            pass


if __name__ == "__main__":
    main()
