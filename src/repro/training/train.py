"""Training step builders: QAT forward + grad accumulation + SGD/momentum +
WOT throttling — the paper's QATT loop (§4.1), scaled out with pjit.

The step is a single jit-able function so the whole thing lowers/compiles
for the production mesh in the dry-run.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import quant, wot
from repro.models import lm
from repro.models.config import ArchConfig
from . import optim


def qat_wt(w):
    """Weight transform used in forward: fake-quant every >=2D float tensor."""
    if w.ndim >= 2 and jnp.issubdtype(w.dtype, jnp.floating):
        return quant.fake_quant(w)
    return w


def qat_wt_bf16(w):
    """fake-quant + bf16 cast BEFORE use, so sharding collectives (FSDP /
    TP gathers) move 2-byte weights, not 4-byte masters (§Perf iter: halves
    weight-gather wire bytes; adds one bf16 rounding on the int8 grid —
    standard mixed-precision semantics)."""
    if w.ndim >= 2 and jnp.issubdtype(w.dtype, jnp.floating):
        return quant.fake_quant(w).astype(jnp.bfloat16)
    return w


def _split_micro(batch, n_micro: int):
    def sp(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(cfg: ArchConfig, *, qat: bool = True, wot_throttle: bool = True,
                    lr: float = 1e-4, mu: float = 0.9, wd: float = 1e-4,
                    chunk: int = 2048, bf16_weights: bool = True,
                    loss_fn: Optional[Callable] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, loss).

    QATT (paper §4.1): 1) QAT fwd/bwd with fake-quantized params + fp32
    masters; 2) throttle quantized weights to the WOT constraint and push the
    clamp back into the masters.
    """
    wt = (qat_wt_bf16 if bf16_weights else qat_wt) if qat else lm.Identity
    lfn = loss_fn or (lambda p, b: lm.loss_fn(cfg, p, b, wt=wt, chunk=chunk))

    def train_step(params, opt_state, batch):
        """Fused grad-accumulation-into-momentum (one param-sized buffer
        instead of two):  m' = mu*m + mean_i(g_i) + 2*wd*w ;  w' = w - lr*m'.
        Identical math to accumulate-then-SGD, ~33% optimizer memory saved
        at 512-device scale."""
        micro = _split_micro(batch, cfg.microbatch)
        inv = 1.0 / cfg.microbatch

        def acc_step(carry, mb):
            loss_sum, m_acc = carry
            l, g = jax.value_and_grad(lfn)(params, mb)
            m_acc = jax.tree.map(lambda m, gg: m + gg.astype(m.dtype) * inv,
                                 m_acc, g)
            return (loss_sum + l, m_acc), None

        m0 = jax.tree.map(lambda m: m * mu, opt_state.momentum)
        (loss_sum, m_acc), _ = jax.lax.scan(acc_step, (jnp.zeros(()), m0), micro)
        m_new = jax.tree.map(lambda m, w: m + (2.0 * wd) * w, m_acc, params)
        params = jax.tree.map(lambda w, m: w - lr * m.astype(w.dtype),
                              params, m_new)
        if wot_throttle:
            params = wot.throttle_tree(params)
        return params, optim.SgdState(m_new), loss_sum * inv

    return train_step


def make_cnn_train_step(cfg_forward: Callable, *, qat: bool = True,
                        wot_throttle: bool = True, lr: float = 1e-4,
                        mu: float = 0.9, wd: float = 1e-4):
    """QATT for the paper's CNNs. cfg_forward(params, images, wt) -> logits."""
    wt = qat_wt if qat else (lambda w: w)

    def loss_fn(params, batch):
        logits = cfg_forward(params, batch["images"], wt)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        tgt = jnp.take_along_axis(logits.astype(jnp.float32),
                                  batch["labels"][:, None], axis=-1)[:, 0]
        return jnp.mean(lse - tgt)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = optim.sgd_update(params, grads, opt_state,
                                             lr=lr, mu=mu, wd=wd)
        if wot_throttle:
            params = wot.throttle_tree(params)
        return params, opt_state, loss

    @jax.jit
    def eval_step(params, batch):
        logits = cfg_forward(params, batch["images"], wt)
        return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]))

    return train_step, eval_step
