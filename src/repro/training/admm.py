"""ADMM-based WOT training (paper §4.1, the evaluated-and-rejected variant).

The paper formulates the WOT constraint via ADMM (Eqs. 5-9): alternate
  1. W-step: SGD on f(W) + λ||W||_F² + γ||W - Z + U||_F²
  2. Z-step: project W + U onto the constraint set S (clamp positions 0..6)
  3. U-step: U += W - Z
and reports that it fails to drive the large-value count to zero and needs a
lossy final hard clamp. We implement it faithfully as the comparison
baseline; `benchmarks/wot_admm_compare.py` reproduces the paper's finding
that QATT dominates.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant, wot
from . import optim, train


class AdmmState(NamedTuple):
    opt: optim.SgdState
    z: dict
    u: dict


def _project(tree, iters: int = 4):
    """Projection onto S in the float domain. Clamping can shrink the
    per-tensor max and hence the quantization scale, re-exposing values at
    the new scale — iterate to a fixed point (converges geometrically; 4
    passes suffice at fp32)."""
    for _ in range(iters):
        tree = wot.throttle_tree(tree)
    return tree


def admm_init(params) -> AdmmState:
    return AdmmState(optim.sgd_init(params),
                     jax.tree.map(jnp.array, params),
                     jax.tree.map(jnp.zeros_like, params))


def make_admm_step(forward_loss, *, lr=1e-3, mu=0.9, wd=1e-4, gamma=1e-3,
                   dual_every: int = 1):
    """forward_loss(params, batch) -> scalar (QAT loss). Returns
    admm_step(params, state, batch) -> (params, state, loss)."""

    def aug_loss(params, z, u, batch):
        base = forward_loss(params, batch)
        pen = 0.0
        for w, z_, u_ in zip(jax.tree.leaves(params), jax.tree.leaves(z),
                             jax.tree.leaves(u)):
            pen = pen + jnp.sum(jnp.square(w - z_ + u_))
        return base + gamma * pen

    @jax.jit
    def admm_step(params, state: AdmmState, batch):
        loss, grads = jax.value_and_grad(aug_loss)(params, state.z, state.u,
                                                   batch)
        params, opt = optim.sgd_update(params, grads, state.opt,
                                       lr=lr, mu=mu, wd=wd)
        # Z-step: project W + U onto S
        wu = jax.tree.map(jnp.add, params, state.u)
        z = _project(wu)
        # U-step
        u = jax.tree.map(lambda u_, w, z_: u_ + w - z_, state.u, params, z)
        return params, AdmmState(opt, z, u), loss

    return admm_step


def finalize(params):
    """Paper: after ADMM training the constraint still isn't met; remaining
    large values in protected positions are hard-clamped (lossy)."""
    return _project(params, iters=8)
