"""Fault-tolerant checkpointing.

* Atomic saves (tmp + rename), keep-last-k rotation, step-indexed.
* ``protected=True`` stores weights as int8 + in-place ECC (the paper's
  format) — the checkpoint *itself* is memory-fault-protected, and 4x
  smaller than fp32.
* Elastic restore: arrays are saved with logical shapes only; on load they
  are ``device_put`` to whatever mesh/sharding the *current* job uses, so a
  job may resume on a different pod count after failures.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro import protection
from repro.core import quant, wot


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree, *, step: int, protected: bool = False,
         scheme: str = "in-place", keep: int = 3) -> str:
    """Atomic save of a pytree. Returns the final checkpoint dir."""
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    leaves, treedef = _flatten(tree)
    host_scheme = protection.get_host_scheme(scheme)
    meta = {"step": step, "protected": protected, "n_leaves": len(leaves),
            "scheme": host_scheme.scheme_id, "treedef": str(treedef)}
    arrays = {}
    for i, leaf in enumerate(leaves):
        a = np.asarray(leaf)
        leaf_path = flat_with_path[i][0]
        if protected and wot.is_protected_weight(leaf_path, leaf):
            scale = float(np.max(np.abs(a))) / quant.QMAX or 1e-12
            q = np.clip(np.round(a / scale), -127, 127).astype(np.int8)
            q = np.asarray(wot.throttle_q(q.reshape(-1))).reshape(a.shape)
            stored = host_scheme.encode(q.reshape(-1))
            arrays[f"leaf_{i}"] = stored.data
            if stored.checks is not None:
                arrays[f"leaf_{i}_checks"] = stored.checks
            meta[f"leaf_{i}"] = {"protected": True, "shape": list(a.shape),
                                 "dtype": str(a.dtype), "scale": scale,
                                 "n": int(stored.n_weights)}
        else:
            arrays[f"leaf_{i}"] = a
            meta[f"leaf_{i}"] = {"protected": False}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _rotate(path, keep)
    return final


def _rotate(path: str, keep: int):
    ckpts = sorted(d for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(path, d))


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    ckpts = sorted(d for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return int(ckpts[-1].split("_")[1]) if ckpts else None


def restore(path: str, tree_like, *, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``tree_like``; optionally device_put to
    ``shardings`` (elastic re-meshing)."""
    step = step if step is not None else latest_step(path)
    assert step is not None, f"no checkpoint under {path}"
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves, treedef = _flatten(tree_like)
    host_scheme = protection.get_host_scheme(meta.get("scheme", "in-place"))
    out = []
    for i in range(len(leaves)):
        lm_ = meta[f"leaf_{i}"]
        a = data[f"leaf_{i}"]
        if lm_["protected"]:
            checks = (data[f"leaf_{i}_checks"]
                      if f"leaf_{i}_checks" in data.files else None)
            stored = protection.Stored(a, checks, lm_["n"])
            q = host_scheme.decode(stored).reshape(lm_["shape"])
            a = (q.astype(np.float32) * lm_["scale"]).astype(lm_["dtype"])
        out.append(a)
    restored = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree.map(jax.device_put, restored, shardings)
    return restored, step


class AsyncCheckpointer:
    """Background-thread checkpointer: training never blocks on I/O."""

    def __init__(self, path: str, *, protected: bool = False, keep: int = 3):
        self.path, self.protected, self.keep = path, protected, keep
        self._thread: Optional[threading.Thread] = None

    def save(self, tree, step: int):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async
        self._thread = threading.Thread(
            target=save, args=(self.path, host_tree),
            kwargs=dict(step=step, protected=self.protected, keep=self.keep))
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
