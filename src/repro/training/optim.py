"""Optimizers in pure JAX. SGD + momentum is the paper's WOT optimizer
(§5.2: lr 1e-4, momentum 0.9, weight decay λ=1e-4 via the Frobenius
regularizer); AdamW provided for the from-scratch pretraining examples."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SgdState(NamedTuple):
    momentum: any


def sgd_init(params) -> SgdState:
    return SgdState(jax.tree.map(jnp.zeros_like, params))


def sgd_update(params, grads, state: SgdState, *, lr, mu=0.9, wd=1e-4):
    """Paper-faithful: g += 2*wd*w (Frobenius term), m = mu*m + g, w -= lr*m."""
    def upd(w, g, m):
        g = g + 2.0 * wd * w
        m = mu * m + g
        return w - lr * m, m
    out = jax.tree.map(upd, params, grads, state.momentum)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, SgdState(new_m)


class AdamState(NamedTuple):
    mu: any
    nu: any
    count: jnp.ndarray


def adam_init(params) -> AdamState:
    z = jax.tree.map(jnp.zeros_like, params)
    return AdamState(z, jax.tree.map(jnp.zeros_like, params),
                     jnp.zeros((), jnp.int32))


def adam_update(params, grads, state: AdamState, *, lr, b1=0.9, b2=0.95,
                eps=1e-8, wd=0.0):
    c = state.count + 1
    bc1 = 1 - b1 ** c.astype(jnp.float32)
    bc2 = 1 - b2 ** c.astype(jnp.float32)

    def upd(w, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        return w - lr * (step + wd * w), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    get = lambda i: jax.tree.map(lambda t: t[i], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return get(0), AdamState(get(1), get(2), c)
