"""Distributed-optimization trick: int8 gradient compression with error
feedback, applied at the data-parallel reduction boundary.

At 1000+ nodes the gradient all-reduce dominates the step at small
per-device batch; int8 compression cuts DP collective bytes 4x (vs fp32).
Error feedback (residual accumulation) keeps SGD convergence unharmed
(Karimireddy et al. 2019). Exposed both as a pure function pair (unit /
property tested) and as a shard_map-based compressed psum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


def compress(g: jnp.ndarray, residual: jnp.ndarray):
    """g + residual -> (q int8, scale, new_residual)."""
    t = g + residual
    scale = quant.compute_scale(t)
    q = jnp.clip(jnp.round(t / scale), -quant.QMAX, quant.QMAX).astype(jnp.int8)
    deq = q.astype(t.dtype) * scale
    return q, scale, t - deq


def decompress(q: jnp.ndarray, scale, dtype=jnp.float32) -> jnp.ndarray:
    return q.astype(dtype) * scale


def compress_tree(grads, residuals):
    qs, scales, new_res = {}, {}, {}
    flat, treedef = jax.tree_util.tree_flatten(grads)
    rflat, _ = jax.tree_util.tree_flatten(residuals)
    out = [compress(g, r) for g, r in zip(flat, rflat)]
    q = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    s = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    res = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return q, s, res


def compressed_psum(g: jnp.ndarray, residual: jnp.ndarray, axis_name: str):
    """Inside shard_map: mean-all-reduce int8 instead of fp32 (4x fewer DP
    bytes). All workers quantize against the *global* max scale (one scalar
    pmax) so the int8 payloads are summable; error feedback eats the
    quantization error locally."""
    t = g + residual
    scale = jax.lax.pmax(quant.compute_scale(t), axis_name)
    q = jnp.clip(jnp.round(t / scale), -quant.QMAX, quant.QMAX).astype(jnp.int8)
    new_res = t - q.astype(t.dtype) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n, new_res
