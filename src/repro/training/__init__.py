from . import admm, checkpoint, compress, optim, train  # noqa: F401
