"""Shared CNN experiment harness for the paper's evaluation (used by the
benchmarks, tests, and examples).

Mirrors the paper's methodology: start from a *trained* fp32 model
(paper: ImageNet-pretrained; here: Adam-pretrained on the synthetic task),
then run WOT fine-tuning = QAT + throttling with SGD momentum (paper §5.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import protection
from repro.core import quant, wot
from repro.data import synthetic
from repro.models import cnn
from . import optim, train

IMG_NORM = 3.0  # images have pixel std ~1.8; normalize into unit-ish range


def _norm(x):
    return x / IMG_NORM


def pretrain(name: str, *, steps=80, lr=1e-3, scale=0.25, img=32,
             n_classes=4, seed=0):
    """Phase 1: fp32 Adam pretraining (stands in for ImageNet weights)."""
    init, fwd = cnn.CNNS[name]
    params = init(jax.random.PRNGKey(seed), n_classes=n_classes, scale=scale,
                  img_size=img)

    def loss_fn(p, batch):
        lg = fwd(p, _norm(batch["images"])).astype(jnp.float32)
        return jnp.mean(jax.nn.logsumexp(lg, -1) - jnp.take_along_axis(
            lg, batch["labels"][:, None], 1)[:, 0])

    st = optim.adam_init(params)

    @jax.jit
    def step(p, st, b):
        l, g = jax.value_and_grad(loss_fn)(p, b)
        p, st = optim.adam_update(p, g, st, lr=lr)
        return p, st, l

    tmpl = None
    for s in range(steps):
        b, tmpl = synthetic.image_batch(n_classes, 64, img, seed=seed, step=s,
                                        templates=tmpl)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, st, _ = step(params, st, b)
    return params, fwd, tmpl


def wot_finetune(params, fwd, tmpl, *, steps=40, lr=1e-3, n_classes=4,
                 img=32, seed=0, throttle=True, track=False):
    """Phase 2: QATT (paper §4.1) — QAT fwd/bwd + SGD momentum + throttling.
    With track=True returns the Fig 3/4 curves."""
    step, _ = train.make_cnn_train_step(
        lambda p, x, wt: fwd(p, _norm(x), wt=wt), qat=True,
        wot_throttle=False, lr=lr)  # throttle applied explicitly for tracking
    opt = optim.sgd_init(params)
    curve = []
    for s in range(steps):
        b, tmpl = synthetic.image_batch(n_classes, 64, img, seed=seed,
                                        step=1000 + s, templates=tmpl)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step(params, opt, b)
        if track:
            pre = large_count(params)
            a_pre = accuracy(params, fwd, tmpl, quantized=True) \
                if s % 10 == 0 else None
        if throttle:
            params = wot.throttle_tree(params)
        if track:
            a_post = accuracy(params, fwd, tmpl, quantized=True) \
                if s % 10 == 0 else None
            curve.append((s, pre, a_pre, a_post))
    return params, tmpl, curve


def train_cnn_wot(name: str, *, pre_steps=80, wot_steps=40, scale=0.25,
                  img=32, n_classes=4, seed=0):
    """Full paper pipeline -> (params, fwd, templates)."""
    params, fwd, tmpl = pretrain(name, steps=pre_steps, scale=scale, img=img,
                                 n_classes=n_classes, seed=seed)
    params, tmpl, _ = wot_finetune(params, fwd, tmpl, steps=wot_steps,
                                   n_classes=n_classes, img=img, seed=seed)
    return params, fwd, tmpl


def accuracy(params, fwd, tmpl, *, quantized=False, n_classes=4, img=32,
             batch=256, seed=777):
    b, _ = synthetic.image_batch(n_classes, batch, img, seed=seed, step=0,
                                 templates=tmpl)
    wt = train.qat_wt if quantized else (lambda w: w)
    lg = fwd(params, _norm(jnp.asarray(b["images"])), wt=wt)
    return float(np.mean(np.argmax(np.asarray(lg), -1) == b["labels"]))


def large_count(params) -> int:
    total = 0
    for leaf in jax.tree.leaves(params):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2:
            q, _ = quant.quantize(leaf)
            total += int(wot.count_large_in_protected(q.reshape(-1)))
    return total


def eval_policy(scheme_name) -> protection.ProtectionPolicy:
    """The paper's evaluation protects every >=2-D tensor (conv + fc)."""
    return protection.ProtectionPolicy(
        default_scheme=scheme_name,
        predicate=lambda path, leaf: getattr(leaf, "ndim", 0) >= 2)


def run_scheme_campaign(params, fwd, tmpl, scheme_name, *, rates, trials,
                        key=None, batch="vmap", n_classes=4, img=32,
                        eval_batch=256, policy=None):
    """Compiled Table-2 column for one scheme: encode once, sweep the whole
    (trial x rate) grid on device in one jitted program (one compile per
    (model, scheme)). Returns a :class:`repro.protection.CampaignResult`.

    ``policy`` overrides the scheme-derived eval policy — pass a
    ``ProtectionPolicy`` (e.g. a mixed-scheme preset) to campaign it under
    the same input pipeline as the Table-2 scheme rows."""
    return protection.run_campaign(
        params, lambda p, x: fwd(p, _norm(x)), tmpl,
        policy if policy is not None else eval_policy(scheme_name),
        rates=rates, trials=trials, key=key, batch=batch,
        n_classes=n_classes, img=img, eval_batch=eval_batch)


def eval_with_scheme(params, fwd, tmpl, scheme_name, rate, seed, *,
                     n_classes=4, img=32):
    """Host-path oracle for one (scheme, rate, trial) cell: quantize+throttle
    weights, encode/inject/decode through a ``ProtectionPolicy`` with NumPy
    injection, eval accuracy. Returns (accuracy, space_overhead).

    Kept as the cross-check for :func:`run_scheme_campaign` — the campaign
    parity tests assert both paths agree statistically on the same grid."""
    policy = eval_policy(scheme_name)
    enc = policy.encode_tree(params)
    if rate:
        enc = protection.inject_tree(enc, rate, seed)
    faulty = protection.decode_tree(enc, jnp.float32)
    b, _ = synthetic.image_batch(n_classes, 256, img, seed=777, step=0,
                                 templates=tmpl)
    lg = cnn_forward_cached(faulty, fwd, b)
    acc = float(np.mean(np.argmax(np.asarray(lg), -1) == b["labels"]))
    return acc, protection.space_overhead(enc)


def cnn_forward_cached(params, fwd, batch):
    return fwd(params, _norm(jnp.asarray(batch["images"])))
