"""Sharding rules: DP + FSDP over 'data' (and 'pod'), TP/EP over 'model'.

Parameter rules are path-based over the pytrees produced by ``models.lm``.
Conventions (2-D matmul weights, layer-stacked with a leading L axis):

  in-projections  (D_in, D_out)  -> P(data, model)   (column parallel + FSDP)
  out-projections (D_in, D_out)  -> P(model, data)   (row parallel + FSDP)
  expert weights  (E, D, F)      -> P(model, data, None)   (EP + FSDP)
  embeddings      (V, D)         -> P(model, data)
  1-D params / norms / convs     -> replicated

KV caches shard sequence over 'model' (every arch's head count need not
divide 16; S always does) and batch over 'data'.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

IN_PROJ = {"wq", "wk", "wv", "w_gate", "w_up", "w_y_gate", "w_input_gate",
           "w_a_gate", "w_dkv", "w_dq", "w_uq", "w_uk", "w_uv", "router",
           "ws_gate", "ws_up"}
OUT_PROJ = {"wo", "w_down", "w_out", "ws_down"}
EXPERT_IN = {"we_gate", "we_up"}
EXPERT_OUT = {"we_down"}
PACKED_IN = {"w_in"}  # mamba2 packed projection: model-sharding would split
                      # the [x,z,B,C,dt] concat across shards -> data only


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


def param_spec(path, leaf, *, data="data", model="model",
               fsdp: bool = True) -> P:
    names = _path_names(path)
    name = names[-1]
    d = data if fsdp else None
    base: Optional[tuple]

    if name in ("embed",):
        base = (model, d)
    elif name in ("head",):
        base = (d, model)
    elif name in EXPERT_IN:
        base = (model, d, None)
    elif name in EXPERT_OUT:
        base = (model, None, d)
    elif name in PACKED_IN:
        base = (d, None)
    elif name in IN_PROJ:
        base = (d, model)
    elif name in OUT_PROJ:
        base = (model, d)
    else:
        base = ()  # norms, biases, convs, scalars -> replicated

    ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
    if base and ndim == len(base) + 1:   # stacked layer axis
        base = (None, *base)
    elif base and ndim != len(base):     # unexpected rank -> replicate
        base = ()
    return P(*base)


def param_specs(params, **kw):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_spec(p, l, **kw), params)


def cache_spec(path, leaf, *, data="data", model="model") -> P:
    names = _path_names(path)
    name = names[-1]
    ndim = leaf.ndim
    if name in ("k", "v", "cross_k", "cross_v"):       # (L,B,S,kv,hd)
        return P(None, data, model, None, None)
    if name in ("k_pages", "v_pages", "k_checks", "v_checks"):
        # (L, P, ps, kv, hd | hd/8) paged pools: identity page tables are
        # batch-major, so the pool dim follows the batch ('data') sharding;
        # pages are indivisible ECC units, so ps/kv/hd stay whole
        return P(None, data, None, None, None)
    if name in ("k_scale", "v_scale"):                 # (L,P,ps)
        return P(None, data, None)
    if name == "kv_table":                             # (L,B,npg) — tiny;
        return P(None, None, None)                     # replicate
    if name in ("latent", "k_rope"):                   # (L,B,S,r)
        return P(None, data, model, None)
    if name == "state":                                # (L,B,h,p,n)
        return P(None, data, None, None, None)
    if name.endswith("_h") or name == "h":             # (L,B,w)
        return P(None, data, None)
    if name.endswith("conv"):                          # (L,B,k-1,c)
        return P(None, data, None, None)
    return P(*([None] * ndim))


def cache_specs(cache, **kw):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: cache_spec(p, l, **kw), cache)


def batch_spec(name: str, leaf, *, dp) -> P:
    ndim = leaf.ndim
    return P(dp, *([None] * (ndim - 1)))


def batch_specs(batch, *, multi_pod: bool = False):
    dp = ("pod", "data") if multi_pod else "data"
    return {k: batch_spec(k, v, dp=dp) for k, v in batch.items()}


def to_named(tree_of_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
