"""Pipeline parallelism (GPipe-style) over a 'stage' mesh axis via shard_map
+ collective_permute.

The assigned production meshes use DP(+pod) x TP, which is the right config
for <=512 chips at these model sizes; this module demonstrates the PP
substrate needed beyond that (thousands of chips / very deep models): layers
are split into S stages, microbatches stream through with
collective_permute boundaries, bubble fraction (S-1)/(S-1+M).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def make_pipeline_fn(stage_fn: Callable, n_stages: int, n_micro: int,
                     mesh: Mesh, axis: str = "stage"):
    """stage_fn(stage_params, x) -> x, applied S times in sequence.

    Returns pipe(params_stacked, x_micro) where params_stacked has leading
    stage axis (sharded over `axis`) and x_micro is (n_micro, mb, ...)
    (replicated). Output: (n_micro, mb, ...) from the last stage.
    """
    assert n_micro >= n_stages, "need >= S microbatches to fill the pipe"

    def per_device(params, xs):
        # params: stage-local (leading axis 1) ; xs: all microbatches
        params = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(axis)
        n_steps = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def step(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if within range)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = jnp.where(sid == 0,
                               xs[mb_idx].astype(buf.dtype), buf)
            y = stage_fn(params, inject)
            # last stage emits microbatch (t - S + 1)
            out_idx = jnp.clip(t - n_stages + 1, 0, n_micro - 1)
            emit = jnp.logical_and(sid == n_stages - 1, t >= n_stages - 1)
            outs = jax.lax.cond(
                emit, lambda o: o.at[out_idx].set(y.astype(o.dtype)),
                lambda o: o, outs)
            # shift activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(step, (buf, outs),
                                      jnp.arange(n_steps))
        # broadcast final outputs from the last stage to all (psum of one-hot)
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), {"_": 0})["_"]

    def pipe(params_stacked, x_micro):
        in_specs = (jax.tree.map(lambda _: P(axis), params_stacked), P())
        return shard_map(per_device, mesh=mesh, in_specs=in_specs,
                         out_specs=P(), check_rep=False)(params_stacked,
                                                         x_micro)

    return pipe
