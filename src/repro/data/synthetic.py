"""Deterministic synthetic data pipelines (no network access in this repo).

* ``token_batches`` — a Zipf-ish token stream with local n-gram structure so
  LMs have signal to learn; per-step deterministic (seed, step) so restarts
  and elastic re-sharding reproduce the exact stream (fault tolerance).
* ``image_batches`` — class-template images + noise: linearly separable but
  non-trivial; CNNs trained on it show the paper's weight-distribution
  phenomenology at CPU scale.
* Loaders yield GLOBAL batches; the launcher device_puts them with the batch
  sharding — hosts in a real multi-pod job would each read their slice
  (shard_index / shard_count mirror that API).
"""
from __future__ import annotations

import numpy as np


def token_batch(vocab: int, batch: int, seq: int, *, seed: int, step: int,
                shard_index: int = 0, shard_count: int = 1):
    """Returns {"tokens", "targets"} int32 arrays of shape (batch, seq)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, shard_index]))
    b = batch // shard_count
    # Markov-ish stream: next token = (prev * a + noise) % vocab
    a = 31
    x = rng.integers(0, vocab, size=(b, seq + 1))
    noise = rng.integers(0, max(2, vocab // 64), size=(b, seq))
    for t in range(1, seq + 1):
        x[:, t] = (x[:, t - 1] * a + noise[:, t - 1]) % vocab
    return {"tokens": x[:, :-1].astype(np.int32),
            "targets": x[:, 1:].astype(np.int32)}


def image_batch(n_classes: int, batch: int, img: int, *, seed: int, step: int,
                templates: np.ndarray | None = None):
    """Returns ({"images": (B,H,W,3) f32, "labels": (B,) i32}, templates)."""
    rng_t = np.random.default_rng(seed)
    if templates is None:
        templates = rng_t.normal(size=(n_classes, img, img, 3)).astype(np.float32)
    rng = np.random.default_rng(np.random.SeedSequence([seed + 1, step]))
    labels = rng.integers(0, n_classes, size=batch)
    noise = rng.normal(scale=1.5, size=(batch, img, img, 3)).astype(np.float32)
    images = templates[labels] + noise
    return {"images": images, "labels": labels.astype(np.int32)}, templates
