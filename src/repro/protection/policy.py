"""``ProtectionPolicy`` — per-layer scheme selection over pytrees.

The policy is the single entry point for protecting a model: it decides
*which* leaves get protected (predicate), *how* (string-keyed scheme registry
+ ordered per-layer rules, so one model can mix schemes), and *where the
bytes live* (same-shape images that inherit sharding, or flat-padded images
for tensors whose last dim is not a block multiple — the old silent
``last-dim % 8`` gate is gone: unaligned tensors are padded and protected by
default, and every decision is visible in the ``CoverageReport``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults, quant, wot

from .backends import AutotuneTable, get_backend
from .schemes import Scheme, get_scheme
from .tensor import ProtectedTensor, is_protected_tensor

__all__ = ["ProtectionPolicy", "CoverageReport", "CoverageEntry",
           "decode_tree", "decode_leaf", "decode_leaf_with_flags",
           "decode_tree_with_flags", "inject_tree", "inject_tree_device",
           "spec_tree", "space_overhead", "path_str"]

BLOCK = 8


def path_str(path) -> str:
    """'layers/0/wq'-style name for a key path (dict/attr/index entries)."""
    out = []
    for p in path:
        for attr in ("key", "name", "idx"):
            if hasattr(p, attr):
                out.append(str(getattr(p, attr)))
                break
        else:
            out.append(str(p))
    return "/".join(out)


# ---------------------------------------------------------------------------
# coverage reporting
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CoverageEntry:
    path: str
    scheme_id: Optional[str]   # None => not protected
    reason: str                # "" | "predicate" | "rule" | "unaligned"
    n_weights: int             # element count of the leaf
    nbytes: int                # stored bytes if protected, raw bytes if not
    pad_bytes: int             # zero-padding added by the flat layout

    @property
    def protected(self) -> bool:
        return self.scheme_id is not None


@dataclasses.dataclass
class CoverageReport:
    """What a policy does (or did) to every leaf of a tree — the loud
    replacement for silently skipping unaligned tensors."""

    entries: list

    @property
    def protected(self) -> list:
        return [e for e in self.entries if e.protected]

    @property
    def unprotected(self) -> list:
        return [e for e in self.entries if not e.protected]

    @property
    def n_protected(self) -> int:
        return len(self.protected)

    @property
    def n_unprotected(self) -> int:
        return len(self.unprotected)

    @property
    def protected_bytes(self) -> int:
        return sum(e.nbytes for e in self.protected)

    @property
    def unprotected_bytes(self) -> int:
        return sum(e.nbytes for e in self.unprotected)

    @property
    def unprotected_weight_bytes(self) -> int:
        """Bytes of weight-like leaves the policy declined (reason
        'unaligned' under pad=False) — the gaps that used to be silent."""
        return sum(e.nbytes for e in self.unprotected
                   if e.reason == "unaligned")

    @property
    def pad_bytes(self) -> int:
        return sum(e.pad_bytes for e in self.protected)

    def by_scheme(self) -> dict:
        out: dict = {}
        for e in self.protected:
            out[e.scheme_id] = out.get(e.scheme_id, 0) + 1
        return out

    def summary(self) -> str:
        lines = [f"protection coverage: {self.n_protected} tensors protected "
                 f"({self.protected_bytes / 2**20:.2f} MiB stored), "
                 f"{self.n_unprotected} unprotected "
                 f"({self.unprotected_bytes / 2**20:.2f} MiB)"]
        for sid, n in sorted(self.by_scheme().items()):
            lines.append(f"  scheme {sid}: {n} tensors")
        if self.pad_bytes:
            lines.append(f"  flat-padded layout added {self.pad_bytes} "
                         f"pad bytes")
        gaps = [e for e in self.unprotected if e.reason == "unaligned"]
        if gaps:
            lines.append(f"  WARNING: {len(gaps)} weight tensors "
                         f"({self.unprotected_weight_bytes} bytes) left "
                         f"unprotected (unaligned, pad=False):")
            lines.extend(f"    {e.path} ({e.n_weights} elems)" for e in gaps)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the policy
# ---------------------------------------------------------------------------


class ProtectionPolicy:
    """Per-layer protection strategy.

    default_scheme: scheme id applied to every leaf the predicate selects.
    rules:          ordered ``(pattern, scheme_id_or_None)`` pairs; the first
                    regex that matches the leaf's path string wins. A scheme
                    of ``None`` (or ``"none"``) leaves that leaf unprotected.
    predicate:      ``(path, leaf) -> bool`` choosing protectable leaves
                    (default: ``wot.is_protected_weight`` — matmul/conv/
                    embedding weights, not norms or biases).
    pad:            True (default) pads tensors whose last dim is not a
                    multiple of 8 into the flat layout so they are protected
                    anyway; False records them as coverage gaps instead.
    throttle:       apply the WOT projection to the quantized weights before
                    encoding (idempotent on WOT-trained weights; required for
                    the in-place code's correctness).
    backend:        "xla" | "pallas" | a Backend instance — the *default*
                    route for 64-bit-block codec compute.
    backend_rules:  ordered ``(pattern, backend)`` pairs resolved per leaf
                    (first regex matching the leaf's path wins) — one model
                    can mix backends per layer.
    autotune:       an :class:`AutotuneTable` (or a BENCH_kernels.json path)
                    consulted by shape when no backend rule matches; the
                    policy-global ``backend`` stays the final fallback.
    """

    def __init__(self, default_scheme: str = "in-place",
                 rules: Sequence = (),
                 predicate: Optional[Callable] = None,
                 *, pad: bool = True, throttle: bool = True,
                 backend="xla", backend_rules: Sequence = (),
                 autotune=None):
        get_scheme(default_scheme)  # validate eagerly
        self.default_scheme = default_scheme
        self.rules = [(re.compile(pat), sid) for pat, sid in rules]
        for _, sid in self.rules:
            if sid not in (None, "none"):
                get_scheme(sid)
        self.predicate = predicate or wot.is_protected_weight
        self.pad = pad
        self.throttle = throttle
        self.backend = get_backend(backend)
        self.backend_rules = [(re.compile(pat), get_backend(be))
                              for pat, be in backend_rules]
        if isinstance(autotune, (str, bytes)):
            autotune = AutotuneTable.from_json(autotune)
        self.autotune = autotune

    # -- selection -----------------------------------------------------------

    def scheme_for(self, path, leaf) -> Optional[Scheme]:
        """Scheme for one leaf, or None if it stays unprotected."""
        sid, _ = self._plan(path, leaf)
        return get_scheme(sid) if sid is not None else None

    def _plan(self, path, leaf) -> tuple:
        """-> (scheme_id | None, reason)."""
        if not self.predicate(path, leaf):
            return None, "predicate"
        sid = self.default_scheme
        p = path_str(path)
        for pat, rule_sid in self.rules:
            if pat.search(p):
                if rule_sid in (None, "none"):
                    return None, "rule"
                sid = rule_sid
                break
        aligned = leaf.ndim >= 1 and leaf.shape[-1] % BLOCK == 0
        if not aligned and not self.pad:
            return None, "unaligned"
        return sid, ""

    def resolve_backend(self, path: str, shape) -> tuple:
        """Per-leaf backend: first matching backend rule wins, then the
        shape-keyed autotune table, then the policy default.

        -> (Backend, source) with source "rule" | "autotune" | "policy".
        """
        for pat, be in self.backend_rules:
            if pat.search(path):
                return be, "rule"
        if self.autotune is not None:
            best = self.autotune.lookup(shape)
            if best is not None:
                return get_backend(best), "autotune"
        return self.backend, "policy"

    # -- the plan ------------------------------------------------------------

    def plan(self, params, *, mesh=None, param_spec_fn=None):
        """Materialize every per-leaf decision ONCE — see
        :func:`repro.protection.plan.make_plan`.  ``encode_tree`` /
        ``decode_tree`` / ``coverage`` below are thin views over this."""
        from .plan import make_plan
        return make_plan(self, params, mesh=mesh, param_spec_fn=param_spec_fn)

    # -- leaf codec ----------------------------------------------------------

    def encode_leaf(self, w: jnp.ndarray, scheme,
                    backend=None) -> ProtectedTensor:
        """fp weight -> quantize (+WOT throttle) -> scheme-encode."""
        scheme = get_scheme(scheme)
        be = self.backend if backend is None else get_backend(backend)
        scale = quant.compute_scale(w)
        q = jnp.clip(jnp.round(w / scale), -quant.QMAX,
                     quant.QMAX).astype(jnp.int8)
        if self.throttle:
            q = wot.throttle_q(q.reshape(-1)).reshape(w.shape)
        if w.ndim >= 1 and w.shape[-1] % BLOCK == 0:
            q_img = q                         # same-shape layout
        else:
            flat = q.reshape(-1)              # flat-padded layout
            pad = (-flat.shape[0]) % BLOCK
            if pad:
                flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
            q_img = flat
        enc, checks = scheme.encode(q_img, be)
        return ProtectedTensor(enc=enc, checks=checks,
                               scale=scale.astype(jnp.float32),
                               scheme_id=scheme.scheme_id,
                               orig_shape=tuple(w.shape))

    def decode_leaf(self, pt: ProtectedTensor, dtype=jnp.bfloat16):
        return decode_leaf(pt, dtype, backend=self.backend)

    # -- tree codec (views over the plan) ------------------------------------

    def encode_tree(self, params):
        """fp params -> tree with ``ProtectedTensor`` leaves (rest unchanged)."""
        return self.plan(params).encode_tree(params)

    def decode_tree(self, enc_tree, dtype=jnp.bfloat16):
        """Decode with per-leaf backend resolution (rules + autotune)."""
        if not self.backend_rules and self.autotune is None:
            return decode_tree(enc_tree, dtype, backend=self.backend)

        def dec(path, leaf):
            if not is_protected_tensor(leaf):
                return leaf
            be, _ = self.resolve_backend(path_str(path), leaf.orig_shape)
            return decode_leaf(leaf, dtype, backend=be)
        return jax.tree_util.tree_map_with_path(
            dec, enc_tree, is_leaf=is_protected_tensor)

    def coverage(self, params) -> CoverageReport:
        """Report what ``encode_tree`` does, without encoding anything."""
        return self.plan(params).coverage()


# ---------------------------------------------------------------------------
# policy-free tree ops (the scheme id travels inside each ProtectedTensor)
# ---------------------------------------------------------------------------


def decode_leaf(pt: ProtectedTensor, dtype=jnp.bfloat16, *, backend="xla"):
    """ProtectedTensor -> dequantized weight tensor (faults corrected)."""
    scheme = get_scheme(pt.scheme_id)
    q = scheme.decode(pt.enc, pt.checks, get_backend(backend))
    if pt.is_flat:
        q = q.reshape(-1)[: pt.n_weights].reshape(pt.orig_shape)
    return (q.astype(jnp.float32) * pt.scale).astype(dtype)


def decode_leaf_with_flags(pt: ProtectedTensor, dtype=jnp.bfloat16, *,
                           backend="xla"):
    """:func:`decode_leaf` plus fault accounting — returns
    ``(weight, corrected, due)`` with int32 scalar counts of repaired and
    detected-uncorrectable (double) errors in this leaf's stored image."""
    scheme = get_scheme(pt.scheme_id)
    q, corrected, due = scheme.decode_with_flags(pt.enc, pt.checks,
                                                 get_backend(backend))
    if pt.is_flat:
        q = q.reshape(-1)[: pt.n_weights].reshape(pt.orig_shape)
    return (q.astype(jnp.float32) * pt.scale).astype(dtype), corrected, due


def decode_tree_with_flags(enc_tree, dtype=jnp.bfloat16, *, backend="xla"):
    """Decode every ProtectedTensor leaf and aggregate fault flags:
    returns ``(decoded_tree, {path: (corrected, due)})`` — the per-leaf
    accounting that fault campaigns sum into DUE curves."""
    be = get_backend(backend)
    flags: dict = {}

    def dec(path, leaf):
        if not is_protected_tensor(leaf):
            return leaf
        w, corrected, due = decode_leaf_with_flags(leaf, dtype, backend=be)
        flags[path_str(path)] = (corrected, due)
        return w

    out = jax.tree_util.tree_map_with_path(dec, enc_tree,
                                           is_leaf=is_protected_tensor)
    return out, flags


def decode_tree(enc_tree, dtype=jnp.bfloat16, *, backend="xla"):
    """Decode every ProtectedTensor leaf; other leaves pass through."""
    be = get_backend(backend)
    return jax.tree.map(
        lambda x: decode_leaf(x, dtype, backend=be)
        if is_protected_tensor(x) else x,
        enc_tree, is_leaf=is_protected_tensor)


def inject_tree(enc_tree, rate: float, seed: int):
    """Host-side memory-fault injection: flip random bits across each leaf's
    full stored image (weight bytes AND check bytes — DRAM faults hit ECC
    bits too). Matches the paper's §5.3 fault model."""
    i = 0

    def inj(pt):
        nonlocal i
        if not is_protected_tensor(pt):
            return pt
        i += 1
        enc = np.asarray(pt.enc).reshape(-1)
        if pt.checks is not None:
            checks = np.asarray(pt.checks).reshape(-1)
            image = faults.inject(np.concatenate([enc, checks]), rate, seed + i)
            new_enc = image[: enc.size].reshape(pt.enc.shape)
            new_checks = image[enc.size:].reshape(pt.checks.shape)
            return dataclasses.replace(pt, enc=jnp.asarray(new_enc),
                                       checks=jnp.asarray(new_checks))
        flipped = faults.inject(enc, rate, seed + i).reshape(pt.enc.shape)
        return dataclasses.replace(pt, enc=jnp.asarray(flipped))

    return jax.tree.map(inj, enc_tree, is_leaf=is_protected_tensor)


def inject_tree_device(enc_tree, rate, key, *, max_rate=None):
    """Jit-safe on-device injection (``faults.inject_jax`` per leaf image).

    With ``max_rate=None`` (default) ``rate`` must be a static Python float.
    Passing ``max_rate`` switches to ``faults.inject_jax_rate``: the per-leaf
    sample budget is fixed by ``max_rate`` and ``rate`` may then be a traced
    scalar — the mechanism compiled fault campaigns use to sweep the whole
    rate grid inside one program.
    """
    if max_rate is None:
        inj = lambda image, k: faults.inject_jax(image, rate, k)
    else:
        inj = lambda image, k: faults.inject_jax_rate(image, rate, k, max_rate)
    leaves, treedef = jax.tree_util.tree_flatten(
        enc_tree, is_leaf=is_protected_tensor)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for k, pt in zip(keys, leaves):
        if not is_protected_tensor(pt):
            out.append(pt)
            continue
        enc = pt.enc.reshape(-1)
        if pt.checks is not None:
            n = enc.shape[0]
            image = jnp.concatenate([enc, pt.checks.reshape(-1)])
            image = inj(image, k)
            pt = dataclasses.replace(
                pt, enc=image[:n].reshape(pt.enc.shape),
                checks=image[n:].reshape(pt.checks.shape))
        else:
            pt = dataclasses.replace(
                pt, enc=inj(enc, k).reshape(pt.enc.shape))
        out.append(pt)
    return jax.tree_util.tree_unflatten(treedef, out)


def spec_tree(enc_tree, param_spec_fn, *, mesh=None):
    """Sharding specs for an encoded tree: a same-shape image inherits the
    weight's spec byte-for-byte; check bytes and scales are replicated.
    Flat-padded images replicate by default; with ``mesh`` they get the
    1-D block-aligned sharded spec (see ``plan._flat_spec``) — prefer
    building a :class:`~repro.protection.plan.ProtectionPlan`, which
    materializes these specs once per leaf."""
    from jax.sharding import PartitionSpec as P

    from .plan import _flat_spec, _mesh_sizes

    sizes = _mesh_sizes(mesh)

    def spec(path, leaf):
        if is_protected_tensor(leaf):
            enc_spec = (_flat_spec(int(leaf.enc.shape[0]), sizes)
                        if leaf.is_flat else param_spec_fn(path, leaf.enc))
            checks_spec = None if leaf.checks is None else P()
            return ProtectedTensor(enc=enc_spec, checks=checks_spec,
                                   scale=P(), scheme_id=leaf.scheme_id,
                                   orig_shape=tuple(leaf.orig_shape))
        return param_spec_fn(path, leaf)

    return jax.tree_util.tree_map_with_path(spec, enc_tree,
                                            is_leaf=is_protected_tensor)


def space_overhead(enc_tree) -> float:
    """(stored - weight) / weight bytes over all protected leaves."""
    stored = weights = 0
    for leaf in jax.tree_util.tree_leaves(enc_tree,
                                          is_leaf=is_protected_tensor):
        if is_protected_tensor(leaf):
            stored += leaf.stored_bytes
            weights += leaf.n_weights
    return (stored - weights) / max(weights, 1)
