"""Backend dispatch: route codec compute through XLA or Pallas.

Every scheme op that touches 64-bit ECC blocks goes through a ``Backend``
object, selected by a single ``backend=`` switch anywhere in the public API:

* ``"xla"``    — the pure-jnp reference path (``core.ecc`` / ``kernels.ref``).
  Works everywhere, fuses into the surrounding XLA program; this is what the
  decode-on-read serving path compiles today.
* ``"pallas"`` — the fused TPU kernels (``kernels/ops.py``): tiled VMEM
  decode/encode and the decode+matmul ``ecc_qmatmul``. ``interpret=True`` by
  default so the same switch validates on CPU; pass
  ``get_backend("pallas", interpret=False)`` on real TPU.

Backends only differ for the in-place (64,57,1) code — parity/secded72 have
no Pallas kernels and always take the jnp path inside their schemes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ecc

__all__ = ["Backend", "XlaBackend", "PallasBackend", "get_backend",
           "BACKENDS"]


class Backend:
    """Interface: in-place-code block ops + the fused protected matmul."""

    name = "abstract"

    def encode64(self, blocks: jnp.ndarray) -> jnp.ndarray:
        """(..., 8) uint8 WOT-compliant bytes -> encoded (..., 8)."""
        raise NotImplementedError

    def decode64(self, blocks: jnp.ndarray):
        """(..., 8) uint8 encoded -> (decoded (..., 8), single, double)."""
        raise NotImplementedError

    def qmatmul(self, a_q: jnp.ndarray, w_enc: jnp.ndarray, a_scale,
                w_scale) -> jnp.ndarray:
        """a_q (M,K) int8 @ decode(w_enc (K,N) uint8) * scales -> (M,N) f32."""
        raise NotImplementedError


class XlaBackend(Backend):
    name = "xla"

    def encode64(self, blocks):
        return ecc.encode64(blocks)

    def decode64(self, blocks):
        return ecc.decode64(blocks)

    def qmatmul(self, a_q, w_enc, a_scale, w_scale):
        from repro.kernels import ref
        acc = ref.ecc_qmatmul_ref(a_q, w_enc)
        return acc.astype(jnp.float32) * (a_scale * w_scale)


class PallasBackend(Backend):
    """Tiled VMEM kernels. Arbitrary block shapes are handled by flattening
    to (nblk, 8) and zero-padding nblk up to a tile multiple (a zero block
    has syndrome 0, so padding decodes/encodes to itself)."""

    name = "pallas"

    def __init__(self, *, interpret: bool = True, blk_n: int = 4096):
        self.interpret = interpret
        self.blk_n = blk_n

    def _tile_pad(self, blocks2d: jnp.ndarray) -> tuple[jnp.ndarray, int]:
        nblk = blocks2d.shape[0]
        if nblk <= self.blk_n:
            return blocks2d, nblk
        pad = (-nblk) % self.blk_n
        if pad:
            blocks2d = jnp.concatenate(
                [blocks2d, jnp.zeros((pad, 8), blocks2d.dtype)])
        return blocks2d, nblk

    def encode64(self, blocks):
        from repro.kernels import ecc_encode
        shape = blocks.shape
        b2, nblk = self._tile_pad(blocks.astype(jnp.uint8).reshape(-1, 8))
        out = ecc_encode.ecc_encode(b2, blk_n=min(self.blk_n, b2.shape[0]),
                                    interpret=self.interpret)
        return out[:nblk].reshape(shape)

    def decode64(self, blocks):
        from repro.kernels import ecc_decode
        shape = blocks.shape
        b2, nblk = self._tile_pad(blocks.astype(jnp.uint8).reshape(-1, 8))
        dec, flags = ecc_decode.ecc_decode(
            b2, blk_n=min(self.blk_n, b2.shape[0]), interpret=self.interpret)
        dec = dec[:nblk].reshape(shape)
        flags = flags[:nblk].reshape(shape[:-1])
        single = (flags & 1) == 1
        double = (flags & 2) == 2
        return dec, single, double

    def qmatmul(self, a_q, w_enc, a_scale, w_scale):
        from repro.kernels import ops
        return ops.qmatmul_protected(a_q, w_enc, a_scale, w_scale,
                                     interpret=self.interpret)


BACKENDS = {"xla": XlaBackend, "pallas": PallasBackend}


def get_backend(backend, **kw) -> Backend:
    """Resolve a backend name or pass an instance through."""
    if isinstance(backend, Backend):
        return backend
    if backend is None:
        backend = "xla"
    try:
        return BACKENDS[backend](**kw)
    except KeyError:
        raise ValueError(
            f"unknown backend {backend!r}; one of {sorted(BACKENDS)}") from None
