"""Backend dispatch: route codec compute through XLA or Pallas.

Every scheme op that touches 64-bit ECC blocks goes through a ``Backend``
object, selected by a single ``backend=`` switch anywhere in the public API:

* ``"xla"``    — the pure-jnp reference path (``core.ecc`` / ``kernels.ref``).
  Works everywhere, fuses into the surrounding XLA program; this is what the
  decode-on-read serving path compiles today.
* ``"pallas"`` — the fused TPU kernels (``kernels/ops.py``): tiled VMEM
  decode/encode and the decode+matmul ``ecc_qmatmul``. ``interpret=True`` by
  default so the same switch validates on CPU; pass
  ``get_backend("pallas", interpret=False)`` on real TPU.

Backends only differ for the in-place (64,57,1) code — parity/secded72 have
no Pallas kernels and always take the jnp path inside their schemes.
"""
from __future__ import annotations

import json
import math

import jax
import jax.numpy as jnp

from repro.core import ecc

__all__ = ["Backend", "XlaBackend", "PallasBackend", "get_backend",
           "BACKENDS", "AutotuneTable", "BENCH_KERNELS_SCHEMA",
           "BENCH_KERNELS_SCHEMA_V1", "BENCH_KERNELS_SCHEMA_V2",
           "BENCH_KERNELS_SCHEMA_V3", "BENCH_KERNELS_SCHEMA_V4",
           "BENCH_KERNELS_SCHEMA_V5"]


class Backend:
    """Interface: in-place-code block ops + the fused protected matmul."""

    name = "abstract"

    def encode64(self, blocks: jnp.ndarray) -> jnp.ndarray:
        """(..., 8) uint8 WOT-compliant bytes -> encoded (..., 8)."""
        raise NotImplementedError

    def decode64(self, blocks: jnp.ndarray):
        """(..., 8) uint8 encoded -> (decoded (..., 8), single, double)."""
        raise NotImplementedError

    def qmatmul(self, a_q: jnp.ndarray, w_enc: jnp.ndarray, a_scale,
                w_scale) -> jnp.ndarray:
        """a_q (M,K) int8 @ decode(w_enc (K,N) uint8) * scales -> (M,N) f32."""
        raise NotImplementedError


class XlaBackend(Backend):
    name = "xla"

    def encode64(self, blocks):
        return ecc.encode64(blocks)

    def decode64(self, blocks):
        return ecc.decode64(blocks)

    def qmatmul(self, a_q, w_enc, a_scale, w_scale):
        from repro.kernels import ref
        acc = ref.ecc_qmatmul_ref(a_q, w_enc)
        return acc.astype(jnp.float32) * (a_scale * w_scale)


class PallasBackend(Backend):
    """Tiled VMEM kernels. Arbitrary block shapes are handled by flattening
    to (nblk, 8) and zero-padding nblk up to a tile multiple (a zero block
    has syndrome 0, so padding decodes/encodes to itself)."""

    name = "pallas"

    def __init__(self, *, interpret: bool = True, blk_n: int = 4096):
        self.interpret = interpret
        self.blk_n = blk_n

    def _tile_pad(self, blocks2d: jnp.ndarray) -> tuple[jnp.ndarray, int]:
        nblk = blocks2d.shape[0]
        if nblk <= self.blk_n:
            return blocks2d, nblk
        pad = (-nblk) % self.blk_n
        if pad:
            blocks2d = jnp.concatenate(
                [blocks2d, jnp.zeros((pad, 8), blocks2d.dtype)])
        return blocks2d, nblk

    def encode64(self, blocks):
        from repro.kernels import ecc_encode
        shape = blocks.shape
        b2, nblk = self._tile_pad(blocks.astype(jnp.uint8).reshape(-1, 8))
        out = ecc_encode.ecc_encode(b2, blk_n=min(self.blk_n, b2.shape[0]),
                                    interpret=self.interpret)
        return out[:nblk].reshape(shape)

    def decode64(self, blocks):
        from repro.kernels import ecc_decode
        shape = blocks.shape
        b2, nblk = self._tile_pad(blocks.astype(jnp.uint8).reshape(-1, 8))
        dec, flags = ecc_decode.ecc_decode(
            b2, blk_n=min(self.blk_n, b2.shape[0]), interpret=self.interpret)
        dec = dec[:nblk].reshape(shape)
        flags = flags[:nblk].reshape(shape[:-1])
        single = (flags & 1) == 1
        double = (flags & 2) == 2
        return dec, single, double

    def qmatmul(self, a_q, w_enc, a_scale, w_scale):
        from repro.kernels import ops
        return ops.qmatmul_protected(a_q, w_enc, a_scale, w_scale,
                                     interpret=self.interpret)


BACKENDS = {"xla": XlaBackend, "pallas": PallasBackend}

BENCH_KERNELS_SCHEMA_V1 = "bench_kernels/v1"
BENCH_KERNELS_SCHEMA_V2 = "bench_kernels/v2"
BENCH_KERNELS_SCHEMA_V3 = "bench_kernels/v3"
BENCH_KERNELS_SCHEMA_V4 = "bench_kernels/v4"
BENCH_KERNELS_SCHEMA_V5 = "bench_kernels/v5"
BENCH_KERNELS_SCHEMA = "bench_kernels/v6"


class AutotuneTable:
    """Shape-keyed backend + tile choice, fed by
    ``benchmarks/kernel_bench.py``.

    Each entry is ``{"shape": [...], "nblocks": int, "xla_us": float,
    "pallas_us": float, "best": "xla"|"pallas"}``; ``bench_kernels/v2``
    entries additionally carry ``"tiles": [bm, bn, bk]`` (the fused
    decode+matmul kernel's best tile sweep result for that shape) and
    ``"fused_us"``; ``bench_kernels/v3`` entries add the int8-epilogue rows
    ``"int8_tiles": [bm, bn, 0]`` and ``"fused_int8_us"`` (the quantized
    serving path — the epilogue always runs full-K tiles, so bk is 0).
    ``bench_kernels/v4`` artifacts additionally carry a top-level
    ``"attention"`` list: fused page-attention (decode-at-use over the
    protected KV cache) vs decode-then-attend reference timings per
    ``(batch, seq, kv_heads, head_dim)`` shape and KV scheme — surfaced on
    :attr:`attention` for reporting, not consulted by the lookups.
    ``bench_kernels/v5`` adds the long-context rows: a top-level
    ``"attention_long"`` list (page-chunked online-softmax kernel vs the
    whole-strip kernel per sequence length, with each length's strip-VMEM
    footprint and chunked-vs-fp64-oracle error) and ``"crossover"`` (the
    structural strip-VMEM crossover: the first sequence length whose
    gathered strip no longer fits the per-core VMEM budget, where the
    chunked kernel becomes the only honest route). ``bench_kernels/v6``
    entries add the ABFT overhead rows ``"fused_abft_us"`` and
    ``"fused_int8_abft_us"``: the same winning tiles re-timed with
    in-kernel checksum verification on (see docs/abft.md) — reporting
    only, the lookups never consult them. v1–v5 artifacts still load —
    their entries simply have no (int8) tile opinion, no ABFT timings,
    and empty :attr:`attention` / :attr:`attention_long`.

    :meth:`lookup` (backend choice) resolves an exact shape match first,
    then the nearest entry by 64-bit-block count within a 4x factor, else
    ``None`` — so the policy's default backend still decides for shapes the
    benchmark never measured. :meth:`lookup_tiles` /
    :meth:`lookup_int8_tiles` are softer: tiles are a hint, not a route, so
    past the exact match they fall back to the nearest tile-bearing entry by
    block count with NO ratio cap (the old behaviour silently used the
    kernel's hardcoded defaults instead); :meth:`lookup_tiles_src` also
    reports where the answer came from (``"exact"`` | ``"nearest"`` | ``""``)
    so plans can surface extrapolated tile choices.
    """

    def __init__(self, entries=(), *, platform: str = "", source: str = "",
                 schema: str = BENCH_KERNELS_SCHEMA, attention=(),
                 attention_long=(), crossover=None):
        self.attention = [dict(a) for a in attention]
        self.attention_long = [dict(a) for a in attention_long]
        self.crossover = dict(crossover) if crossover else None
        self.entries = []
        for e in entries:
            e = dict(e)
            shape = tuple(int(s) for s in e.get("shape", ()))
            if e.get("best") not in BACKENDS:
                raise ValueError(f"autotune entry for shape {shape} has "
                                 f"unknown best backend {e.get('best')!r}")
            e["shape"] = shape
            e.setdefault("nblocks",
                         int(math.prod(shape)) // 8 if shape else 0)
            for key in ("tiles", "int8_tiles"):
                if e.get(key) is not None:
                    e[key] = tuple(int(t) for t in e[key])
            self.entries.append(e)
        self.platform = platform
        self.source = source
        self.schema = schema
        self._by_shape = {e["shape"]: e for e in self.entries}

    def __len__(self) -> int:
        return len(self.entries)

    def _nearest(self, shape) -> dict | None:
        """Exact shape entry, else nearest by block count within 4x."""
        shape = tuple(int(s) for s in shape)
        hit = self._by_shape.get(shape)
        if hit is not None:
            return hit
        nblk = int(math.prod(shape)) // 8 if shape else 0
        if nblk <= 0 or not self.entries:
            return None
        nearest = min(self.entries,
                      key=lambda e: abs(math.log(max(e["nblocks"], 1) / nblk)))
        ratio = max(nearest["nblocks"], 1) / nblk
        if ratio > 4 or ratio < 0.25:
            return None
        return nearest

    def lookup(self, shape) -> str | None:
        """Best backend name for a weight shape, or None when the table has
        nothing close enough to say."""
        e = self._nearest(shape)
        return e["best"] if e is not None else None

    def lookup_tiles_src(self, shape, *, key: str = "tiles") -> tuple:
        """-> ``(tiles | None, source)`` for a weight shape, with source
        ``"exact"`` (shape match), ``"nearest"`` (nearest tile-bearing entry
        by block count — tiles extrapolate, unlike backend choices, so no
        ratio cap), or ``""`` (no entry carries this tile key at all)."""
        shape = tuple(int(s) for s in shape)
        hit = self._by_shape.get(shape)
        if hit is not None and hit.get(key):
            return tuple(hit[key]), "exact"
        with_tiles = [e for e in self.entries if e.get(key)]
        nblk = int(math.prod(shape)) // 8 if shape else 0
        if nblk <= 0 or not with_tiles:
            return None, ""
        nearest = min(with_tiles,
                      key=lambda e: abs(math.log(max(e["nblocks"], 1) / nblk)))
        return tuple(nearest[key]), "nearest"

    def lookup_tiles(self, shape) -> tuple | None:
        """Best fused-kernel (bm, bn, bk) for a weight shape — exact match
        or nearest tile-bearing entry; None only when no entry has tiles
        (a v1 artifact)."""
        return self.lookup_tiles_src(shape)[0]

    def lookup_int8_tiles(self, shape) -> tuple | None:
        """Best int8-epilogue (bm, bn, 0) tiles — same resolution as
        :meth:`lookup_tiles`; None for pre-v3 artifacts."""
        return self.lookup_tiles_src(shape, key="int8_tiles")[0]

    def to_dict(self) -> dict:
        d = {"schema": self.schema, "platform": self.platform,
             "entries": [{**e, "shape": list(e["shape"]),
                          **{k: list(e[k]) for k in
                             ("tiles", "int8_tiles") if e.get(k)}}
                         for e in self.entries]}
        if self.attention:
            d["attention"] = [dict(a) for a in self.attention]
        if self.attention_long:
            d["attention_long"] = [dict(a) for a in self.attention_long]
        if self.crossover:
            d["crossover"] = dict(self.crossover)
        return d

    @classmethod
    def from_dict(cls, d: dict, *, source: str = "") -> "AutotuneTable":
        schema = d.get("schema", "")
        known = (BENCH_KERNELS_SCHEMA, BENCH_KERNELS_SCHEMA_V5,
                 BENCH_KERNELS_SCHEMA_V4, BENCH_KERNELS_SCHEMA_V3,
                 BENCH_KERNELS_SCHEMA_V2, BENCH_KERNELS_SCHEMA_V1)
        if schema and schema not in known:
            raise ValueError(
                f"unsupported autotune schema {schema!r} (expected one of "
                f"{known})")
        return cls(d.get("entries", ()), platform=d.get("platform", ""),
                   source=source, schema=schema or BENCH_KERNELS_SCHEMA_V1,
                   attention=d.get("attention", ()),
                   attention_long=d.get("attention_long", ()),
                   crossover=d.get("crossover"))

    @classmethod
    def from_json(cls, path) -> "AutotuneTable":
        with open(path) as f:
            return cls.from_dict(json.load(f), source=str(path))


def get_backend(backend, **kw) -> Backend:
    """Resolve a backend name or pass an instance through."""
    if isinstance(backend, Backend):
        return backend
    if backend is None:
        backend = "xla"
    try:
        return BACKENDS[backend](**kw)
    except KeyError:
        raise ValueError(
            f"unknown backend {backend!r}; one of {sorted(BACKENDS)}") from None
