"""Compiled on-device fault campaigns — the paper's Table 2 at device speed.

The host pipeline re-encodes and re-injects per (scheme, rate, trial), so a
4-scheme x 5-rate x 5-trial grid is ~100 serial host round-trips.  A
*campaign* instead encodes the model **once**, then runs the whole
(trial x rate) grid of inject -> decode -> eval inside **one compiled
program**:

* the fault rate is a *traced* scalar: every leaf samples a fixed budget of
  ``n_faults(bits, max(rates))`` candidate bit positions and keeps the first
  ``round(bits * rate)`` (``core.faults.inject_jax_rate``), so one program
  shape covers every rate in the sweep;
* ``batch="vmap"`` lays the full grid out as two nested ``vmap`` axes
  (fastest; peak memory ~ grid-size x the per-cell parity vectors);
  ``batch="scan"`` runs the same cells sequentially under ``lax.scan``
  (constant memory; use for big models or large trial counts);
* exactly **one** jit compile happens per campaign (AOT ``lower().compile()``
  — the compile time is reported separately from the sweep wall-clock).

The host path (``protection.inject_tree`` + ``host.run_fault_trial``) stays
as the cross-check oracle: :func:`run_campaign_host` runs the identical grid
through it, and the test suite asserts statistical parity between the two.
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .policy import (ProtectionPolicy, decode_leaf, decode_tree,
                     decode_tree_with_flags, inject_tree,
                     inject_tree_device, path_str, space_overhead)
from .tensor import is_protected_tensor

__all__ = ["CampaignResult", "run_campaign", "run_campaign_host",
           "fidelity_campaign", "due_campaign", "compute_campaign",
           "accuracy_eval", "fidelity_eval", "due_eval"]

RATES = (1e-6, 1e-5, 1e-4, 1e-3, 3e-3)


# ---------------------------------------------------------------------------
# result carrier
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CampaignResult:
    """One campaign = one (model, policy) over a (rate x trial) grid.

    ``grid[r][t]`` is the raw metric value (accuracy or decode fidelity) of
    trial ``t`` at ``rates[r]``; ``clean`` is the same metric with zero
    faults.  Derived per-rate mean/std/drop views are computed, not stored,
    so the JSON round-trip stays lossless.
    """

    scheme: str                # scheme id(s) of the policy under test
    metric: str                # "accuracy" | "fidelity"
    rates: tuple               # swept fault rates
    trials: int
    clean: float               # metric at rate 0 (no injection)
    grid: tuple                # (len(rates), trials) nested tuples of float
    space_overhead: float      # (stored - weight) / weight bytes
    compile_s: float           # one-off jit compile time (0.0 for host)
    wall_clock_s: float        # grid execution time, compile excluded
    batch: str                 # "vmap" | "scan" | "host"
    backend: str               # protection backend ("xla" | "pallas")
    platform: str              # jax device platform ("cpu", "tpu", ...)
    device: str                # jax device kind string
    target: str = "weights"    # what the faults hit: "weights" | "kv" |
    #                            "both" | "compute" (ABFT campaign)
    layer_rows: tuple = ()     # (n_layers, 2) per-layer KV (corrected, due)
    #                            at max(rates) — () unless target covers KV
    coverage_rows: tuple = ()  # per-leaf (path, detected, injected) at
    #                            max(rates) — compute campaigns only

    # -- derived views -------------------------------------------------------

    def mean(self) -> tuple:
        """Per-rate mean metric across trials."""
        return tuple(float(np.mean(row)) for row in self.grid)

    def std(self) -> tuple:
        """Per-rate metric std across trials."""
        return tuple(float(np.std(row)) for row in self.grid)

    def drop(self) -> tuple:
        """Per-rate mean metric drop vs clean (the Table-2 cell value)."""
        return tuple(self.clean - m for m in self.mean())

    def row(self) -> list:
        """Table-2 row format: ``[(mean_drop, std), ...]`` per rate."""
        return list(zip(self.drop(), self.std()))

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["rates"] = list(self.rates)
        d["grid"] = [list(row) for row in self.grid]
        d["layer_rows"] = [list(row) for row in self.layer_rows]
        d["coverage_rows"] = [list(row) for row in self.coverage_rows]
        d["derived"] = {"mean": list(self.mean()), "std": list(self.std()),
                        "drop": list(self.drop())}
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CampaignResult":
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        kw["rates"] = tuple(kw["rates"])
        kw["grid"] = tuple(tuple(row) for row in kw["grid"])
        kw["layer_rows"] = tuple(tuple(int(v) for v in row)
                                 for row in kw.get("layer_rows", ()))
        kw["coverage_rows"] = tuple(
            (str(p), int(det), int(inj))
            for p, det, inj in kw.get("coverage_rows", ()))
        return cls(**kw)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), indent=kw.pop("indent", 2), **kw)

    @classmethod
    def from_json(cls, s: str) -> "CampaignResult":
        return cls.from_dict(json.loads(s))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "CampaignResult":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# eval metrics
# ---------------------------------------------------------------------------


def accuracy_eval(fwd, batch):
    """Metric: top-1 accuracy of ``fwd(decoded_params, images)`` on a fixed
    eval batch (the Table-2 metric)."""
    images = jnp.asarray(batch["images"])
    labels = jnp.asarray(batch["labels"])

    def ev(dec_params):
        lg = fwd(dec_params, images)
        return jnp.mean((jnp.argmax(lg, -1) == labels).astype(jnp.float32))

    return ev


def fidelity_eval(enc_tree, backend="xla"):
    """Metric: fraction of *protected* weight values that decode identically
    to the fault-free decode.  Label-free, so it works for any model (the
    serving smoke-check uses it on LM weights)."""
    enc_leaves = jax.tree_util.tree_flatten(
        enc_tree, is_leaf=is_protected_tensor)[0]
    prot_idx = [i for i, l in enumerate(enc_leaves) if is_protected_tensor(l)]
    if not prot_idx:
        raise ValueError("fidelity_eval: the tree has no protected leaves "
                         "(did the policy's predicate select anything?)")
    clean = [decode_leaf(enc_leaves[i], jnp.float32, backend=backend)
             for i in prot_idx]
    total = sum(int(np.prod(c.shape)) for c in clean)

    def ev(dec_params):
        leaves = jax.tree_util.tree_leaves(dec_params)
        eq = sum(jnp.sum(leaves[i] == c) for i, c in zip(prot_idx, clean))
        return eq.astype(jnp.float32) / max(total, 1)

    return ev


def due_eval(backend="xla", *, what="due"):
    """Metric over the ENCODED tree: total detected-uncorrectable (double)
    errors — the per-leaf flags the decode-at-use serve step surfaces,
    summed at campaign scale (``what="corrected"`` counts repairs instead).
    """
    idx = {"corrected": 0, "due": 1}[what]

    def ev(enc_tree):
        _, flags = decode_tree_with_flags(enc_tree, jnp.float32,
                                          backend=backend)
        total = jnp.zeros((), jnp.int32)
        for pair in flags.values():
            total = total + pair[idx]
        return total.astype(jnp.float32)

    ev.wants_encoded = True
    return ev


# ---------------------------------------------------------------------------
# the compiled grid
# ---------------------------------------------------------------------------


def _scheme_label(enc_tree) -> str:
    sids = sorted({l.scheme_id for l in jax.tree_util.tree_leaves(
        enc_tree, is_leaf=is_protected_tensor) if is_protected_tensor(l)})
    return "+".join(sids) if sids else "none"


def _is_encoded(tree) -> bool:
    return any(is_protected_tensor(l) for l in jax.tree_util.tree_leaves(
        tree, is_leaf=is_protected_tensor))


def _run_grid(enc, eval_fn, rates, trials, key, batch, backend, metric):
    """Shared engine: compile one program for the whole (rate x trial) grid,
    execute it, and wrap everything into a :class:`CampaignResult`."""
    if batch not in ("vmap", "scan"):
        raise ValueError(f"batch must be 'vmap' or 'scan', got {batch!r}")
    rates = tuple(float(r) for r in rates)
    max_rate = max(rates) if rates else 0.0
    n_rates = len(rates)

    # eval fns tagged wants_encoded consume the (dirty) encoded tree itself
    # (e.g. the DUE-flags metric); everything else sees the decoded params
    wants_enc = getattr(eval_fn, "wants_encoded", False)
    clean = float(eval_fn(enc) if wants_enc else
                  eval_fn(decode_tree(enc, jnp.float32, backend=backend)))

    def cell(enc_tree, rate, k):
        dirty = inject_tree_device(enc_tree, rate, k, max_rate=max_rate)
        if wants_enc:
            return eval_fn(dirty)
        return eval_fn(decode_tree(dirty, jnp.float32, backend=backend))

    if batch == "vmap":
        def grid(enc_tree, rates_v, keys_v):
            per_rate = jax.vmap(cell, in_axes=(None, None, 0))   # trials
            return jax.vmap(per_rate, in_axes=(None, 0, 0))(     # rates
                enc_tree, rates_v, keys_v)
    else:
        def grid(enc_tree, rates_v, keys_v):
            flat_r = jnp.repeat(rates_v, trials)
            flat_k = keys_v.reshape((n_rates * trials,) + keys_v.shape[2:])

            def step(carry, rk):
                r, k = rk
                return carry, cell(enc_tree, r, k)

            _, out = jax.lax.scan(step, (), (flat_r, flat_k))
            return out.reshape(n_rates, trials)

    rates_arr = jnp.asarray(rates, jnp.float32)
    keys = jax.random.split(key, max(n_rates * trials, 1))
    keys = keys[: n_rates * trials].reshape((n_rates, trials) + keys.shape[1:])

    t0 = time.perf_counter()
    compiled = jax.jit(grid).lower(enc, rates_arr, keys).compile()
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = np.asarray(jax.block_until_ready(compiled(enc, rates_arr, keys)))
    wall = time.perf_counter() - t0

    dev = jax.devices()[0]
    be = getattr(backend, "name", str(backend))
    return CampaignResult(
        scheme=_scheme_label(enc), metric=metric, rates=rates, trials=trials,
        clean=clean, grid=tuple(tuple(float(v) for v in row) for row in out),
        space_overhead=float(space_overhead(enc)), compile_s=compile_s,
        wall_clock_s=wall, batch=batch, backend=be, platform=dev.platform,
        device=getattr(dev, "device_kind", dev.platform))


def _as_policy(policy) -> ProtectionPolicy:
    if isinstance(policy, ProtectionPolicy):
        return policy
    return ProtectionPolicy(default_scheme=policy,
                            predicate=lambda p, l: getattr(l, "ndim", 0) >= 2)


def _default_eval(fwd, tmpl, *, n_classes, img, eval_batch, eval_seed):
    from repro.data import synthetic
    b, _ = synthetic.image_batch(n_classes, eval_batch, img, seed=eval_seed,
                                 step=0, templates=tmpl)
    return accuracy_eval(fwd, b)


def run_campaign(params, fwd, tmpl, policy, rates=RATES, trials=5, key=None,
                 batch="vmap", *, eval_fn=None, eval_batch=256, n_classes=4,
                 img=32, eval_seed=777) -> CampaignResult:
    """Encode once, then sweep the full (trial x rate) fault grid on device.

    params:  fp32 parameter tree (encoded here under ``policy``).
    fwd:     ``fwd(decoded_params, images) -> logits`` (pass any input
             normalization inside); ignored when ``eval_fn`` is given.
    tmpl:    synthetic-data class templates for the eval batch (None draws
             fresh ones from ``eval_seed``); ignored when ``eval_fn`` given.
    policy:  a ``ProtectionPolicy`` or a scheme id (which gets the paper's
             eval policy: every >=2-D tensor protected).
    batch:   "vmap" (parallel grid, fastest) or "scan" (sequential,
             constant memory).
    eval_fn: optional ``(decoded_tree) -> scalar`` metric override.

    Returns a :class:`CampaignResult`; exactly one jit compile happens.
    """
    policy = _as_policy(policy)
    key = jax.random.PRNGKey(0) if key is None else key
    enc = policy.encode_tree(params)
    if eval_fn is None:
        eval_fn = _default_eval(fwd, tmpl, n_classes=n_classes, img=img,
                                eval_batch=eval_batch, eval_seed=eval_seed)
        metric = "accuracy"
    else:
        metric = "custom"
    return _run_grid(enc, eval_fn, rates, trials, key, batch, policy.backend,
                     metric)


def fidelity_campaign(tree, policy=None, rates=(1e-4,), trials=2, key=None,
                      batch="vmap") -> CampaignResult:
    """Label-free campaign: metric = decode fidelity vs the clean decode.

    ``tree`` may be raw fp32 params (encoded here under ``policy``) or an
    already-encoded tree (``policy`` then only supplies the backend).  This
    is the serving fault smoke-check: it answers "at rate r, what fraction
    of my resident weights still decode correctly?" without needing labels.
    """
    policy = _as_policy(policy if policy is not None else "in-place")
    key = jax.random.PRNGKey(0) if key is None else key
    enc = tree if _is_encoded(tree) else policy.encode_tree(tree)
    eval_fn = fidelity_eval(enc, backend=policy.backend)
    res = _run_grid(enc, eval_fn, rates, trials, key, batch, policy.backend,
                    "fidelity")
    return res


def due_campaign(tree, policy=None, rates=(1e-4,), trials=2, key=None,
                 batch="vmap", *, what="due", target="weights",
                 kv_tree=None) -> CampaignResult:
    """Fault-accounting campaign: metric = total detected-uncorrectable
    (double-error, DUE) count across protected leaves per cell — the same
    per-leaf flags the decode-at-use serve step reports per layer, swept
    over the (rate x trial) grid in one compiled program.  At the paper's
    fault model the in-place (64,57,1) code corrects all singles, so the DUE
    curve is exactly the residual risk curve; ``what="corrected"`` sweeps
    the repair counts instead.

    ``target`` picks what the faults hit: "weights" (default, ``tree``),
    "kv" (a paged KV cache's ProtectedTensor pools — build ``kv_tree`` with
    :func:`repro.serving.kvcache.as_protected_tree`), or "both" (one grid
    over the combined state).  When the target covers KV, the result also
    carries ``layer_rows``: per-layer (corrected, DUE) counts from one
    representative injection at ``max(rates)`` — the serving-state analogue
    of the per-layer weight flags."""
    if target not in ("weights", "kv", "both"):
        raise ValueError(f"target {target!r}; one of "
                         f"('weights', 'kv', 'both')")
    if target != "weights" and kv_tree is None:
        raise ValueError(f"target={target!r} needs kv_tree (see "
                         f"repro.serving.kvcache.as_protected_tree)")
    policy = _as_policy(policy if policy is not None else "in-place")
    key = jax.random.PRNGKey(0) if key is None else key
    if target == "kv":
        enc = kv_tree
    else:
        wtree = tree if _is_encoded(tree) else policy.encode_tree(tree)
        enc = wtree if target == "weights" else {"weights": wtree,
                                                 "kv": kv_tree}
    ev = due_eval(backend=policy.backend, what=what)
    res = _run_grid(enc, ev, rates, trials, key, batch, policy.backend,
                    f"{what}_count")
    res = dataclasses.replace(res, target=target)
    if target != "weights":
        from repro.serving import kvcache  # deferred: serving builds on us
        dirty = inject_tree_device(kv_tree, max(rates), key,
                                   max_rate=max(rates))
        rows = np.asarray(kvcache.tree_layer_flags(
            dirty, backend=getattr(policy.backend, "name", policy.backend)))
        res = dataclasses.replace(
            res, layer_rows=tuple(tuple(int(v) for v in r) for r in rows))
    return res


def compute_campaign(tree, policy=None, rates=(1e-3,), trials=2, key=None,
                     batch="vmap", *, target="acc", probe_m=8,
                     probe_seed=777) -> CampaignResult:
    """COMPUTE-fault campaign: how much silent data corruption in the
    matmuls themselves does the in-kernel ABFT check catch?

    Memory campaigns (:func:`due_campaign`) flip bits in the stored image
    and let ECC account for them. This one flips bits in the *arithmetic* —
    the fault classes ECC cannot see and the fused kernel's checksum pair
    (``ecc_qmatmul(..., with_abft=True)``) exists for. Per protected >=2-D
    leaf, a fixed int8 probe activation drives the leaf's exact int32
    accumulator (``quant.int8_acc`` — the same accumulator the requantize
    epilogue checks); each (rate, trial) cell then

    * ``target="acc"``: XORs a random bit (position 0..30) into each
      accumulator element selected by a Bernoulli(rate) mask — MXU/
      datapath SDCs; a fault is DETECTED when its row or column checksum
      fires;
    * ``target="wdec"``: flips a random bit of each selected decoded-weight
      byte *in the main dot only* (the checksum references keep the clean
      tile, exactly the kernel situation where the MXU reads a corrupted
      operand) — detected when the fault's column check or any affected
      row's check fires.

    The fault rate is traced and the whole (rate x trial) grid runs as ONE
    compiled program, like every other campaign here. Returns a
    :class:`CampaignResult` with ``metric="abft_coverage"``: ``grid`` cells
    are detected/injected coverage fractions, ``clean`` is the total number
    of checksum firings at rate 0 (the false-positive count — 0 by
    construction: the int8 path compares int32 modular sums bit-exactly),
    and ``coverage_rows`` carries per-leaf (path, detected, injected)
    counts from one representative injection at ``max(rates)``.
    """
    if target not in ("acc", "wdec"):
        raise ValueError(f"target {target!r}; one of ('acc', 'wdec')")
    if batch not in ("vmap", "scan"):
        raise ValueError(f"batch must be 'vmap' or 'scan', got {batch!r}")
    from repro.core import quant
    from repro.kernels import ref as kref
    policy = _as_policy(policy if policy is not None else "in-place")
    key = jax.random.PRNGKey(0) if key is None else key
    enc = tree if _is_encoded(tree) else policy.encode_tree(tree)
    rates = tuple(float(r) for r in rates)
    n_rates = len(rates)

    # stage per-leaf (probe, int8 weights) once — the campaign operands
    flat = jax.tree_util.tree_flatten_with_path(
        enc, is_leaf=is_protected_tensor)[0]
    paths, probes = [], []
    pk = jax.random.PRNGKey(probe_seed)
    for path, leaf in flat:
        if not (is_protected_tensor(leaf) and len(leaf.orig_shape) == 2):
            continue
        w = decode_leaf(leaf, jnp.float32, backend=policy.backend)
        w_q, _ = quant.quantize(w)
        pk, sub = jax.random.split(pk)
        x_q = jax.random.randint(sub, (probe_m, w.shape[0]), -127, 128,
                                 jnp.int32).astype(jnp.int8)
        paths.append(path_str(path))
        probes.append((x_q, w_q))
    if not probes:
        raise ValueError("compute_campaign: no protected >=2-D leaves "
                         "(did the policy's predicate select anything?)")

    def leaf_counts(x_q, w_q, rate, k):
        """-> (detected, injected, fired) int32 for one leaf/cell."""
        acc = quant.int8_acc(x_q, w_q)
        k1, k2 = jax.random.split(k)
        if target == "acc":
            mask = jax.random.bernoulli(k1, rate, acc.shape)
            bit = jnp.int32(1) << jax.random.randint(k2, acc.shape, 0, 31)
            faulty = jnp.where(mask, acc ^ bit, acc)
            row_bad, col_bad = kref.abft_counts(x_q, w_q, faulty)
            hit = jnp.logical_or(row_bad[:, None] > 0, col_bad[None, :] > 0)
            det = jnp.sum(jnp.logical_and(mask, hit).astype(jnp.int32))
        else:  # wdec: corrupt the dot's operand, checksums keep the clean w
            mask = jax.random.bernoulli(k1, rate, w_q.shape)
            bit = (jnp.uint8(1) << jax.random.randint(
                k2, w_q.shape, 0, 8, jnp.uint8))
            w_f = jnp.where(
                mask,
                jax.lax.bitcast_convert_type(
                    jax.lax.bitcast_convert_type(w_q, jnp.uint8) ^ bit,
                    jnp.int8),
                w_q)
            faulty = quant.int8_acc(x_q, w_f)
            row_bad, col_bad = kref.abft_counts(x_q, w_q, faulty)
            # fault at (k0, j): the rows it perturbs are those with
            # x[:, k0] != 0; detected when one of them fires, or column j
            rdet = jnp.any(jnp.logical_and(row_bad[:, None] > 0, x_q != 0),
                           axis=0)                                     # (K,)
            hit = jnp.logical_or(rdet[:, None], col_bad[None, :] > 0)
            det = jnp.sum(jnp.logical_and(mask, hit).astype(jnp.int32))
        inj = jnp.sum(mask.astype(jnp.int32))
        fired = jnp.sum(row_bad) + jnp.sum(col_bad)
        return det, inj, fired

    def cell(rate, k):
        det = inj = fired = jnp.int32(0)
        for idx, (x_q, w_q) in enumerate(probes):
            d, i, f = leaf_counts(x_q, w_q, rate, jax.random.fold_in(k, idx))
            det, inj, fired = det + d, inj + i, fired + f
        return jnp.stack([det, inj, fired])

    if batch == "vmap":
        def grid_fn(rates_v, keys_v):
            per_rate = jax.vmap(cell, in_axes=(None, 0))
            return jax.vmap(per_rate, in_axes=(0, 0))(rates_v, keys_v)
    else:
        def grid_fn(rates_v, keys_v):
            flat_r = jnp.repeat(rates_v, trials)
            flat_k = keys_v.reshape((n_rates * trials,) + keys_v.shape[2:])

            def step(carry, rk):
                return carry, cell(*rk)

            _, out = jax.lax.scan(step, (), (flat_r, flat_k))
            return out.reshape(n_rates, trials, 3)

    rates_arr = jnp.asarray(rates, jnp.float32)
    keys = jax.random.split(key, max(n_rates * trials, 1))
    keys = keys[: n_rates * trials].reshape((n_rates, trials) + keys.shape[1:])

    t0 = time.perf_counter()
    compiled = jax.jit(grid_fn).lower(rates_arr, keys).compile()
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = np.asarray(jax.block_until_ready(compiled(rates_arr, keys)))
    wall = time.perf_counter() - t0

    # rate-0 cell: every checksum firing would be a false positive
    clean = float(np.asarray(jax.jit(cell)(
        jnp.float32(0.0), jax.random.fold_in(key, 2**31)))[2])
    # per-leaf attribution at max(rates), one representative key
    rows = []
    rk = jax.random.fold_in(key, 2**31 + 1)
    for idx, ((x_q, w_q), p) in enumerate(zip(probes, paths)):
        d, i, _ = jax.jit(leaf_counts)(x_q, w_q, jnp.float32(max(rates)),
                                       jax.random.fold_in(rk, idx))
        rows.append((p, int(d), int(i)))

    grid = tuple(tuple(float(out[r, t, 0]) / max(float(out[r, t, 1]), 1.0)
                       for t in range(trials)) for r in range(n_rates))
    dev = jax.devices()[0]
    return CampaignResult(
        scheme=_scheme_label(enc), metric="abft_coverage", rates=rates,
        trials=trials, clean=clean, grid=grid,
        space_overhead=float(space_overhead(enc)), compile_s=compile_s,
        wall_clock_s=wall, batch=batch,
        backend=getattr(policy.backend, "name", str(policy.backend)),
        platform=dev.platform,
        device=getattr(dev, "device_kind", dev.platform),
        target="compute", coverage_rows=tuple(rows))


def run_campaign_host(params, fwd, tmpl, policy, rates=RATES, trials=5,
                      seed=0, *, eval_fn=None, eval_batch=256, n_classes=4,
                      img=32, eval_seed=777) -> CampaignResult:
    """The cross-check oracle: the identical grid through the host path
    (``protection.inject_tree`` NumPy injection, one eager round-trip per
    cell).  Slow by construction; campaign<->host statistical parity on the
    same grid is asserted in the test suite."""
    policy = _as_policy(policy)
    enc = policy.encode_tree(params)
    if eval_fn is None:
        eval_fn = _default_eval(fwd, tmpl, n_classes=n_classes, img=img,
                                eval_batch=eval_batch, eval_seed=eval_seed)
        metric = "accuracy"
    else:
        metric = "custom"
    rates = tuple(float(r) for r in rates)
    clean = float(eval_fn(decode_tree(enc, jnp.float32,
                                      backend=policy.backend)))
    t0 = time.perf_counter()
    grid = []
    for ri, rate in enumerate(rates):
        row = []
        for t in range(trials):
            dirty = inject_tree(enc, rate, seed + 1000 * t + ri) if rate \
                else enc
            dec = decode_tree(dirty, jnp.float32, backend=policy.backend)
            row.append(float(eval_fn(dec)))
        grid.append(tuple(row))
    wall = time.perf_counter() - t0
    dev = jax.devices()[0]
    return CampaignResult(
        scheme=_scheme_label(enc), metric=metric, rates=rates, trials=trials,
        clean=clean, grid=tuple(grid),
        space_overhead=float(space_overhead(enc)), compile_s=0.0,
        wall_clock_s=wall, batch="host",
        backend=getattr(policy.backend, "name", "xla"),
        platform=dev.platform,
        device=getattr(dev, "device_kind", dev.platform))
