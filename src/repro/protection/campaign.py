"""Compiled on-device fault campaigns — the paper's Table 2 at device speed.

The host pipeline re-encodes and re-injects per (scheme, rate, trial), so a
4-scheme x 5-rate x 5-trial grid is ~100 serial host round-trips.  A
*campaign* instead encodes the model **once**, then runs the whole
(trial x rate) grid of inject -> decode -> eval inside **one compiled
program**:

* the fault rate is a *traced* scalar: every leaf samples a fixed budget of
  ``n_faults(bits, max(rates))`` candidate bit positions and keeps the first
  ``round(bits * rate)`` (``core.faults.inject_jax_rate``), so one program
  shape covers every rate in the sweep;
* ``batch="vmap"`` lays the full grid out as two nested ``vmap`` axes
  (fastest; peak memory ~ grid-size x the per-cell parity vectors);
  ``batch="scan"`` runs the same cells sequentially under ``lax.scan``
  (constant memory; use for big models or large trial counts);
* exactly **one** jit compile happens per campaign (AOT ``lower().compile()``
  — the compile time is reported separately from the sweep wall-clock).

The host path (``protection.inject_tree`` + ``host.run_fault_trial``) stays
as the cross-check oracle: :func:`run_campaign_host` runs the identical grid
through it, and the test suite asserts statistical parity between the two.
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .policy import (ProtectionPolicy, decode_leaf, decode_tree,
                     decode_tree_with_flags, inject_tree,
                     inject_tree_device, space_overhead)
from .tensor import is_protected_tensor

__all__ = ["CampaignResult", "run_campaign", "run_campaign_host",
           "fidelity_campaign", "due_campaign", "accuracy_eval",
           "fidelity_eval", "due_eval"]

RATES = (1e-6, 1e-5, 1e-4, 1e-3, 3e-3)


# ---------------------------------------------------------------------------
# result carrier
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CampaignResult:
    """One campaign = one (model, policy) over a (rate x trial) grid.

    ``grid[r][t]`` is the raw metric value (accuracy or decode fidelity) of
    trial ``t`` at ``rates[r]``; ``clean`` is the same metric with zero
    faults.  Derived per-rate mean/std/drop views are computed, not stored,
    so the JSON round-trip stays lossless.
    """

    scheme: str                # scheme id(s) of the policy under test
    metric: str                # "accuracy" | "fidelity"
    rates: tuple               # swept fault rates
    trials: int
    clean: float               # metric at rate 0 (no injection)
    grid: tuple                # (len(rates), trials) nested tuples of float
    space_overhead: float      # (stored - weight) / weight bytes
    compile_s: float           # one-off jit compile time (0.0 for host)
    wall_clock_s: float        # grid execution time, compile excluded
    batch: str                 # "vmap" | "scan" | "host"
    backend: str               # protection backend ("xla" | "pallas")
    platform: str              # jax device platform ("cpu", "tpu", ...)
    device: str                # jax device kind string
    target: str = "weights"    # what the faults hit: "weights" | "kv" | "both"
    layer_rows: tuple = ()     # (n_layers, 2) per-layer KV (corrected, due)
    #                            at max(rates) — () unless target covers KV

    # -- derived views -------------------------------------------------------

    def mean(self) -> tuple:
        """Per-rate mean metric across trials."""
        return tuple(float(np.mean(row)) for row in self.grid)

    def std(self) -> tuple:
        """Per-rate metric std across trials."""
        return tuple(float(np.std(row)) for row in self.grid)

    def drop(self) -> tuple:
        """Per-rate mean metric drop vs clean (the Table-2 cell value)."""
        return tuple(self.clean - m for m in self.mean())

    def row(self) -> list:
        """Table-2 row format: ``[(mean_drop, std), ...]`` per rate."""
        return list(zip(self.drop(), self.std()))

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["rates"] = list(self.rates)
        d["grid"] = [list(row) for row in self.grid]
        d["layer_rows"] = [list(row) for row in self.layer_rows]
        d["derived"] = {"mean": list(self.mean()), "std": list(self.std()),
                        "drop": list(self.drop())}
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CampaignResult":
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        kw["rates"] = tuple(kw["rates"])
        kw["grid"] = tuple(tuple(row) for row in kw["grid"])
        kw["layer_rows"] = tuple(tuple(int(v) for v in row)
                                 for row in kw.get("layer_rows", ()))
        return cls(**kw)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), indent=kw.pop("indent", 2), **kw)

    @classmethod
    def from_json(cls, s: str) -> "CampaignResult":
        return cls.from_dict(json.loads(s))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "CampaignResult":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# eval metrics
# ---------------------------------------------------------------------------


def accuracy_eval(fwd, batch):
    """Metric: top-1 accuracy of ``fwd(decoded_params, images)`` on a fixed
    eval batch (the Table-2 metric)."""
    images = jnp.asarray(batch["images"])
    labels = jnp.asarray(batch["labels"])

    def ev(dec_params):
        lg = fwd(dec_params, images)
        return jnp.mean((jnp.argmax(lg, -1) == labels).astype(jnp.float32))

    return ev


def fidelity_eval(enc_tree, backend="xla"):
    """Metric: fraction of *protected* weight values that decode identically
    to the fault-free decode.  Label-free, so it works for any model (the
    serving smoke-check uses it on LM weights)."""
    enc_leaves = jax.tree_util.tree_flatten(
        enc_tree, is_leaf=is_protected_tensor)[0]
    prot_idx = [i for i, l in enumerate(enc_leaves) if is_protected_tensor(l)]
    if not prot_idx:
        raise ValueError("fidelity_eval: the tree has no protected leaves "
                         "(did the policy's predicate select anything?)")
    clean = [decode_leaf(enc_leaves[i], jnp.float32, backend=backend)
             for i in prot_idx]
    total = sum(int(np.prod(c.shape)) for c in clean)

    def ev(dec_params):
        leaves = jax.tree_util.tree_leaves(dec_params)
        eq = sum(jnp.sum(leaves[i] == c) for i, c in zip(prot_idx, clean))
        return eq.astype(jnp.float32) / max(total, 1)

    return ev


def due_eval(backend="xla", *, what="due"):
    """Metric over the ENCODED tree: total detected-uncorrectable (double)
    errors — the per-leaf flags the decode-at-use serve step surfaces,
    summed at campaign scale (``what="corrected"`` counts repairs instead).
    """
    idx = {"corrected": 0, "due": 1}[what]

    def ev(enc_tree):
        _, flags = decode_tree_with_flags(enc_tree, jnp.float32,
                                          backend=backend)
        total = jnp.zeros((), jnp.int32)
        for pair in flags.values():
            total = total + pair[idx]
        return total.astype(jnp.float32)

    ev.wants_encoded = True
    return ev


# ---------------------------------------------------------------------------
# the compiled grid
# ---------------------------------------------------------------------------


def _scheme_label(enc_tree) -> str:
    sids = sorted({l.scheme_id for l in jax.tree_util.tree_leaves(
        enc_tree, is_leaf=is_protected_tensor) if is_protected_tensor(l)})
    return "+".join(sids) if sids else "none"


def _is_encoded(tree) -> bool:
    return any(is_protected_tensor(l) for l in jax.tree_util.tree_leaves(
        tree, is_leaf=is_protected_tensor))


def _run_grid(enc, eval_fn, rates, trials, key, batch, backend, metric):
    """Shared engine: compile one program for the whole (rate x trial) grid,
    execute it, and wrap everything into a :class:`CampaignResult`."""
    if batch not in ("vmap", "scan"):
        raise ValueError(f"batch must be 'vmap' or 'scan', got {batch!r}")
    rates = tuple(float(r) for r in rates)
    max_rate = max(rates) if rates else 0.0
    n_rates = len(rates)

    # eval fns tagged wants_encoded consume the (dirty) encoded tree itself
    # (e.g. the DUE-flags metric); everything else sees the decoded params
    wants_enc = getattr(eval_fn, "wants_encoded", False)
    clean = float(eval_fn(enc) if wants_enc else
                  eval_fn(decode_tree(enc, jnp.float32, backend=backend)))

    def cell(enc_tree, rate, k):
        dirty = inject_tree_device(enc_tree, rate, k, max_rate=max_rate)
        if wants_enc:
            return eval_fn(dirty)
        return eval_fn(decode_tree(dirty, jnp.float32, backend=backend))

    if batch == "vmap":
        def grid(enc_tree, rates_v, keys_v):
            per_rate = jax.vmap(cell, in_axes=(None, None, 0))   # trials
            return jax.vmap(per_rate, in_axes=(None, 0, 0))(     # rates
                enc_tree, rates_v, keys_v)
    else:
        def grid(enc_tree, rates_v, keys_v):
            flat_r = jnp.repeat(rates_v, trials)
            flat_k = keys_v.reshape((n_rates * trials,) + keys_v.shape[2:])

            def step(carry, rk):
                r, k = rk
                return carry, cell(enc_tree, r, k)

            _, out = jax.lax.scan(step, (), (flat_r, flat_k))
            return out.reshape(n_rates, trials)

    rates_arr = jnp.asarray(rates, jnp.float32)
    keys = jax.random.split(key, max(n_rates * trials, 1))
    keys = keys[: n_rates * trials].reshape((n_rates, trials) + keys.shape[1:])

    t0 = time.perf_counter()
    compiled = jax.jit(grid).lower(enc, rates_arr, keys).compile()
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = np.asarray(jax.block_until_ready(compiled(enc, rates_arr, keys)))
    wall = time.perf_counter() - t0

    dev = jax.devices()[0]
    be = getattr(backend, "name", str(backend))
    return CampaignResult(
        scheme=_scheme_label(enc), metric=metric, rates=rates, trials=trials,
        clean=clean, grid=tuple(tuple(float(v) for v in row) for row in out),
        space_overhead=float(space_overhead(enc)), compile_s=compile_s,
        wall_clock_s=wall, batch=batch, backend=be, platform=dev.platform,
        device=getattr(dev, "device_kind", dev.platform))


def _as_policy(policy) -> ProtectionPolicy:
    if isinstance(policy, ProtectionPolicy):
        return policy
    return ProtectionPolicy(default_scheme=policy,
                            predicate=lambda p, l: getattr(l, "ndim", 0) >= 2)


def _default_eval(fwd, tmpl, *, n_classes, img, eval_batch, eval_seed):
    from repro.data import synthetic
    b, _ = synthetic.image_batch(n_classes, eval_batch, img, seed=eval_seed,
                                 step=0, templates=tmpl)
    return accuracy_eval(fwd, b)


def run_campaign(params, fwd, tmpl, policy, rates=RATES, trials=5, key=None,
                 batch="vmap", *, eval_fn=None, eval_batch=256, n_classes=4,
                 img=32, eval_seed=777) -> CampaignResult:
    """Encode once, then sweep the full (trial x rate) fault grid on device.

    params:  fp32 parameter tree (encoded here under ``policy``).
    fwd:     ``fwd(decoded_params, images) -> logits`` (pass any input
             normalization inside); ignored when ``eval_fn`` is given.
    tmpl:    synthetic-data class templates for the eval batch (None draws
             fresh ones from ``eval_seed``); ignored when ``eval_fn`` given.
    policy:  a ``ProtectionPolicy`` or a scheme id (which gets the paper's
             eval policy: every >=2-D tensor protected).
    batch:   "vmap" (parallel grid, fastest) or "scan" (sequential,
             constant memory).
    eval_fn: optional ``(decoded_tree) -> scalar`` metric override.

    Returns a :class:`CampaignResult`; exactly one jit compile happens.
    """
    policy = _as_policy(policy)
    key = jax.random.PRNGKey(0) if key is None else key
    enc = policy.encode_tree(params)
    if eval_fn is None:
        eval_fn = _default_eval(fwd, tmpl, n_classes=n_classes, img=img,
                                eval_batch=eval_batch, eval_seed=eval_seed)
        metric = "accuracy"
    else:
        metric = "custom"
    return _run_grid(enc, eval_fn, rates, trials, key, batch, policy.backend,
                     metric)


def fidelity_campaign(tree, policy=None, rates=(1e-4,), trials=2, key=None,
                      batch="vmap") -> CampaignResult:
    """Label-free campaign: metric = decode fidelity vs the clean decode.

    ``tree`` may be raw fp32 params (encoded here under ``policy``) or an
    already-encoded tree (``policy`` then only supplies the backend).  This
    is the serving fault smoke-check: it answers "at rate r, what fraction
    of my resident weights still decode correctly?" without needing labels.
    """
    policy = _as_policy(policy if policy is not None else "in-place")
    key = jax.random.PRNGKey(0) if key is None else key
    enc = tree if _is_encoded(tree) else policy.encode_tree(tree)
    eval_fn = fidelity_eval(enc, backend=policy.backend)
    res = _run_grid(enc, eval_fn, rates, trials, key, batch, policy.backend,
                    "fidelity")
    return res


def due_campaign(tree, policy=None, rates=(1e-4,), trials=2, key=None,
                 batch="vmap", *, what="due", target="weights",
                 kv_tree=None) -> CampaignResult:
    """Fault-accounting campaign: metric = total detected-uncorrectable
    (double-error, DUE) count across protected leaves per cell — the same
    per-leaf flags the decode-at-use serve step reports per layer, swept
    over the (rate x trial) grid in one compiled program.  At the paper's
    fault model the in-place (64,57,1) code corrects all singles, so the DUE
    curve is exactly the residual risk curve; ``what="corrected"`` sweeps
    the repair counts instead.

    ``target`` picks what the faults hit: "weights" (default, ``tree``),
    "kv" (a paged KV cache's ProtectedTensor pools — build ``kv_tree`` with
    :func:`repro.serving.kvcache.as_protected_tree`), or "both" (one grid
    over the combined state).  When the target covers KV, the result also
    carries ``layer_rows``: per-layer (corrected, DUE) counts from one
    representative injection at ``max(rates)`` — the serving-state analogue
    of the per-layer weight flags."""
    if target not in ("weights", "kv", "both"):
        raise ValueError(f"target {target!r}; one of "
                         f"('weights', 'kv', 'both')")
    if target != "weights" and kv_tree is None:
        raise ValueError(f"target={target!r} needs kv_tree (see "
                         f"repro.serving.kvcache.as_protected_tree)")
    policy = _as_policy(policy if policy is not None else "in-place")
    key = jax.random.PRNGKey(0) if key is None else key
    if target == "kv":
        enc = kv_tree
    else:
        wtree = tree if _is_encoded(tree) else policy.encode_tree(tree)
        enc = wtree if target == "weights" else {"weights": wtree,
                                                 "kv": kv_tree}
    ev = due_eval(backend=policy.backend, what=what)
    res = _run_grid(enc, ev, rates, trials, key, batch, policy.backend,
                    f"{what}_count")
    res = dataclasses.replace(res, target=target)
    if target != "weights":
        from repro.serving import kvcache  # deferred: serving builds on us
        dirty = inject_tree_device(kv_tree, max(rates), key,
                                   max_rate=max(rates))
        rows = np.asarray(kvcache.tree_layer_flags(
            dirty, backend=getattr(policy.backend, "name", policy.backend)))
        res = dataclasses.replace(
            res, layer_rows=tuple(tuple(int(v) for v in r) for r in rows))
    return res


def run_campaign_host(params, fwd, tmpl, policy, rates=RATES, trials=5,
                      seed=0, *, eval_fn=None, eval_batch=256, n_classes=4,
                      img=32, eval_seed=777) -> CampaignResult:
    """The cross-check oracle: the identical grid through the host path
    (``protection.inject_tree`` NumPy injection, one eager round-trip per
    cell).  Slow by construction; campaign<->host statistical parity on the
    same grid is asserted in the test suite."""
    policy = _as_policy(policy)
    enc = policy.encode_tree(params)
    if eval_fn is None:
        eval_fn = _default_eval(fwd, tmpl, n_classes=n_classes, img=img,
                                eval_batch=eval_batch, eval_seed=eval_seed)
        metric = "accuracy"
    else:
        metric = "custom"
    rates = tuple(float(r) for r in rates)
    clean = float(eval_fn(decode_tree(enc, jnp.float32,
                                      backend=policy.backend)))
    t0 = time.perf_counter()
    grid = []
    for ri, rate in enumerate(rates):
        row = []
        for t in range(trials):
            dirty = inject_tree(enc, rate, seed + 1000 * t + ri) if rate \
                else enc
            dec = decode_tree(dirty, jnp.float32, backend=policy.backend)
            row.append(float(eval_fn(dec)))
        grid.append(tuple(row))
    wall = time.perf_counter() - t0
    dev = jax.devices()[0]
    return CampaignResult(
        scheme=_scheme_label(enc), metric=metric, rates=rates, trials=trials,
        clean=clean, grid=tuple(grid),
        space_overhead=float(space_overhead(enc)), compile_s=0.0,
        wall_clock_s=wall, batch="host",
        backend=getattr(policy.backend, "name", "xla"),
        platform=dev.platform,
        device=getattr(dev, "device_kind", dev.platform))
