"""``ProtectionPlan`` — materialized per-leaf protection decisions.

The paper's zero-space guarantee is *per tensor*: each weight independently
earns (or is denied) the in-place (64,57,1) code.  A :class:`ProtectionPlan`
makes that concrete — it is built ONCE from ``(policy, abstract_params,
mesh?)`` and holds, for every leaf, the resolved :class:`LeafPlan`: scheme
id, storage layout (same-shape vs flat-padded), resolved backend (per-leaf
rules > shape-keyed autotune table > policy default), stored-bytes
accounting, and the sharding spec of the stored image.  Every consumer —
``ProtectionPolicy.encode_tree/decode_tree/coverage``, the protected serving
step, the dry-run grid — is a view over the same plan, so "which protection,
where, on which backend" is one inspectable artifact instead of scattered
call-site defaults.

Lifecycle::

    policy = get_policy_preset("attn-inplace-mlp-secded")
    plan   = make_plan(policy, abstract_params, mesh=mesh,
                       param_spec_fn=param_spec)
    enc    = plan.encode_tree(params)       # mixed schemes per leaf
    espec  = plan.spec_tree(enc)            # sharded flat images included
    step   = make_serve_step(cfg, plan=plan)  # mixed backends per leaf
    plan.summary()                          # byte-exact vs CoverageReport

Flat-padded images get a real 1-D sharded spec over ``('data', 'model')``
when the mesh is known and shards stay 8-byte-block aligned — replicating
them (the old behaviour, still the fallback) silently blows HBM at
production scale.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .backends import Backend, get_backend
from .schemes import get_scheme
from .tensor import ProtectedTensor, is_protected_tensor

__all__ = ["LeafPlan", "ProtectionPlan", "make_plan", "LeafDiff",
           "PlanDiff", "transcode_leaf",
           "POLICY_PRESETS", "get_policy_preset"]

BLOCK = 8
FLAT_SHARD_AXES = ("data", "model")


# ---------------------------------------------------------------------------
# per-leaf decision
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    """One leaf's fully-resolved protection decision.

    path:        'layers/0/wq'-style key path of the leaf.
    scheme_id:   codec id, or None when the leaf stays unprotected.
    reason:      why unprotected ("predicate" | "rule" | "unaligned"; "" when
                 protected).
    backend:     resolved backend *name* for this leaf's codec compute.
    backend_src: where the backend came from ("rule" | "autotune" | "policy").
    layout:      "same-shape" | "flat-padded" | "raw" (unprotected).
    shape:       logical weight shape.
    n_weights:   element count.
    enc_shape:   stored image shape (== shape for same-shape, 1-D for flat).
    pad_bytes:   zero padding added by the flat layout.
    check_bytes: out-of-place check bytes (secded72 / parity-zero).
    stored_bytes: bytes resident in fault-prone memory (raw bytes when
                 unprotected) — matches ``CoverageEntry.nbytes`` exactly.
    spec:        sharding spec of the stored image (a ``ProtectedTensor`` of
                 ``PartitionSpec`` for protected leaves) or None when the
                 plan was built without ``param_spec_fn``.
    tiles:       fused decode+matmul (bm, bn, bk) for this leaf's per-layer
                 (K, N) = ``shape[-2:]`` matmul, from the policy's autotune
                 table (None without a table / for non-matmul shapes).
    int8_tiles:  int8-epilogue (bm, bn, 0) tiles, same resolution.
    tiles_src:   where the tiles came from: "exact" | "nearest" | "".
    act_quant:   activation-quantization decision for the serve step:
                 None (float activations) | "dynamic" (per-token absmax) |
                 "static" (calibrated ``a_scale``). Set via
                 :meth:`ProtectionPlan.with_act_quant`.
    a_scale:     calibrated static activation scale (float) or None.
    abft:        verify ABFT checksums on this leaf's matmuls (compute-fault
                 detection inside the epilogue). Set via
                 :meth:`ProtectionPlan.with_abft`.
    clamp:       per-leaf activation-range bound (absmax): the epilogue
                 output is clipped to ``[-clamp, +clamp]`` with out-of-range
                 hits counted. None disables (the default — bit-identical
                 to an unguarded epilogue).
    """

    path: str
    scheme_id: Optional[str]
    reason: str
    backend: str
    backend_src: str
    layout: str
    shape: tuple
    n_weights: int
    enc_shape: tuple
    pad_bytes: int
    check_bytes: int
    stored_bytes: int
    spec: Any = dataclasses.field(default=None, compare=False)
    backend_obj: Any = dataclasses.field(default=None, compare=False,
                                         repr=False)
    tiles: Optional[tuple] = None
    int8_tiles: Optional[tuple] = None
    tiles_src: str = ""
    act_quant: Optional[str] = None
    a_scale: Optional[float] = None
    abft: bool = False
    clamp: Optional[float] = None

    @property
    def protected(self) -> bool:
        return self.scheme_id is not None

    @property
    def flat_sharded(self) -> bool:
        """True when a flat-padded image got a real (non-replicated) spec."""
        from jax.sharding import PartitionSpec as P
        return (self.layout == "flat-padded" and self.spec is not None
                and self.spec.enc != P())


# ---------------------------------------------------------------------------
# plan diffs + rolling migration (the serving-side promotion primitive)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LeafDiff:
    """One leaf whose protection decision differs between two plans."""

    path: str
    from_scheme: Optional[str]
    to_scheme: Optional[str]
    from_backend: str
    to_backend: str
    stored_bytes_delta: int

    @property
    def scheme_changed(self) -> bool:
        return self.from_scheme != self.to_scheme


@dataclasses.dataclass(frozen=True)
class PlanDiff:
    """Ordered per-leaf delta between two :class:`ProtectionPlan`\\ s built
    for the SAME tree. ``paths`` (the scheme changes, in plan order) is the
    migration work-list a :class:`~repro.serving.scrubber.Migrator` drains
    shard-by-shard — one planned leaf is one shard."""

    entries: tuple

    @property
    def paths(self) -> tuple:
        """Leaves whose *scheme* changes — the shards a rolling migration
        must transcode (backend-only changes need no byte rewrite)."""
        return tuple(e.path for e in self.entries if e.scheme_changed)

    @property
    def empty(self) -> bool:
        return not self.entries

    def __len__(self) -> int:
        return len(self.entries)

    def summary(self) -> dict:
        moves: dict = {}
        for e in self.entries:
            if e.scheme_changed:
                k = f"{e.from_scheme}->{e.to_scheme}"
                moves[k] = moves.get(k, 0) + 1
        return {
            "n_changed": len(self.entries),
            "n_scheme_changes": len(self.paths),
            "moves": moves,
            "stored_bytes_delta": sum(e.stored_bytes_delta
                                      for e in self.entries),
        }


def transcode_leaf(pt: ProtectedTensor, to_scheme, *, backend="xla"):
    """Re-encode one stored image under another scheme WITHOUT a float
    round-trip: decode to the int8 domain (correcting what the old code
    can), then encode those exact values under the new scheme. Quantized
    values — and therefore every decoded logit — are preserved bit for bit
    for any scheme pair whose source was WOT-throttled at original encode
    time (every plan encodes through ``ProtectionPolicy.encode_leaf``,
    which throttles whenever ANY in-place leaf may exist; re-throttling
    here is idempotent on compliant values, so promoting secded72 ->
    in-place is value-exact too).

    Returns ``(new_pt, corrected, due)`` — the decode flags observed while
    reading the old image (``due`` blocks transcode carrying whatever the
    old decode returned; repair is a separate pass)."""
    from repro.core import wot

    frm = get_scheme(pt.scheme_id)
    to = get_scheme(to_scheme)
    be = get_backend(backend)
    q, corrected, due = frm.decode_with_flags(pt.enc, pt.checks, be)
    if to.requires_wot:
        q = wot.throttle_q(q.reshape(-1)).reshape(q.shape)
    enc, checks = to.encode(q, be)
    new = ProtectedTensor(enc=enc, checks=checks, scale=pt.scale,
                          scheme_id=to.scheme_id,
                          orig_shape=tuple(pt.orig_shape))
    return new, corrected, due


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------


class ProtectionPlan:
    """Materialized per-leaf decisions for one ``(policy, tree, mesh?)``.

    Holds an ordered ``{path: LeafPlan}`` map in tree-traversal order. All
    tree-shaped operations (:meth:`encode_tree`, :meth:`decode_tree`,
    :meth:`spec_tree`) look each leaf up by path and fail loudly on a tree
    that does not match the plan.
    """

    def __init__(self, policy, leaves: dict, *, mesh_axes=None,
                 kv_policy=None):
        self.policy = policy
        self.leaves = leaves
        self.mesh_axes = mesh_axes
        self.kv_policy = kv_policy

    # -- lookup --------------------------------------------------------------

    def __len__(self) -> int:
        return len(self.leaves)

    def __iter__(self):
        return iter(self.leaves.values())

    def __getitem__(self, path: str) -> LeafPlan:
        return self.leaves[path]

    def _leaf(self, path) -> LeafPlan:
        from .policy import path_str
        p = path_str(path)
        try:
            return self.leaves[p]
        except KeyError:
            raise KeyError(
                f"leaf {p!r} is not in this ProtectionPlan (plan built for a "
                f"different tree? {len(self.leaves)} planned leaves)") from None

    @property
    def protected(self) -> list:
        return [lp for lp in self if lp.protected]

    @property
    def unprotected(self) -> list:
        return [lp for lp in self if not lp.protected]

    # -- accounting ----------------------------------------------------------

    def by_scheme(self) -> dict:
        """Per-scheme accounting: ``{scheme_id: {n_tensors, weight_bytes,
        stored_bytes, check_bytes, pad_bytes}}``."""
        out: dict = {}
        for lp in self.protected:
            d = out.setdefault(lp.scheme_id, {"n_tensors": 0, "weight_bytes": 0,
                                              "stored_bytes": 0,
                                              "check_bytes": 0, "pad_bytes": 0})
            d["n_tensors"] += 1
            d["weight_bytes"] += lp.n_weights
            d["stored_bytes"] += lp.stored_bytes
            d["check_bytes"] += lp.check_bytes
            d["pad_bytes"] += lp.pad_bytes
        return out

    def by_backend(self) -> dict:
        out: dict = {}
        for lp in self.protected:
            out[lp.backend] = out.get(lp.backend, 0) + 1
        return out

    def summary(self) -> dict:
        """JSON-ready accounting of the whole plan. Byte-for-byte consistent
        with :class:`CoverageReport` (``protected_bytes`` etc. are sums of
        the same per-leaf ``stored_bytes``)."""
        prot, unprot = self.protected, self.unprotected
        return {
            "n_leaves": len(self.leaves),
            "n_protected": len(prot),
            "n_unprotected": len(unprot),
            "protected_bytes": sum(lp.stored_bytes for lp in prot),
            "unprotected_bytes": sum(lp.stored_bytes for lp in unprot),
            "weight_bytes": sum(lp.n_weights for lp in prot),
            "pad_bytes": sum(lp.pad_bytes for lp in prot),
            "check_bytes": sum(lp.check_bytes for lp in prot),
            "by_scheme": self.by_scheme(),
            "by_backend": self.by_backend(),
            "n_flat_padded": sum(lp.layout == "flat-padded" for lp in prot),
            "n_flat_sharded": sum(lp.flat_sharded for lp in prot),
            "tiles_src": self._count(prot, "tiles_src"),
            "act_quant": self._count(prot, "act_quant"),
            "n_abft": sum(lp.abft for lp in prot),
            "n_clamped": sum(lp.clamp is not None for lp in prot),
            "kv_policy": ({"scheme": self.kv_policy.scheme,
                           "fused": self.kv_policy.fused,
                           "attention_impl": self.kv_policy.attention_impl,
                           "page_size": self.kv_policy.page_size}
                          if self.kv_policy is not None else None),
        }

    @staticmethod
    def _count(leaves, field) -> dict:
        """{value: count} over truthy values of one LeafPlan field."""
        out: dict = {}
        for lp in leaves:
            v = getattr(lp, field)
            if v:
                out[v] = out.get(v, 0) + 1
        return out

    # -- activation quantization ---------------------------------------------

    def with_act_quant(self, mode: str = "dynamic",
                       scales: Optional[dict] = None, *,
                       clamp: bool = False) -> "ProtectionPlan":
        """A new plan whose protected matmul leaves carry activation-quant
        decisions for the int8 serve path.

        mode="dynamic":  every protected leaf with a matmul-shaped image
                         (ndim >= 2) quantizes its activations per token
                         (absmax) at use. Leaves consumed elementwise (conv
                         kernels, embeddings) ignore the marker.
        mode="static":   ``scales`` maps leaf paths to calibrated activation
                         scales (see ``serving.protected.calibrate_act_
                         scales``); exactly the calibrated leaves go static,
                         everything else keeps float activations — the
                         calibration run defines the quantized set.
        clamp=True:      (static mode only) additionally carry each
                         calibrated leaf's activation-range bound — the
                         absmax the scale was derived from
                         (``a_scale * quant.QMAX``) — so the epilogue clips
                         out-of-range outputs and counts hits
                         (Geissler-style range supervision). Off by
                         default: without it the epilogue is bit-identical
                         to the unguarded one.
        """
        from repro.core import quant
        if mode not in ("static", "dynamic"):
            raise ValueError(f"act-quant mode {mode!r}; one of "
                             f"('static', 'dynamic')")
        if mode == "static" and not scales:
            raise ValueError("static activation quantization needs calibrated"
                             " scales — run calibrate_act_scales() first")
        if clamp and mode != "static":
            raise ValueError("clamp ranges come from calibrated absmax — use "
                             "mode='static' with calibrate_act_scales()")
        scales = scales or {}
        leaves = {}
        for p, lp in self.leaves.items():
            if not lp.protected or len(lp.shape) < 2:
                leaves[p] = lp
            elif mode == "static":
                leaves[p] = dataclasses.replace(
                    lp, act_quant="static", a_scale=float(scales[p]),
                    clamp=(float(scales[p]) * quant.QMAX if clamp
                           else lp.clamp)) \
                    if p in scales else lp
            else:
                leaves[p] = dataclasses.replace(lp, act_quant="dynamic")
        return ProtectionPlan(self.policy, leaves, mesh_axes=self.mesh_axes,
                              kv_policy=self.kv_policy)

    # -- compute-fault detection (ABFT) ---------------------------------------

    def with_abft(self, enabled: bool = True, *,
                  clamps: Optional[dict] = None) -> "ProtectionPlan":
        """A new plan whose protected matmul leaves verify ABFT checksums
        at every use: the epilogue checks the accumulator's row/column sums
        against activation/weight checksums in the same kernel invocation
        (bit-exact on the int8 path), so MXU/SDC compute faults surface as
        a ``flags["layers_abft"]`` channel next to the memory-fault flags.

        ``clamps`` optionally maps leaf paths to activation-range bounds
        (absmax, e.g. ``{p: s * quant.QMAX for p, s in
        calibrate_act_scales(...).items()}``) fused into the same epilogue;
        leaves absent from the map keep their current clamp. Leaves
        consumed elementwise (conv kernels) ignore the marker."""
        clamps = clamps or {}
        leaves = {}
        for p, lp in self.leaves.items():
            if not lp.protected or len(lp.shape) < 2:
                leaves[p] = lp
            else:
                leaves[p] = dataclasses.replace(
                    lp, abft=bool(enabled),
                    clamp=(float(clamps[p]) if p in clamps else lp.clamp))
        return ProtectionPlan(self.policy, leaves, mesh_axes=self.mesh_axes,
                              kv_policy=self.kv_policy)

    # -- serving-state (KV cache) protection ----------------------------------

    def with_kv_policy(self, kv_policy) -> "ProtectionPlan":
        """A new plan that also carries a serving-state decision: the
        ``KVProtectionPolicy`` (or preset name) protecting the paged KV
        cache. Weight leaves are untouched — KV pages are protected
        per-token at write time, not planned per leaf — but serving
        entry points (``make_serve_step`` / ``make_prefill``) default
        their ``kv_policy`` from the plan, so one object routes both the
        weight and the serving-state protection story."""
        from repro.serving import kvcache  # deferred: serving builds on us
        return ProtectionPlan(self.policy, self.leaves,
                              mesh_axes=self.mesh_axes,
                              kv_policy=kvcache.get_kv_policy(kv_policy))

    # -- plan diff + rolling migration ---------------------------------------

    def diff(self, other: "ProtectionPlan") -> PlanDiff:
        """Per-leaf delta against ``other`` (the target plan). Both plans
        must be built for the same tree — same leaf paths — or the diff is
        meaningless and this raises. Entries keep this plan's traversal
        order, so a rolling migration promotes shards deterministically."""
        if set(self.leaves) != set(other.leaves):
            missing = set(self.leaves) ^ set(other.leaves)
            raise ValueError(
                f"plans cover different trees ({len(self.leaves)} vs "
                f"{len(other.leaves)} leaves; e.g. {sorted(missing)[:3]})")
        entries = []
        for p, lp in self.leaves.items():
            tp = other.leaves[p]
            if lp.scheme_id == tp.scheme_id and lp.backend == tp.backend:
                continue
            entries.append(LeafDiff(
                path=p, from_scheme=lp.scheme_id, to_scheme=tp.scheme_id,
                from_backend=lp.backend, to_backend=tp.backend,
                stored_bytes_delta=tp.stored_bytes - lp.stored_bytes))
        return PlanDiff(entries=tuple(entries))

    def with_leaves(self, leaves: dict) -> "ProtectionPlan":
        """A new plan with some leaves replaced (``{path: LeafPlan}``) —
        the post-promotion plan a migration step hands back."""
        unknown = set(leaves) - set(self.leaves)
        if unknown:
            raise KeyError(f"not in this plan: {sorted(unknown)[:3]}")
        return ProtectionPlan(self.policy, {**self.leaves, **leaves},
                              mesh_axes=self.mesh_axes,
                              kv_policy=self.kv_policy)

    def migrate_step(self, enc_tree, target: "ProtectionPlan",
                     paths) -> tuple:
        """Promote the given leaves to their ``target`` scheme IN the
        encoded tree: transcode each named leaf's stored image
        (:func:`transcode_leaf` — int8-domain, value-exact under the
        default throttled encode) and adopt the target's ``LeafPlan``.

        Returns ``(new_enc_tree, new_plan, records)`` where each record is
        ``{path, from, to, corrected, due}`` with the decode flags observed
        while reading the old image. The serve step keeps working across
        the swap — decode dispatches on each ``ProtectedTensor.scheme_id``,
        so the only cost is one planned retrace per promoted tree
        structure (a checks plane appears or disappears)."""
        from .policy import path_str

        want = set(paths)
        todo = [p for p in self.leaves if p in want]
        if len(todo) != len(want):
            raise KeyError(f"paths not in plan: "
                           f"{sorted(want - set(todo))[:3]}")
        todo_set = set(todo)
        for p in todo:
            if target.leaves[p].scheme_id is None:
                raise ValueError(f"target leaves {p!r} unprotected — "
                                 "migration only moves between schemes")
        records = []

        def mig(path, leaf):
            p = path_str(path)
            if p not in todo_set:
                return leaf
            if not is_protected_tensor(leaf):
                raise ValueError(f"{p!r} is not a ProtectedTensor "
                                 "in the encoded tree")
            tp = target.leaves[p]
            new, cor, due = transcode_leaf(
                leaf, tp.scheme_id,
                backend=tp.backend_obj or tp.backend or "xla")
            records.append({"path": p, "from": leaf.scheme_id,
                            "to": tp.scheme_id, "corrected": int(cor),
                            "due": int(due)})
            return new

        new_tree = jax.tree_util.tree_map_with_path(
            mig, enc_tree, is_leaf=is_protected_tensor)
        new_plan = self.with_leaves({p: target.leaves[p] for p in todo})
        return new_tree, new_plan, records

    def coverage(self):
        """The plan as a :class:`CoverageReport` (the legacy view)."""
        from .policy import CoverageEntry, CoverageReport
        return CoverageReport([
            CoverageEntry(lp.path, lp.scheme_id, lp.reason, lp.n_weights,
                          lp.stored_bytes, lp.pad_bytes) for lp in self])

    # -- tree ops ------------------------------------------------------------

    def encode_tree(self, params):
        """fp params -> tree with ``ProtectedTensor`` leaves, each encoded
        under its planned scheme *and* backend."""
        def enc(path, leaf):
            lp = self._leaf(path)
            if not lp.protected:
                return leaf
            return self.policy.encode_leaf(leaf, lp.scheme_id,
                                           backend=lp.backend_obj)
        return jax.tree_util.tree_map_with_path(enc, params)

    def decode_tree(self, enc_tree, dtype=jnp.bfloat16):
        """Decode with each leaf's planned backend — one tree may mix
        schemes AND backends."""
        from .policy import decode_leaf

        def dec(path, leaf):
            if not is_protected_tensor(leaf):
                return leaf
            lp = self._leaf(path)
            return decode_leaf(leaf, dtype,
                               backend=lp.backend_obj or lp.backend)
        return jax.tree_util.tree_map_with_path(
            dec, enc_tree, is_leaf=is_protected_tensor)

    def spec_tree(self, enc_tree):
        """Sharding specs for an encoded tree, from the plan's materialized
        per-leaf specs (flat-padded images sharded when block-aligned)."""
        def spec(path, leaf):
            lp = self._leaf(path)
            if lp.spec is None:
                raise ValueError(
                    f"plan has no spec for {lp.path!r} — build it with "
                    f"make_plan(..., param_spec_fn=...) to use spec_tree()")
            return lp.spec
        return jax.tree_util.tree_map_with_path(
            spec, enc_tree, is_leaf=is_protected_tensor)


# ---------------------------------------------------------------------------
# materialization
# ---------------------------------------------------------------------------


def _mesh_sizes(mesh) -> Optional[dict]:
    if mesh is None:
        return None
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _drop_nondividing(spec, shape, sizes):
    """Drop mesh axes from dims they don't divide (mirrors the dry-run's
    sanitize pass, applied at plan time when the mesh is known)."""
    from jax.sharding import PartitionSpec as P
    if sizes is None or not isinstance(spec, P):
        return spec
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim_size, entry in zip(shape, dims):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        prod = int(np.prod([sizes.get(n, 0) for n in names]))
        out.append(entry if prod and dim_size % prod == 0 else None)
    return P(*out)


def _flat_spec(enc_len: int, sizes):
    """1-D sharded spec for a flat-padded image over ('data', 'model') when
    every shard keeps whole 8-byte ECC blocks; replicated otherwise."""
    from jax.sharding import PartitionSpec as P
    if sizes is None:
        return P()
    axes = tuple(a for a in FLAT_SHARD_AXES if a in sizes)
    if not axes:
        return P()
    n_shards = int(np.prod([sizes[a] for a in axes]))
    if n_shards <= 1 or enc_len % (BLOCK * n_shards) != 0:
        return P()
    return P(axes)


def make_plan(policy, params, *, mesh=None,
              param_spec_fn: Optional[Callable] = None) -> ProtectionPlan:
    """Materialize a :class:`ProtectionPlan` from ``(policy, params, mesh?)``.

    params:        a concrete or abstract (``jax.eval_shape``) parameter
                   tree — only shapes/dtypes/paths are read.
    mesh:          optional ``jax.sharding.Mesh``; enables sharded specs for
                   flat-padded images and sanitizes same-shape specs against
                   the actual axis sizes.
    param_spec_fn: ``(path, leaf) -> PartitionSpec`` for weight leaves (the
                   same rule table serving uses); without it the plan has no
                   specs and :meth:`ProtectionPlan.spec_tree` raises.
    """
    from jax.sharding import PartitionSpec as P

    from .policy import path_str

    sizes = _mesh_sizes(mesh)
    leaves: dict = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        p = path_str(path)
        sid, reason = policy._plan(path, leaf)
        shape = tuple(getattr(leaf, "shape", ()))
        n = int(np.prod(shape)) if shape else 1
        if sid is None:
            nbytes = n * getattr(getattr(leaf, "dtype", None), "itemsize", 4)
            spec = None
            if param_spec_fn is not None:
                spec = _drop_nondividing(param_spec_fn(path, leaf), shape,
                                         sizes)
            leaves[p] = LeafPlan(
                path=p, scheme_id=None, reason=reason, backend="",
                backend_src="", layout="raw", shape=shape, n_weights=n,
                enc_shape=(), pad_bytes=0, check_bytes=0, stored_bytes=nbytes,
                spec=spec)
            continue

        scheme = get_scheme(sid)
        aligned = len(shape) >= 1 and shape[-1] % BLOCK == 0
        pad = 0 if aligned else (-n) % BLOCK
        enc_shape = shape if aligned else (n + pad,)
        checks = int((n + pad) * scheme.check_ratio)
        stored = n + pad + checks
        be, be_src = policy.resolve_backend(p, shape)
        # fused-kernel tiles for the per-layer matmul: stacked leaves
        # (L, K, N) slice to (K, N) inside the scan, so the tile shape is
        # always the trailing two dims
        tiles = int8_tiles = None
        tiles_src = ""
        if policy.autotune is not None and len(shape) >= 2:
            tiles, f_src = policy.autotune.lookup_tiles_src(shape[-2:])
            int8_tiles, i_src = policy.autotune.lookup_tiles_src(
                shape[-2:], key="int8_tiles")
            # one marker per leaf: "exact" only when every resolved tile
            # kind matched the shape; any extrapolation surfaces as "nearest"
            srcs = {s for s in (f_src, i_src) if s}
            tiles_src = ("nearest" if "nearest" in srcs
                         else "exact" if srcs else "")
        spec = None
        if param_spec_fn is not None:
            if aligned:
                enc_sds = jax.ShapeDtypeStruct(enc_shape, jnp.uint8)
                enc_spec = _drop_nondividing(param_spec_fn(path, enc_sds),
                                             enc_shape, sizes)
            else:
                enc_spec = _flat_spec(n + pad, sizes)
            spec = ProtectedTensor(enc=enc_spec,
                                   checks=P() if checks else None,
                                   scale=P(), scheme_id=scheme.scheme_id,
                                   orig_shape=shape)
        leaves[p] = LeafPlan(
            path=p, scheme_id=scheme.scheme_id, reason="", backend=be.name,
            backend_src=be_src, layout="same-shape" if aligned
            else "flat-padded", shape=shape, n_weights=n, enc_shape=enc_shape,
            pad_bytes=pad, check_bytes=checks, stored_bytes=stored, spec=spec,
            backend_obj=be, tiles=tiles, int8_tiles=int8_tiles,
            tiles_src=tiles_src)
    return ProtectionPlan(policy, leaves,
                          mesh_axes=tuple(sizes) if sizes else None)


# ---------------------------------------------------------------------------
# named policy presets (the dry-run grid's --policy axis)
# ---------------------------------------------------------------------------

# MLP / FFN / expert projections — everything the attn-inplace-mlp-secded
# preset moves to standard SEC-DED(72,64).
_MLP_PAT = (r"(^|/)(mlp|ffn|w_gate|w_up|w_down|"
            r"we_gate|we_up|we_down|ws_gate|ws_up|ws_down)(/|$)")

# Preset name -> ProtectionPolicy kwargs. "unprotected" is the paper's
# "faulty" row: same int8 residency, zero checks — the HBM/traffic baseline
# the dry-run deltas are measured against.
POLICY_PRESETS: dict = {
    "all-in-place": {},
    "all-secded72": {"default_scheme": "secded72"},
    "attn-inplace-mlp-secded": {"default_scheme": "in-place",
                                "rules": [(_MLP_PAT, "secded72")]},
    "unprotected": {"default_scheme": "faulty"},
}


def get_policy_preset(name: str, **overrides):
    """Build a named preset ``ProtectionPolicy``; extra kwargs override the
    preset's (e.g. ``predicate=``, ``backend=``, ``autotune=``)."""
    from .policy import ProtectionPolicy
    try:
        kw = dict(POLICY_PRESETS[name])
    except KeyError:
        raise ValueError(f"unknown policy preset {name!r}; one of "
                         f"{sorted(POLICY_PRESETS)}") from None
    kw.update(overrides)
    return ProtectionPolicy(**kw)
