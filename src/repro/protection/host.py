"""Host-side NumPy trial pipeline — thin wrapper over the jittable schemes.

This is the per-trial Table-2 experiment surface: encode a flat int8 weight
vector into its stored byte image, flip bits in the whole image (check bytes
included), decode, and measure.  It is also the cross-check oracle for the
compiled on-device campaigns (``repro.protection.campaign``): the parity
tests run the same grid through both paths and assert statistical agreement.
``Stored`` keeps the field shape of the removed ``core.protect.Stored`` so
protected checkpoints read the same either way.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import faults

from .schemes import Scheme, get_scheme

__all__ = ["Stored", "HostScheme", "get_host_scheme", "run_fault_trial"]

BLOCK = 8


@dataclasses.dataclass
class Stored:
    """Byte image of one protected flat weight vector."""
    data: np.ndarray              # (n_padded,) uint8 — weight bytes
    checks: np.ndarray | None     # out-of-place check bytes or None
    n_weights: int                # original length (pre-padding)

    @property
    def total_bytes(self) -> int:
        return self.data.size + (self.checks.size if self.checks is not None
                                 else 0)


class HostScheme:
    """NumPy facade over a jittable ``Scheme`` (one per registry id)."""

    def __init__(self, scheme):
        self._scheme: Scheme = get_scheme(scheme)

    @property
    def scheme_id(self) -> str:
        return self._scheme.scheme_id

    @property
    def name(self) -> str:
        return self._scheme.paper_name

    @property
    def needs_ecc_hw(self) -> bool:
        return self._scheme.needs_ecc_hw

    def encode(self, q_flat: np.ndarray) -> Stored:
        q = np.asarray(q_flat, dtype=np.int8).reshape(-1)
        pad = (-q.size) % BLOCK
        padded = np.concatenate([q, np.zeros(pad, np.int8)]) if pad else q
        enc, checks = self._scheme.encode(jnp.asarray(padded))
        return Stored(data=np.asarray(enc),
                      checks=None if checks is None else np.asarray(checks),
                      n_weights=q.size)

    def decode(self, s: Stored) -> np.ndarray:
        checks = None if s.checks is None else jnp.asarray(s.checks)
        dec = self._scheme.decode(jnp.asarray(s.data), checks)
        return np.asarray(dec, dtype=np.int8)[: s.n_weights].copy()

    def inject(self, s: Stored, rate: float, seed: int) -> Stored:
        """Flip bits across the whole stored image (data + check bytes)."""
        if s.checks is None:
            return Stored(faults.inject(s.data, rate, seed), None, s.n_weights)
        image = np.concatenate([s.data, s.checks.reshape(-1)])
        flipped = faults.inject(image, rate, seed)
        return Stored(flipped[: s.data.size],
                      flipped[s.data.size:].reshape(s.checks.shape),
                      s.n_weights)

    def space_overhead(self, s: Stored) -> float:
        return (s.total_bytes - s.n_weights) / s.n_weights


def get_host_scheme(name) -> HostScheme:
    return HostScheme(name)


def run_fault_trial(scheme, q_flat: np.ndarray, rate: float,
                    seed: int) -> np.ndarray:
    """encode -> inject faults -> decode: the per-trial pipeline of Table 2."""
    sch = scheme if isinstance(scheme, HostScheme) else get_host_scheme(scheme)
    stored = sch.encode(q_flat)
    return sch.decode(sch.inject(stored, rate, seed))
