"""MILR-style algebraic weight recovery — the last resort after a DUE.

When an 8-byte ECC block takes a second hit the code detects but cannot
correct it (a DUE), and the scrubber refuses to write the leaf back (see
``repro.serving.scrubber``).  MILR (Ponader et al., PAPERS.md) observes
that a linear layer's weights are over-determined by known input/output
pairs: with ``y = x @ W`` pinned at plan time for a clean ``W``, any set
of corrupted rows ``R`` solves exactly from

    x[:, R] @ W[R] = y - x[:, ~R] @ W[~R]

as long as ``|R| <= n_samples`` and ``x[:, R]`` has full column rank.  We
run the whole recovery in the *quantized* domain — ``y = x @ q`` with
``q`` the stored int8 image — so the solve targets integers: rounding the
least-squares solution to int8 reproduces the original rows *bit-exactly*
(the residual check then verifies against the pinned outputs before
anything is re-encoded).

The :class:`RepairKit` is built ONCE from the freshly-encoded tree
(:func:`build_repair_kit`): per repairable leaf a seeded calibration
matrix ``x`` (n_samples, K), the clean response ``y`` (float64), and —
the quarantine fallback — a ``secded72`` **twin** of the leaf's stored
image.  When reconstruction is impossible (flat-padded layout with no row
structure, more corrupted rows than samples, or residual above
tolerance) :func:`repair_leaf` *quarantines* instead: the twin replaces
the leaf, routing the layer to its out-of-place-protected copy.  Either
way the returned leaf decodes clean.

Everything here is host-side numpy (float64 solves) — repair is a
maintenance action riding the serve loop, not a jitted hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import ecc, wot
from .backends import get_backend
from .policy import path_str
from .schemes import get_scheme
from .tensor import ProtectedTensor, is_protected_tensor

__all__ = ["LeafKit", "RepairKit", "build_repair_kit", "repair_leaf",
           "repair_tree", "due_block_mask"]

_REPAIRABLE = ("in-place", "secded72")   # schemes with localizable DUEs


# ---------------------------------------------------------------------------
# DUE localization: which blocks, which rows
# ---------------------------------------------------------------------------


def due_block_mask(pt: ProtectedTensor, *, backend: str = "xla"):
    """Decode a leaf's stored image with PER-BLOCK flags.

    Returns ``(q, double)`` where ``q`` is the decoded int8 image (shape
    ``pt.enc.shape``; garbage inside DUE blocks) and ``double`` is the
    bool DUE mask over 8-byte blocks, shape ``(*enc.shape[:-1],
    enc.shape[-1] // 8)``.  Scalar scheme flags can say *that* a leaf has
    a DUE; repair needs to know *where*.
    """
    if pt.scheme_id not in _REPAIRABLE:
        raise ValueError(f"scheme {pt.scheme_id!r} has no localizable DUE "
                         f"(one of {_REPAIRABLE})")
    enc = pt.enc
    blocks = enc.reshape(*enc.shape[:-1], enc.shape[-1] // 8, 8)
    if pt.scheme_id == "in-place":
        dec, _, double = get_backend(backend).decode64(blocks)
    else:                   # secded72 decodes through the shared ecc core
        dec, _, double = ecc.decode72(blocks, pt.checks)
    q = jax.lax.bitcast_convert_type(
        dec.reshape(enc.shape), jnp.int8)
    return np.asarray(q), np.asarray(double).astype(bool)


# ---------------------------------------------------------------------------
# the kit
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LeafKit:
    """Pinned calibration for one leaf.

    x:    (n_samples, K) float64 seeded probe inputs (None when the leaf
          has no row structure to solve — twin-only quarantine coverage).
    y:    clean response ``x @ q`` in float64 — (n, N) for a 2-D leaf,
          (L, n, N) per stacked layer (None when x is None).
    twin: ``secded72``-encoded copy of the clean stored image, or None
          when the kit was built with ``twins=False``.
    """

    x: Optional[np.ndarray]
    y: Optional[np.ndarray]
    twin: Optional[ProtectedTensor]

    @property
    def solvable(self) -> bool:
        return self.x is not None


@dataclasses.dataclass(frozen=True)
class RepairKit:
    """Per-path :class:`LeafKit` map + the knobs repair runs under."""

    entries: dict
    n_samples: int
    tol: float

    def __contains__(self, path: str) -> bool:
        return path in self.entries

    def __len__(self) -> int:
        return len(self.entries)


def _leaf_items(enc_tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        enc_tree, is_leaf=is_protected_tensor)
    return flat, treedef


def build_repair_kit(enc_tree, *, seed: int = 0, n_samples: int = 32,
                     tol: float = 1e-3, backend: str = "xla",
                     twins: bool = True) -> RepairKit:
    """Pin (x, y) calibration pairs + secded72 twins from a CLEAN tree.

    Call this right after ``plan.encode_tree`` — the kit's responses are
    only as trustworthy as the image they were computed from.  Leaves
    whose stored image keeps the matmul row structure (same-shape 2-D, or
    stacked 3-D) get a solvable kit; flat-padded leaves get twin-only
    coverage (quarantine is their only recovery).  ``seed`` drives a
    dedicated numpy generator, so kits are reproducible independent of
    any jax key discipline.
    """
    rng = np.random.default_rng(seed)
    flat, _ = _leaf_items(enc_tree)
    entries = {}
    for path, leaf in flat:
        if not is_protected_tensor(leaf):
            continue
        if leaf.scheme_id not in _REPAIRABLE:
            continue
        q, double = due_block_mask(leaf, backend=backend)
        if double.any():
            raise ValueError(f"{path_str(path)}: tree has DUEs — a repair "
                             "kit must be pinned from a clean tree")
        twin = None
        if twins:
            enc_t, checks_t = get_scheme("secded72").encode(
                jnp.asarray(q), backend)
            twin = ProtectedTensor(enc=enc_t, checks=checks_t,
                                   scale=leaf.scale, scheme_id="secded72",
                                   orig_shape=tuple(leaf.orig_shape))
        x = y = None
        if not leaf.is_flat and q.ndim in (2, 3):
            k = q.shape[-2]
            x = rng.standard_normal((n_samples, k))
            y = np.einsum("nk,...kj->...nj", x, q.astype(np.float64))
        entries[path_str(path)] = LeafKit(x=x, y=y, twin=twin)
    return RepairKit(entries=entries, n_samples=n_samples, tol=tol)


# ---------------------------------------------------------------------------
# the repair
# ---------------------------------------------------------------------------


def _solve_rows(x, y, q, rows, requires_wot):
    """Reconstruct rows ``rows`` of one (K, N) int8 matrix from the pinned
    (x, y) pair.  Returns the repaired int8 matrix (float64 lstsq, rounded,
    WOT-throttled when the target scheme needs bit 6 free)."""
    ok = ~rows
    a = x[:, rows]                                       # (n, r)
    b = y - x[:, ok] @ q[ok].astype(np.float64)          # (n, N)
    sol, *_ = np.linalg.lstsq(a, b, rcond=None)          # (r, N)
    rec = np.clip(np.rint(sol), -127, 127).astype(np.int8)
    if requires_wot:
        rec = np.asarray(wot.throttle_q(
            jnp.asarray(rec.reshape(-1)))).reshape(rec.shape)
    out = q.copy()
    out[rows] = rec
    return out


def repair_leaf(pt: ProtectedTensor, kit: LeafKit, *,
                tol: Optional[float] = None, n_samples: Optional[int] = None,
                backend: str = "xla"):
    """Repair one DUE-carrying leaf.  Returns ``(new_pt, report)``.

    report["status"] is one of:
      "clean"       — no DUE found, leaf returned unchanged;
      "repaired"    — MILR reconstruction succeeded (residual under
                      tolerance); new leaf re-encoded under the SAME
                      scheme, bit-exact with the pre-fault image whenever
                      the solve is determined;
      "quarantined" — reconstruction impossible or rejected; the secded72
                      twin replaces the leaf;
      "unrecoverable" — no solve AND no twin; leaf returned unchanged
                      (the caller must treat the layer as failed).
    """
    q, double = due_block_mask(pt, backend=backend)
    report = {"scheme": pt.scheme_id, "due_blocks": int(double.sum()),
              "rows": 0, "residual": None}
    if not double.any():
        report["status"] = "clean"
        return pt, report

    def quarantine():
        if kit.twin is None:
            report["status"] = "unrecoverable"
            return pt, report
        report["status"] = "quarantined"
        return kit.twin, report

    if not kit.solvable:
        return quarantine()
    limit = n_samples if n_samples is not None else kit.x.shape[0]

    requires_wot = get_scheme(pt.scheme_id).requires_wot
    x, y = kit.x, kit.y
    stacked = q.ndim == 3
    q_layers = q if stacked else q[None]
    y_layers = y if stacked else y[None]
    dbl_layers = double if stacked else double[None]
    out_layers = []
    worst = 0.0
    n_rows = 0
    for ql, yl, dl in zip(q_layers, y_layers, dbl_layers):
        rows = dl.any(axis=-1)                    # (K,) DUE rows
        n_rows += int(rows.sum())
        if not rows.any():
            out_layers.append(ql)
            continue
        if int(rows.sum()) > limit:
            report["rows"] = n_rows
            return quarantine()
        fixed = _solve_rows(x, yl, ql, rows, requires_wot)
        resid = np.abs(x @ fixed.astype(np.float64) - yl)
        rel = float(resid.max() / (np.abs(yl).max() + 1e-12))
        worst = max(worst, rel)
        out_layers.append(fixed)
    report["rows"] = n_rows
    report["residual"] = worst
    if worst > (tol if tol is not None else 1e-3):
        return quarantine()

    q_new = np.stack(out_layers) if stacked else out_layers[0]
    enc, checks = get_scheme(pt.scheme_id).encode(
        jnp.asarray(q_new), backend)
    new_pt = ProtectedTensor(enc=enc, checks=checks, scale=pt.scale,
                             scheme_id=pt.scheme_id,
                             orig_shape=tuple(pt.orig_shape))
    report["status"] = "repaired"
    return new_pt, report


def repair_tree(enc_tree, kit: RepairKit, *, paths=None,
                backend: str = "xla"):
    """Repair every kit-covered leaf in ``paths`` (default: all covered
    leaves) that carries a DUE.  Returns ``(new_tree, reports)`` with one
    ``{path, status, rows, residual, due_blocks, scheme}`` dict per leaf
    that was actually examined and found dirty."""
    flat, treedef = _leaf_items(enc_tree)
    want = None if paths is None else set(paths)
    leaves = [leaf for _, leaf in flat]
    reports = []
    for i, (path, leaf) in enumerate(flat):
        if not is_protected_tensor(leaf):
            continue
        p = path_str(path)
        if (want is not None and p not in want) or p not in kit.entries:
            continue
        if leaf.scheme_id not in _REPAIRABLE:
            continue
        new_leaf, rep = repair_leaf(leaf, kit.entries[p], tol=kit.tol,
                                    backend=backend)
        if rep["status"] == "clean":
            continue
        leaves[i] = new_leaf
        reports.append({"path": p, **rep})
    return jax.tree_util.tree_unflatten(treedef, leaves), reports
