"""``ProtectedTensor`` — the pytree carrier for protected weights.

Replaces the fragile ``{"enc", "scale"}`` dict marker that the serving path
used to sniff for. A ``ProtectedTensor`` is a registered JAX pytree node, so
it flows through ``jax.jit`` / ``jax.tree.map`` / ``jax.eval_shape`` /
``tree_flatten`` transparently; array fields (``enc``, ``checks``, ``scale``)
are children and the codec metadata (``scheme_id``, ``orig_shape``) rides
along as static aux data.

Two storage layouts:

* **same-shape** — ``enc`` has exactly the weight's shape (ECC blocks run
  along the last dim, which must be a multiple of 8). The encoded image
  inherits the weight's sharding spec byte for byte.
* **flat-padded** — for tensors whose last dim is *not* a block multiple:
  ``enc`` is 1-D, the flattened weight padded up to a block multiple.
  ``orig_shape`` + ``n_weights`` recover the tensor on decode.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax

__all__ = ["ProtectedTensor", "is_protected_tensor"]


@dataclasses.dataclass(frozen=True)
class ProtectedTensor:
    """Stored byte image of one protected weight tensor.

    enc:        uint8 encoded weight bytes (same-shape or flat-padded).
    checks:     out-of-place check bytes (secded72 / parity-zero) or None.
    scale:      f32 quantization scale (q = round(w / scale)).
    scheme_id:  registry id of the codec ("faulty", "parity-zero",
                "secded72", "in-place").
    orig_shape: logical shape of the original weight tensor.
    """
    enc: Any
    checks: Any
    scale: Any
    scheme_id: str = "in-place"
    orig_shape: tuple = ()

    # -- metadata ------------------------------------------------------------

    @property
    def n_weights(self) -> int:
        return int(math.prod(self.orig_shape))

    @property
    def is_flat(self) -> bool:
        """True for the flat-padded layout (enc 1-D, weight possibly not)."""
        return tuple(self.enc.shape) != tuple(self.orig_shape)

    @property
    def stored_bytes(self) -> int:
        """Total bytes resident in fault-prone memory (enc + check bytes)."""
        total = int(math.prod(self.enc.shape))
        if self.checks is not None:
            total += int(math.prod(self.checks.shape))
        return total

    @property
    def space_overhead(self) -> float:
        """(stored - weight) / weight bytes; 0.0 for in-place on aligned
        tensors, 0.125 for secded72/parity-zero."""
        return (self.stored_bytes - self.n_weights) / max(self.n_weights, 1)

    def __repr__(self) -> str:  # compact: the arrays can be huge
        enc_shape = tuple(getattr(self.enc, "shape", ()))
        return (f"ProtectedTensor(scheme={self.scheme_id!r}, "
                f"orig_shape={tuple(self.orig_shape)}, enc={enc_shape}, "
                f"checks={self.checks is not None})")


def _flatten_with_keys(pt: ProtectedTensor):
    keys = (jax.tree_util.GetAttrKey("enc"), jax.tree_util.GetAttrKey("checks"),
            jax.tree_util.GetAttrKey("scale"))
    children = (pt.enc, pt.checks, pt.scale)
    aux = (pt.scheme_id, tuple(pt.orig_shape))
    return tuple(zip(keys, children)), aux


def _flatten(pt: ProtectedTensor):
    return (pt.enc, pt.checks, pt.scale), (pt.scheme_id, tuple(pt.orig_shape))


def _unflatten(aux, children) -> ProtectedTensor:
    scheme_id, orig_shape = aux
    enc, checks, scale = children
    return ProtectedTensor(enc=enc, checks=checks, scale=scale,
                           scheme_id=scheme_id, orig_shape=orig_shape)


jax.tree_util.register_pytree_with_keys(
    ProtectedTensor, _flatten_with_keys, _unflatten, _flatten)


def is_protected_tensor(x) -> bool:
    return isinstance(x, ProtectedTensor)
