"""``ProtectedWeight`` — lazy decode-at-use carrier for one protected leaf.

The decode-at-use serving step replaces each (per-layer) ``ProtectedTensor``
with a ``ProtectedWeight`` view instead of decoding the whole tree up front.
The view defers ALL codec work to the weight's point of use inside the
model:

* ``matmul(x)`` — the projection path. On the Pallas route for 2-D
  same-shape in-place images this calls the fused ``kernels.ecc_qmatmul``
  (decode in VMEM on the way to the MXU — zero decoded bytes ever hit HBM);
  every other route decodes just this leaf inline and matmuls.
* ``astype(dtype)`` — the fallback for non-projection uses (router einsums,
  gate matmuls, 3-D expert weights): decodes just this leaf, with flags.

Both paths report ``(corrected, due)`` int32 counts through the ``record``
callback, which the serving step wires to the per-layer flags sink in
``models.layers`` — the FT-CNN-style fault accounting that used to be
discarded by the kernel.

``models.layers._proj`` recognizes the view by its ``decode_at_use`` class
attribute (duck typing — layers never imports this module).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from .backends import get_backend
from .policy import decode_leaf_with_flags
from .tensor import ProtectedTensor

__all__ = ["ProtectedWeight", "can_fuse"]


def can_fuse(pt: ProtectedTensor, backend) -> bool:
    """True when this leaf can route through the fused decode+matmul kernel:
    Pallas backend, in-place scheme, 2-D same-shape image (ECC blocks along
    the output dim)."""
    name = getattr(backend, "name", backend) or "xla"
    return (name == "pallas" and pt.scheme_id == "in-place"
            and not pt.is_flat and getattr(pt.enc, "ndim", 0) == 2)


def is_matmul_weight(path: str) -> bool:
    """True when the leaf is consumed as the RHS of a matmul/einsum — the
    only uses a lazy view can serve. Depthwise conv kernels (``conv_w``) are
    indexed elementwise by ``layers._causal_conv`` and must decode to real
    arrays instead."""
    last = path.rsplit("/", 1)[-1]
    return not last.startswith("conv")


class ProtectedWeight:
    """One leaf's decode-at-use view (see module docstring).

    pt:      the (already per-layer-sliced) ProtectedTensor.
    backend: Backend instance or name for this leaf's codec compute.
    tiles:   optional (bm, bn, bk) for the fused kernel (from the autotune
             table); None uses the kernel defaults (full-K tiles).
    record:  ``record(corrected, due)`` flags callback (no-op when None).
    """

    decode_at_use = True  # the marker layers._proj dispatches on

    def __init__(self, pt: ProtectedTensor, backend="xla", *,
                 tiles: Optional[tuple] = None,
                 record: Optional[Callable] = None):
        self.pt = pt
        self.backend = get_backend(backend)
        self.fuse = can_fuse(pt, self.backend)
        self.tiles = tiles
        self._record = record

    # -- array-protocol surface (enough for every call site in layers.py) ----

    @property
    def shape(self):
        return tuple(self.pt.orig_shape)

    @property
    def ndim(self):
        return len(self.pt.orig_shape)

    def record(self, corrected, due):
        if self._record is not None:
            self._record(corrected, due)

    def astype(self, dtype):
        """Decode just this leaf (recording flags) -> dequantized array."""
        w, corrected, due = decode_leaf_with_flags(self.pt, dtype,
                                                   backend=self.backend)
        self.record(corrected, due)
        return w

    def matmul(self, x):
        """``x @ decode(self)`` with decode at the point of use.

        Fused route: the Pallas kernel dequantizes each decoded tile in VMEM
        (identical value path to decode-then-matmul) and returns the block
        flag counts. Inline route: decode this leaf, then a plain matmul.
        """
        if not jnp.issubdtype(x.dtype, jnp.floating):
            # int8 activations need the raw int32 accumulator + explicit
            # activation scaling — use kernels.ecc_qmatmul / Backend.qmatmul
            # directly; silently casting the accumulator to x.dtype would
            # truncate it.
            raise TypeError(
                f"ProtectedWeight.matmul serves float activations (got "
                f"{x.dtype}); for the quantized int8 path call "
                f"protection.qmatmul / kernels.ecc_qmatmul directly")
        if not self.fuse:
            return x @ self.astype(x.dtype)
        from repro.kernels.ecc_qmatmul import ecc_qmatmul
        interpret = getattr(self.backend, "interpret", True)
        # serving keeps full-K tiles (bk=0): one f32 dot per output tile, so
        # the accumulation order — and hence every logit — is bit-identical
        # to decode-then-matmul. The autotune bk only tunes the int8 path.
        bm, bn, _bk = self.tiles or (128, 128, 0)
        lead = x.shape[:-1]
        a2 = x.reshape(-1, x.shape[-1])
        out, flags = ecc_qmatmul(a2, self.pt.enc, self.pt.scale,
                                 bm=bm, bn=bn, bk=0, interpret=interpret,
                                 with_flags=True)
        self.record(flags[0], flags[1])
        return out.astype(x.dtype).reshape(*lead, self.pt.enc.shape[1])

    def __repr__(self):
        return (f"ProtectedWeight({self.pt!r}, backend={self.backend.name!r}, "
                f"fuse={self.fuse})")
