"""``ProtectedWeight`` — lazy decode-at-use carrier for one protected leaf.

The decode-at-use serving step replaces each (per-layer) ``ProtectedTensor``
with a ``ProtectedWeight`` view instead of decoding the whole tree up front.
The view defers ALL codec work to the weight's point of use inside the
model:

* ``matmul(x)`` — the projection path. Float activations take the fused
  ``kernels.ecc_qmatmul`` float path on the Pallas route (decode in VMEM on
  the way to the MXU — zero decoded bytes ever hit HBM) or a per-leaf inline
  decode + matmul elsewhere. With an activation-quant decision
  (``act_quant`` = "static" calibrated scale | "dynamic" per-token absmax)
  the view quantizes the activations to int8 first and runs the kernel's
  fused requantize epilogue — int8 MXU throughput, int32 accumulation, and
  a bf16 result straight out of VMEM. The non-fused int8 route (XLA backend,
  flat images) is the literal quantize -> decode -> int8-matmul -> rescale
  sequence, bit-identical to the epilogue (both scale one exact int32
  accumulator by ``a_scale * w_scale`` in f32).
* ``astype(dtype)`` — the fallback for non-projection uses (router einsums,
  gate matmuls, 3-D expert weights): decodes just this leaf, with flags.

Both paths report ``(corrected, due)`` int32 counts through the ``record``
callback, which the serving step wires to the per-layer flags sink in
``models.layers`` — the FT-CNN-style fault accounting that used to be
discarded by the kernel. An optional ``observe`` callback receives each
float activation absmax — the calibration pass uses it to derive static
``a_scale`` values from a small batch.

``models.layers._proj`` recognizes the view by its ``decode_at_use`` class
attribute (duck typing — layers never imports this module).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import quant

from .backends import get_backend
from .policy import decode_leaf_with_flags
from .schemes import get_scheme
from .tensor import ProtectedTensor

__all__ = ["ProtectedWeight", "can_fuse"]


def can_fuse(pt: ProtectedTensor, backend) -> bool:
    """True when this leaf can route through the fused decode+matmul kernel:
    Pallas backend, in-place scheme, 2-D same-shape image (ECC blocks along
    the output dim)."""
    name = getattr(backend, "name", backend) or "xla"
    return (name == "pallas" and pt.scheme_id == "in-place"
            and not pt.is_flat and getattr(pt.enc, "ndim", 0) == 2)


def is_matmul_weight(path: str) -> bool:
    """True when the leaf is consumed as the RHS of a matmul/einsum — the
    only uses a lazy view can serve. Depthwise conv kernels (``conv_w``) are
    indexed elementwise by ``layers._causal_conv`` and must decode to real
    arrays instead."""
    last = path.rsplit("/", 1)[-1]
    return not last.startswith("conv")


class ProtectedWeight:
    """One leaf's decode-at-use view (see module docstring).

    pt:         the (already per-layer-sliced) ProtectedTensor.
    backend:    Backend instance or name for this leaf's codec compute.
    tiles:      optional (bm, bn, bk) for the fused float path (autotune);
                None uses the kernel defaults (full-K tiles).
    int8_tiles: optional (bm, bn, 0) for the fused int8 epilogue.
    record:     ``record(corrected, due)`` flags callback (no-op when None).
    act_quant:  None (float activations) | "dynamic" (per-token absmax) |
                "static" (needs ``a_scale``) — the int8 MXU serve path.
    a_scale:    calibrated static activation scale (float).
    observe:    ``observe(absmax)`` callback fed each float activation's
                absmax (the calibration hook; no-op when None).
    abft:       verify ABFT checksums on every matmul (in-kernel on the
                fused route, the ``kernels.ref.abft_counts`` mirror on the
                XLA route — same math, backend parity).
    clamp:      per-leaf activation absmax: epilogue output clipped to
                ``[-clamp, +clamp]``, hits counted (Geissler-style range
                supervision).
    record_abft: ``record_abft(mismatches, clamp_hits)`` callback; scalars,
                or per-output-row (M,) vectors when ``abft_per_slot`` (the
                column-check count is not row-attributable and then rides
                only the scalar channel).
    """

    decode_at_use = True  # the marker layers._proj dispatches on

    def __init__(self, pt: ProtectedTensor, backend="xla", *,
                 tiles: Optional[tuple] = None,
                 int8_tiles: Optional[tuple] = None,
                 record: Optional[Callable] = None,
                 act_quant: Optional[str] = None,
                 a_scale: Optional[float] = None,
                 observe: Optional[Callable] = None,
                 abft: bool = False,
                 clamp: Optional[float] = None,
                 record_abft: Optional[Callable] = None,
                 abft_per_slot: bool = False):
        if act_quant not in (None, "static", "dynamic"):
            raise ValueError(f"act_quant {act_quant!r}; one of "
                             f"(None, 'static', 'dynamic')")
        if act_quant == "static" and a_scale is None:
            raise ValueError("act_quant='static' needs a calibrated a_scale")
        self.pt = pt
        self.backend = get_backend(backend)
        self.fuse = can_fuse(pt, self.backend)
        self.tiles = tiles
        self.int8_tiles = int8_tiles
        self.act_quant = act_quant
        self.a_scale = a_scale
        self.abft = bool(abft)
        self.clamp = None if clamp is None else float(clamp)
        self.abft_per_slot = abft_per_slot
        self._record = record
        self._record_abft = record_abft
        self._observe = observe

    # -- array-protocol surface (enough for every call site in layers.py) ----

    @property
    def shape(self):
        return tuple(self.pt.orig_shape)

    @property
    def ndim(self):
        return len(self.pt.orig_shape)

    def record(self, corrected, due):
        if self._record is not None:
            self._record(corrected, due)

    @property
    def _track(self):
        """ABFT and/or clamp accounting active for this leaf."""
        return self.abft or self.clamp is not None

    def record_abft(self, row_mm, clamp_hits, col_mm):
        """Report (mismatches, clamp hits) — per-row vectors when the serve
        step wants per-slot attribution, else scalars (the scalar mismatch
        total additionally includes the column-check count)."""
        if self._record_abft is None:
            return
        if self.abft_per_slot:
            self._record_abft(row_mm, clamp_hits)
        else:
            self._record_abft(jnp.sum(row_mm) + col_mm, jnp.sum(clamp_hits))

    def astype(self, dtype):
        """Decode just this leaf (recording flags) -> dequantized array."""
        w, corrected, due = decode_leaf_with_flags(self.pt, dtype,
                                                   backend=self.backend)
        self.record(corrected, due)
        return w

    # -- int8 path internals -------------------------------------------------

    def _decode_q(self):
        """Decode to RAW int8 weights (no dequantization), with flags."""
        scheme = get_scheme(self.pt.scheme_id)
        q, corrected, due = scheme.decode_with_flags(self.pt.enc,
                                                     self.pt.checks,
                                                     self.backend)
        if self.pt.is_flat:
            q = q.reshape(-1)[: self.pt.n_weights].reshape(self.pt.orig_shape)
        return q, corrected, due

    def _quantize_x(self, x2):
        """(M, K) float -> (int8 q, f32 a_scale (scalar | (M, 1)))."""
        xf = x2.astype(jnp.float32)
        if self.act_quant == "static":
            a_scale = jnp.asarray(self.a_scale, jnp.float32)
        else:  # dynamic per-token absmax
            a_scale = quant.compute_scale(xf, axis=1)  # (M, 1)
        q, _ = quant.quantize(xf, scale=a_scale)
        return q, a_scale

    def _int8_matmul(self, q_x, a_scale, out_dtype):
        """``q_x (M,K) int8 @ decode(enc)`` with the fused requantize
        epilogue (Pallas route) or the inline quantize->decode->matmul
        reference (every other route) — bit-identical value paths: one
        exact int32 accumulator scaled by ``a_scale * w_scale`` in f32."""
        if self.fuse:
            from repro.kernels.ecc_qmatmul import ecc_qmatmul
            interpret = getattr(self.backend, "interpret", True)
            bm, bn, _bk = (self.int8_tiles or self.tiles or (128, 128, 0))
            res = ecc_qmatmul(q_x, self.pt.enc, self.pt.scale,
                              a_scale=a_scale, out_dtype=out_dtype,
                              bm=bm, bn=bn, interpret=interpret,
                              with_flags=True, with_abft=self.abft,
                              clamp=self.clamp)
            if self._track:
                out, flags, (rows, col_mm) = res
                self.record_abft(rows[:, 0], rows[:, 1], col_mm)
            else:
                out, flags = res
            self.record(flags[0], flags[1])
            return out
        q_w, corrected, due = self._decode_q()
        self.record(corrected, due)
        if not self._track:
            # quant.int8_matmul is the single source of the epilogue's value
            # path: exact int32 accumulator * (a_scale * w_scale) in f32
            return quant.int8_matmul(q_x, q_w, a_scale,
                                     self.pt.scale).astype(out_dtype)
        # XLA mirror of the guarded epilogue: the same int32 accumulator
        # (quant.int8_acc IS int8_matmul's accumulator) checked by the
        # same ABFT pair, then the identical rescale.
        from repro.kernels import ref
        acc = quant.int8_acc(q_x, q_w)
        if self.abft:
            row_mm, col_bad = ref.abft_counts(q_x, q_w, acc)
            col_mm = jnp.sum(col_bad)
        else:
            row_mm = jnp.zeros((q_x.shape[0],), jnp.int32)
            col_mm = jnp.int32(0)
        out = acc.astype(jnp.float32) * (a_scale * self.pt.scale)
        if self.clamp is not None:
            out, hits = ref.clamp_counts(out, self.clamp)
        else:
            hits = jnp.zeros_like(row_mm)
        self.record_abft(row_mm, hits, col_mm)
        return out.astype(out_dtype)

    # -- the projection entry point ------------------------------------------

    def matmul(self, x):
        """``x @ decode(self)`` with decode at the point of use.

        Float ``x``: fused float path / inline decode (value path identical
        to decode-then-matmul); with an ``act_quant`` decision, ``x`` is
        quantized here and served over the int8 MXU path instead. int8 ``x``
        is accepted when a static ``a_scale`` says what the integers mean.
        """
        lead = x.shape[:-1]
        a2 = x.reshape(-1, x.shape[-1])
        n_out = self.pt.orig_shape[-1]
        if not jnp.issubdtype(x.dtype, jnp.floating):
            # pre-quantized activations: meaningful only at a known scale
            if self.act_quant != "static":
                raise TypeError(
                    f"ProtectedWeight.matmul got raw {x.dtype} activations "
                    f"without a static a_scale; serve float activations, or "
                    f"plan.with_act_quant('static', scales) so the view "
                    f"knows the quantization scale")
            out = self._int8_matmul(a2, jnp.asarray(self.a_scale, jnp.float32),
                                    jnp.bfloat16)
            return out.reshape(*lead, n_out)
        if self._observe is not None:
            self._observe(jnp.max(jnp.abs(a2.astype(jnp.float32))))
        if self.act_quant is not None:
            q_x, a_scale = self._quantize_x(a2)
            out = self._int8_matmul(q_x, a_scale, x.dtype)
            return out.astype(x.dtype).reshape(*lead, n_out)
        if not self.fuse:
            if not self._track:
                return x @ self.astype(x.dtype)
            from repro.kernels import ref
            w = self.astype(x.dtype)
            # check the f32 accumulator, as the kernel does — a bf16 dot's
            # rounded output would trip the float tolerance spuriously; the
            # value path stays identical (f32 accumulate, one final round)
            acc = jax.lax.dot_general(
                a2, w, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            if self.abft:
                row_mm, col_bad = ref.abft_counts(a2, w, acc)
                col_mm = jnp.sum(col_bad)
            else:
                row_mm = jnp.zeros((a2.shape[0],), jnp.int32)
                col_mm = jnp.int32(0)
            if self.clamp is not None:
                acc, hits = ref.clamp_counts(acc, self.clamp)
            else:
                hits = jnp.zeros_like(row_mm)
            self.record_abft(row_mm, hits, col_mm)
            return acc.astype(x.dtype).reshape(*lead, n_out)
        from repro.kernels.ecc_qmatmul import ecc_qmatmul
        interpret = getattr(self.backend, "interpret", True)
        # serving keeps full-K tiles (bk=0): one f32 dot per output tile, so
        # the accumulation order — and hence every logit — is bit-identical
        # to decode-then-matmul. The autotune bk only tunes the int8 path.
        bm, bn, _bk = self.tiles or (128, 128, 0)
        res = ecc_qmatmul(a2, self.pt.enc, self.pt.scale,
                          bm=bm, bn=bn, bk=0, interpret=interpret,
                          with_flags=True, with_abft=self.abft,
                          clamp=self.clamp)
        if self._track:
            out, flags, (rows, col_mm) = res
            self.record_abft(rows[:, 0], rows[:, 1], col_mm)
        else:
            out, flags = res
        self.record(flags[0], flags[1])
        return out.astype(x.dtype).reshape(*lead, self.pt.enc.shape[1])

    def __repr__(self):
        return (f"ProtectedWeight({self.pt!r}, backend={self.backend.name!r}, "
                f"fuse={self.fuse}, act_quant={self.act_quant!r})")
