"""``repro.protection`` — the unified host/device protection API.

One surface for everything the paper's contribution needs in production:

* :class:`ProtectedTensor` — pytree carrier for encoded weights (replaces the
  old ``{"enc", "scale"}`` dict marker).
* :mod:`schemes <repro.protection.schemes>` — jittable codecs for all four
  paper schemes (``faulty`` / ``parity-zero`` / ``secded72`` / ``in-place``).
* :class:`ProtectionPolicy` — per-layer scheme selection, pad-and-protect,
  and :class:`CoverageReport` (subsumes ``wot.is_protected_weight`` and the
  old silent ``last-dim % 8`` gate).
* :mod:`backends <repro.protection.backends>` — ``backend="xla" | "pallas"``
  routes block codec compute and the fused protected matmul.
* :mod:`host <repro.protection.host>` — the NumPy Table-2 trial pipeline as a
  thin wrapper over the same schemes (the campaign cross-check oracle).
* :mod:`campaign <repro.protection.campaign>` — compiled on-device fault
  campaigns: encode once, sweep the whole (trial x rate) grid inside one
  jitted program, get a serializable :class:`CampaignResult`.

See ``docs/campaigns.md`` for the campaign API guide and ``docs/faq.md`` for
the fault model.
"""
from __future__ import annotations

import jax.numpy as jnp

from .backends import (BACKENDS, BENCH_KERNELS_SCHEMA,
                       BENCH_KERNELS_SCHEMA_V1, BENCH_KERNELS_SCHEMA_V2,
                       BENCH_KERNELS_SCHEMA_V3, BENCH_KERNELS_SCHEMA_V4,
                       BENCH_KERNELS_SCHEMA_V5, AutotuneTable, Backend,
                       PallasBackend, XlaBackend, get_backend)
from .campaign import (CampaignResult, accuracy_eval, compute_campaign,
                       due_campaign, due_eval, fidelity_campaign,
                       fidelity_eval, run_campaign, run_campaign_host)
from .fused import ProtectedWeight, can_fuse
from .host import HostScheme, Stored, get_host_scheme, run_fault_trial
from .plan import (POLICY_PRESETS, LeafPlan, ProtectionPlan,
                   get_policy_preset, make_plan)
from .policy import (CoverageEntry, CoverageReport, ProtectionPolicy,
                     decode_leaf, decode_leaf_with_flags, decode_tree,
                     decode_tree_with_flags, inject_tree,
                     inject_tree_device, space_overhead, spec_tree)
from .schemes import (ALIASES, SCHEMES, Faulty, InPlace, ParityZero, Scheme,
                      Secded72, get_scheme, scheme_ids)
from .tensor import ProtectedTensor, is_protected_tensor

__all__ = [
    "ProtectedTensor", "is_protected_tensor",
    "Scheme", "Faulty", "ParityZero", "Secded72", "InPlace",
    "SCHEMES", "ALIASES", "get_scheme", "scheme_ids",
    "ProtectionPolicy", "CoverageReport", "CoverageEntry",
    "ProtectionPlan", "LeafPlan", "make_plan",
    "POLICY_PRESETS", "get_policy_preset",
    "decode_leaf", "decode_tree", "decode_leaf_with_flags",
    "decode_tree_with_flags", "inject_tree", "inject_tree_device",
    "spec_tree", "space_overhead", "ProtectedWeight", "can_fuse",
    "Backend", "XlaBackend", "PallasBackend", "BACKENDS", "get_backend",
    "AutotuneTable", "BENCH_KERNELS_SCHEMA", "BENCH_KERNELS_SCHEMA_V1",
    "BENCH_KERNELS_SCHEMA_V2", "BENCH_KERNELS_SCHEMA_V3",
    "BENCH_KERNELS_SCHEMA_V4", "BENCH_KERNELS_SCHEMA_V5",
    "HostScheme", "Stored", "get_host_scheme", "run_fault_trial",
    "CampaignResult", "run_campaign", "run_campaign_host",
    "fidelity_campaign", "due_campaign", "compute_campaign", "accuracy_eval",
    "fidelity_eval",
    "due_eval",
    "default_policy", "encode_tree", "coverage", "qmatmul",
]

_DEFAULT_POLICY: ProtectionPolicy | None = None


def default_policy() -> ProtectionPolicy:
    """The serving default: in-place zero-space ECC on every weight tensor,
    pad-and-protect, XLA backend."""
    global _DEFAULT_POLICY
    if _DEFAULT_POLICY is None:
        _DEFAULT_POLICY = ProtectionPolicy()
    return _DEFAULT_POLICY


def encode_tree(params, policy: ProtectionPolicy | None = None):
    """Encode a parameter tree under ``policy`` (default: in-place on all
    weights). Decode side needs no policy — each ``ProtectedTensor`` carries
    its scheme id."""
    return (policy or default_policy()).encode_tree(params)


def coverage(params, policy: ProtectionPolicy | None = None) -> CoverageReport:
    return (policy or default_policy()).coverage(params)


def qmatmul(a_q, w, a_scale, *, backend="xla"):
    """Protected matmul: ``a_q (M,K) int8 @ decode(w) * scales -> (M,N) f32``.

    ``w`` is a ``ProtectedTensor`` holding an in-place-encoded 2-D weight in
    the same-shape layout (the fused Pallas kernel decodes 64-bit blocks on
    the way to the MXU; the XLA backend decodes then matmuls).
    """
    if not is_protected_tensor(w):
        raise TypeError(f"qmatmul needs a ProtectedTensor, got {type(w)}")
    if w.scheme_id != "in-place":
        raise ValueError(f"fused qmatmul supports the in-place scheme only, "
                         f"got {w.scheme_id!r}")
    if w.is_flat or w.enc.ndim != 2:
        raise ValueError("qmatmul needs a 2-D same-shape encoded image "
                         f"(got enc shape {tuple(w.enc.shape)} for weight "
                         f"{tuple(w.orig_shape)})")
    return get_backend(backend).qmatmul(a_q, w.enc, a_scale,
                                        w.scale.astype(jnp.float32))
