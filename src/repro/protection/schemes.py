"""Jittable protection schemes (paper §5.1 baselines + the contribution).

A scheme maps int8 weight arrays (trailing dim a multiple of 8 — the policy
layer guarantees this by padding) to the *stored byte image* that lives in
fault-prone memory, and back:

  faulty       raw bytes, no protection                      (paper "faulty")
  parity-zero  byte parity, detected-faulty weight -> 0      (paper "zero")
  secded72     standard SEC-DED (72,64,1), 12.5% overhead    (paper "ecc")
  in-place     in-place zero-space SEC-DED (64,57,1), 0%     (paper "in-place")

``encode``/``decode`` are pure jnp (trace-safe), batched over any leading
dims, and route 64-bit-block compute through a pluggable ``Backend``
(XLA reference or the fused Pallas kernels). The host-side NumPy trial
pipeline of the Table-2 experiments is a thin wrapper in ``host.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ecc

from .backends import Backend, get_backend

__all__ = ["Scheme", "Faulty", "ParityZero", "Secded72", "InPlace",
           "SCHEMES", "ALIASES", "get_scheme", "scheme_ids"]

BLOCK = ecc.BLOCK_BYTES


def _as_bytes(q: jnp.ndarray) -> jnp.ndarray:
    if q.dtype == jnp.uint8:
        return q
    return jax.lax.bitcast_convert_type(q.astype(jnp.int8), jnp.uint8)


def _as_int8(b: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(b.astype(jnp.uint8), jnp.int8)


def _blocks(b: jnp.ndarray) -> jnp.ndarray:
    return b.reshape(*b.shape[:-1], b.shape[-1] // BLOCK, BLOCK)


class Scheme:
    """Base interface. Subclasses are stateless; use ``get_scheme``."""

    scheme_id: str = "faulty"
    paper_name: str = "faulty"      # row label in the paper's Table 2
    needs_ecc_hw: bool = False      # needs the Fig.-2 swizzle + ECC logic
    check_ratio: float = 0.0        # out-of-place check bytes per weight byte
    requires_wot: bool = False      # encode corrupts non-WOT-compliant bytes

    def encode(self, q: jnp.ndarray, backend: Backend | str = "xla"):
        """int8 (..., n), n % 8 == 0 -> (enc uint8 (..., n), checks | None)."""
        raise NotImplementedError

    def decode(self, enc: jnp.ndarray, checks, backend: Backend | str = "xla"):
        """Stored image -> int8 (..., n). Corrects/zeroes per the scheme."""
        raise NotImplementedError

    def decode_with_flags(self, enc, checks, backend: Backend | str = "xla"):
        """Like :meth:`decode`, plus fault accounting: returns
        ``(decoded, corrected, due)`` where ``corrected`` counts faults the
        scheme repaired (bit corrections, parity-zeroed bytes) and ``due``
        counts detected-uncorrectable (double) errors — both int32 scalars.
        Schemes with no detection capability report zeros."""
        zero = jnp.zeros((), jnp.int32)
        return self.decode(enc, checks, backend), zero, zero


class Faulty(Scheme):
    scheme_id = "faulty"
    paper_name = "faulty"

    def encode(self, q, backend="xla"):
        return _as_bytes(q), None

    def decode(self, enc, checks, backend="xla"):
        return _as_int8(enc)


class ParityZero(Scheme):
    scheme_id = "parity-zero"
    paper_name = "zero"
    check_ratio = 1.0 / BLOCK

    def encode(self, q, backend="xla"):
        data = _as_bytes(q)
        return data, ecc.encode_parity8(data)

    def decode(self, enc, checks, backend="xla"):
        data, _bad = ecc.decode_parity8(enc, checks)
        return _as_int8(data)

    def decode_with_flags(self, enc, checks, backend="xla"):
        data, bad = ecc.decode_parity8(enc, checks)
        # zeroing a detected-faulty byte IS this scheme's repair action
        return (_as_int8(data), jnp.sum(bad.astype(jnp.int32)),
                jnp.zeros((), jnp.int32))


class Secded72(Scheme):
    scheme_id = "secded72"
    paper_name = "ecc"
    needs_ecc_hw = True
    check_ratio = 1.0 / BLOCK

    def encode(self, q, backend="xla"):
        data = _as_bytes(q)
        return data, ecc.encode72(_blocks(data))

    def decode(self, enc, checks, backend="xla"):
        dec, _single, _double = ecc.decode72(_blocks(enc), checks)
        return _as_int8(dec.reshape(enc.shape))

    def decode_with_flags(self, enc, checks, backend="xla"):
        dec, single, double = ecc.decode72(_blocks(enc), checks)
        return (_as_int8(dec.reshape(enc.shape)),
                jnp.sum(single.astype(jnp.int32)),
                jnp.sum(double.astype(jnp.int32)))


class InPlace(Scheme):
    """The paper's contribution: check bits live in the non-informative bit 6
    of bytes 0..6 of every 8-byte block. Requires WOT-compliant weights."""

    scheme_id = "in-place"
    paper_name = "in-place"
    needs_ecc_hw = True
    requires_wot = True

    def encode(self, q, backend="xla"):
        be = get_backend(backend)
        data = _as_bytes(q)
        return be.encode64(_blocks(data)).reshape(data.shape), None

    def decode(self, enc, checks, backend="xla"):
        be = get_backend(backend)
        dec, _single, _double = be.decode64(_blocks(enc))
        return _as_int8(dec.reshape(enc.shape))

    def decode_with_flags(self, enc, checks, backend="xla"):
        be = get_backend(backend)
        dec, single, double = be.decode64(_blocks(enc))
        return (_as_int8(dec.reshape(enc.shape)),
                jnp.sum(single.astype(jnp.int32)),
                jnp.sum(double.astype(jnp.int32)))


SCHEMES: dict[str, Scheme] = {s.scheme_id: s for s in
                              (Faulty(), ParityZero(), Secded72(), InPlace())}

# Paper Table-2 row names and historical core.protect ids resolve too.
ALIASES = {"none": "faulty", "zero": "parity-zero", "parity8": "parity-zero",
           "ecc": "secded72", "inplace": "in-place"}


def get_scheme(name) -> Scheme:
    """Resolve a scheme id (or paper alias, or Scheme instance)."""
    if isinstance(name, Scheme):
        return name
    key = ALIASES.get(name, name)
    try:
        return SCHEMES[key]
    except KeyError:
        raise ValueError(f"unknown scheme {name!r}; one of "
                         f"{sorted(SCHEMES) + sorted(ALIASES)}") from None


def scheme_ids() -> tuple[str, ...]:
    return tuple(SCHEMES)
