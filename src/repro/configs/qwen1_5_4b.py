"""Qwen1.5-4B [hf:Qwen/Qwen1.5]: QKV bias."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
    n_heads=20, n_kv_heads=20, head_dim=128, d_ff=6912, vocab=151936,
    qkv_bias=True, microbatch=8,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                     head_dim=16, d_ff=128, vocab=512, microbatch=1)
