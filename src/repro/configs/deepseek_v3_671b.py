"""DeepSeek-V3 671B MoE [arXiv:2412.19437]: MLA with q_lora, 1 shared +
256 routed experts, top-8. (MTP head omitted — see DESIGN.md.)"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv_heads=128, head_dim=128, d_ff=2048, vocab=129280,
    n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
    qk_rope_dim=64, v_head_dim=128, microbatch=8, param_dtype="bfloat16",
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, head_dim=16,
                     d_ff=64, moe_d_ff=64, vocab=512, n_experts=8, top_k=2,
                     n_shared_experts=1, kv_lora_rank=32, q_lora_rank=32,
                     qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                     microbatch=1)
