"""Minitron-4B (pruned Nemotron) [arXiv:2407.14679]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv_heads=8, head_dim=128, d_ff=9216, vocab=256000,
    microbatch=8,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=128, vocab=512, microbatch=1)
