"""Mamba2-2.7B (SSD, attention-free) [arXiv:2405.21060]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
    microbatch=8,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, vocab=512, ssm_state=16,
                     ssm_head_dim=16, ssm_chunk=32, microbatch=1)
