"""Architecture registry: one module per assigned arch (+ paper's CNNs).

``get(name)`` returns the full-size ArchConfig; ``get_smoke(name)`` a reduced
same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig, SHAPES, ShapeConfig  # noqa: F401

ARCH_IDS = [
    "paligemma-3b", "minitron-4b", "phi3-medium-14b", "qwen1.5-4b",
    "deepseek-7b", "mamba2-2.7b", "whisper-base", "deepseek-v2-236b",
    "deepseek-v3-671b", "recurrentgemma-2b",
]

CNN_IDS = ["vgg16", "resnet18", "squeezenet"]


def _module(name: str):
    return importlib.import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).SMOKE
