"""RecurrentGemma-2B [arXiv:2402.19427]: RG-LRU + local attention, 1:2."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, head_dim=256, d_ff=7680, vocab=256000,
    lru_width=2560, attn_window=2048, ssm_conv_width=4,
    tie_embeddings=True, microbatch=8,
)

SMOKE = CONFIG.with_(n_layers=6, d_model=64, n_heads=2, n_kv_heads=1,
                     head_dim=32, d_ff=128, vocab=512, lru_width=64,
                     attn_window=32, microbatch=1)
