"""Whisper-base enc-dec backbone [arXiv:2212.04356]. Conv/audio frontend is a
stub: input_specs provides 1500 precomputed frame embeddings."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, head_dim=64, d_ff=2048, vocab=51865,
    norm="layer", enc_layers=6, enc_seq=1500, microbatch=4,
)

SMOKE = CONFIG.with_(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
                     enc_seq=32, microbatch=1)
