"""Phi-3-medium 14B [arXiv:2404.14219]: RoPE + SwiGLU + GQA."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=10, head_dim=128, d_ff=17920, vocab=100352,
    microbatch=16,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=128, vocab=512, microbatch=1)
