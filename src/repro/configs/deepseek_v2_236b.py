"""DeepSeek-V2 236B MoE [arXiv:2405.04434]: MLA (kv_lora=512), 2 shared +
160 routed experts, top-6."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv_heads=128, head_dim=128, d_ff=1536, vocab=102400,
    n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128, microbatch=8, param_dtype="bfloat16",
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, head_dim=16,
                     d_ff=64, moe_d_ff=64, vocab=512, n_experts=8, top_k=2,
                     n_shared_experts=1, kv_lora_rank=32, qk_nope_dim=16,
                     qk_rope_dim=8, v_head_dim=16, microbatch=1)
