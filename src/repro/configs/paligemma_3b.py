"""PaliGemma-3B backbone [arXiv:2407.07726]. SigLIP frontend is a stub:
input_specs provides 256 precomputed patch embeddings per image."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm", n_layers=18, d_model=2048,
    n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384, vocab=257216,
    n_patches=256, tie_embeddings=True, microbatch=8,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
                     head_dim=32, d_ff=128, vocab=512, n_patches=8,
                     microbatch=1)
