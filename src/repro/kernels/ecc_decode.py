"""Pallas TPU kernel: in-place SEC-DED (64,57,1) decode.

Streams ECC-encoded int8 weight blocks HBM->VMEM, computes the 7-bit Hsiao
syndrome per 64-bit block with VPU popcounts, corrects single-bit errors,
restores the non-informative sign bits, and writes decoded weights back — the
software analogue of the paper's Fig. 2 "swizzle + standard ECC logic" path.

Tiling: operand viewed as (nblk, 8) uint8. Block shape (BLK_N, 8): BLK_N
blocks per VMEM tile => BLK_N*8 bytes (default 4096 blocks = 32 KiB/tile,
well inside VMEM; bump for production). The two code tables (ROWMASK64,
COLS64) ride along as tiny replicated operands (Pallas forbids captured
consts). All ops are elementwise/reduction on the VPU — no MXU use, so this
kernel is purely memory-bound (see roofline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import ecc

DEFAULT_BLK_N = 4096


def _decode_tile(blocks, rowmask, cols):
    """Decode a (bn, 8) uint8 tile. Mirrors core.ecc.decode64 elementwise.

    rowmask: (7, 8) uint8 = ecc.ROWMASK64; cols: (8, 8) uint8 = COLS64_BYBYTE.
    """
    masked = blocks[:, None, :] & rowmask  # (bn, 7, 8)
    pc = jax.lax.population_count(masked).astype(jnp.uint32)
    parity = (jnp.sum(pc, axis=-1) & 1).astype(jnp.uint8)  # (bn, 7)
    rowval = (jnp.uint8(1) << jax.lax.broadcasted_iota(jnp.uint8, (7,), 0))
    syn = jnp.sum(parity * rowval, axis=-1).astype(jnp.uint8)  # (bn,)

    syn_pc = jax.lax.population_count(syn)
    single = (syn_pc & 1) == 1
    double = jnp.logical_and(syn != 0, jnp.logical_not(single))

    match = (syn[:, None, None] == cols).astype(jnp.uint8)  # (bn, 8, 8)
    bitval = (jnp.uint8(1) << jax.lax.broadcasted_iota(jnp.uint8, (8,), 0))
    flip = jnp.sum(match * bitval, axis=-1).astype(jnp.uint8)  # (bn, 8)
    corrected = jnp.where(single[:, None], blocks ^ flip, blocks)

    # sign-bit restore: bit6 := bit7 for bytes 0..6
    sign6 = (corrected >> 1) & np.uint8(1 << ecc.CHECK_BIT)
    restored = (corrected & np.uint8(0xBF)) | sign6
    keep_last = jax.lax.broadcasted_iota(jnp.int32, (8,), 0) == 7
    dec = jnp.where(keep_last, corrected, restored)

    flags = single.astype(jnp.uint8) | (double.astype(jnp.uint8) << 1)
    return dec, flags


def _kernel(enc_ref, rowmask_ref, cols_ref, dec_ref, flags_ref):
    dec, flags = _decode_tile(enc_ref[...], rowmask_ref[...], cols_ref[...])
    dec_ref[...] = dec
    flags_ref[...] = flags


@functools.partial(jax.jit, static_argnames=("blk_n", "interpret"))
def ecc_decode(enc: jnp.ndarray, *, blk_n: int = DEFAULT_BLK_N,
               interpret: bool = True):
    """(nblk, 8) uint8 -> (decoded (nblk, 8) uint8, flags (nblk,) uint8)."""
    nblk = enc.shape[0]
    blk_n = min(blk_n, nblk)
    assert nblk % blk_n == 0, (nblk, blk_n)
    grid = (nblk // blk_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_n, 8), lambda i: (i, 0)),
            pl.BlockSpec((7, 8), lambda i: (0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk_n, 8), lambda i: (i, 0)),
            pl.BlockSpec((blk_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, 8), jnp.uint8),
            jax.ShapeDtypeStruct((nblk,), jnp.uint8),
        ],
        interpret=interpret,
    )(enc, jnp.asarray(ecc.ROWMASK64), jnp.asarray(ecc.COLS64_BYBYTE))
