# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

# Kernels: ecc_decode, ecc_encode, ecc_qmatmul (fused decode+matmul),
# flash_attention, quant_throttle, throttle. Wrappers in ops.py; oracles in
# ref.py. All validated via interpret=True on CPU; TPU is the target.
