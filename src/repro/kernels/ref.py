"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ecc, wot


def ecc_decode_ref(enc_blocks: jnp.ndarray):
    """(nblk, 8) uint8 encoded -> (decoded uint8 (nblk,8), flags uint8 (nblk,)).

    flags bit0 = single-corrected, bit1 = double-detected.
    """
    dec, single, double = ecc.decode64(enc_blocks)
    flags = single.astype(jnp.uint8) | (double.astype(jnp.uint8) << 1)
    return dec, flags


def ecc_qmatmul_ref(a_q: jnp.ndarray, w_enc: jnp.ndarray) -> jnp.ndarray:
    """Decode-then-matmul oracle.

    a_q:   (M, K) int8 activations
    w_enc: (K, N) uint8 in-place-ECC-encoded int8 weights (blocks along N)
    -> (M, N) int32 accumulator.
    """
    k_dim, n_dim = w_enc.shape
    blocks = w_enc.reshape(k_dim, n_dim // ecc.BLOCK_BYTES, ecc.BLOCK_BYTES)
    dec, _, _ = ecc.decode64(blocks)
    w_q = jax.lax.bitcast_convert_type(dec.reshape(k_dim, n_dim), jnp.int8)
    return jax.lax.dot_general(
        a_q, w_q, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def throttle_ref(q_blocks: jnp.ndarray) -> jnp.ndarray:
    """(nblk, 8) int8 -> WOT-throttled (positions 0..6 clamped to [-64, 63])."""
    pos = jnp.arange(ecc.BLOCK_BYTES)
    clamped = jnp.clip(q_blocks, wot.WOT_LO, wot.WOT_HI)
    return jnp.where(pos == ecc.BLOCK_BYTES - 1, q_blocks, clamped).astype(jnp.int8)
