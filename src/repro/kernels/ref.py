"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ecc, wot


def ecc_decode_ref(enc_blocks: jnp.ndarray):
    """(nblk, 8) uint8 encoded -> (decoded uint8 (nblk,8), flags uint8 (nblk,)).

    flags bit0 = single-corrected, bit1 = double-detected.
    """
    dec, single, double = ecc.decode64(enc_blocks)
    flags = single.astype(jnp.uint8) | (double.astype(jnp.uint8) << 1)
    return dec, flags


def ecc_qmatmul_ref(a_q: jnp.ndarray, w_enc: jnp.ndarray) -> jnp.ndarray:
    """Decode-then-matmul oracle.

    a_q:   (M, K) int8 activations
    w_enc: (K, N) uint8 in-place-ECC-encoded int8 weights (blocks along N)
    -> (M, N) int32 accumulator.
    """
    k_dim, n_dim = w_enc.shape
    blocks = w_enc.reshape(k_dim, n_dim // ecc.BLOCK_BYTES, ecc.BLOCK_BYTES)
    dec, _, _ = ecc.decode64(blocks)
    w_q = jax.lax.bitcast_convert_type(dec.reshape(k_dim, n_dim), jnp.int8)
    return jax.lax.dot_general(
        a_q, w_q, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def abft_counts(a: jnp.ndarray, w: jnp.ndarray, acc: jnp.ndarray, *,
                rtol: float = 1e-4, atol: float = 1e-6):
    """ABFT checksum verification of ``acc`` against ``a @ w`` — the XLA
    mirror of the in-kernel check (``ecc_qmatmul(..., with_abft=True)``).

    Verifies the classic pair: ``acc`` row sums vs ``a @ rowsum(w)`` and
    column sums vs ``colsum(a) @ w``. Integer inputs compare BIT-EXACTLY
    (int32 modular arithmetic distributes, so reassociation is free);
    float inputs are tolerance-gated against an |a|·|w| checksum scale.

    a:   (M, K), w: (K, N), acc: (M, N) = the accumulator under test.
    -> ``(row_bad (M,) int32, col_bad (N,) int32)`` 0/1 mismatch flags.
    """
    dn = (((1,), (0,)), ((), ()))
    exact = jnp.issubdtype(acc.dtype, jnp.integer)
    dt = acc.dtype if exact else jnp.float32
    a_c, w_c = a.astype(dt), w.astype(dt)
    rs_acc = jnp.sum(acc, axis=1, keepdims=True)
    rs_ref = jax.lax.dot_general(a_c, jnp.sum(w_c, axis=1, keepdims=True),
                                 dn, preferred_element_type=dt)
    cs_acc = jnp.sum(acc, axis=0, keepdims=True)
    cs_ref = jax.lax.dot_general(jnp.sum(a_c, axis=0, keepdims=True), w_c,
                                 dn, preferred_element_type=dt)
    if exact:
        row_bad, col_bad = rs_acc != rs_ref, cs_acc != cs_ref
    else:
        a_abs, w_abs = jnp.abs(a_c), jnp.abs(w_c)
        rs_sc = jax.lax.dot_general(
            a_abs, jnp.sum(w_abs, axis=1, keepdims=True), dn,
            preferred_element_type=dt)
        cs_sc = jax.lax.dot_general(
            jnp.sum(a_abs, axis=0, keepdims=True), w_abs, dn,
            preferred_element_type=dt)
        row_bad = jnp.abs(rs_acc - rs_ref) > atol + rtol * rs_sc
        col_bad = jnp.abs(cs_acc - cs_ref) > atol + rtol * cs_sc
    return (row_bad[:, 0].astype(jnp.int32), col_bad[0, :].astype(jnp.int32))


def clamp_counts(y: jnp.ndarray, clamp):
    """Activation-range supervision oracle: clip ``y`` to ``[-c, +c]`` and
    count out-of-range hits per row. -> ``(clipped, hits (M,) int32)``."""
    c = jnp.asarray(clamp, jnp.float32)
    hits = jnp.sum((jnp.abs(y.astype(jnp.float32)) > c).astype(jnp.int32),
                   axis=-1)
    return jnp.clip(y, -c.astype(y.dtype), c.astype(y.dtype)), hits


def throttle_ref(q_blocks: jnp.ndarray) -> jnp.ndarray:
    """(nblk, 8) int8 -> WOT-throttled (positions 0..6 clamped to [-64, 63])."""
    pos = jnp.arange(ecc.BLOCK_BYTES)
    clamped = jnp.clip(q_blocks, wot.WOT_LO, wot.WOT_HI)
    return jnp.where(pos == ecc.BLOCK_BYTES - 1, q_blocks, clamped).astype(jnp.int8)
