"""Pallas TPU kernel: WOT throttling projection (paper §4.1, step 2).

Clamps positions 0..6 of every 8-value block of an int8 weight vector to
[-64, 63]; position 7 stays free. Elementwise VPU op, memory-bound; runs
after every QATT optimizer step so it must not add HBM round-trips beyond
one read + one write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import wot

DEFAULT_BLK_N = 4096


def _kernel(q_ref, out_ref):
    q = q_ref[...]  # (bn, 8) int8
    pos = jax.lax.broadcasted_iota(jnp.int32, q.shape, dimension=1)
    clamped = jnp.clip(q, wot.WOT_LO, wot.WOT_HI)
    out_ref[...] = jnp.where(pos == 7, q, clamped).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("blk_n", "interpret"))
def throttle(q_blocks: jnp.ndarray, *, blk_n: int = DEFAULT_BLK_N,
             interpret: bool = True) -> jnp.ndarray:
    """(nblk, 8) int8 -> WOT-throttled (nblk, 8) int8."""
    nblk = q_blocks.shape[0]
    blk_n = min(blk_n, nblk)
    assert nblk % blk_n == 0
    return pl.pallas_call(
        _kernel,
        grid=(nblk // blk_n,),
        in_specs=[pl.BlockSpec((blk_n, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((blk_n, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, 8), jnp.int8),
        interpret=interpret,
    )(q_blocks)
