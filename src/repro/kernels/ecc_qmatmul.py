"""Pallas TPU kernel: FUSED in-place-ECC decode + matmul (beyond-paper).

The paper keeps decode in hardware. On TPU we instead keep weights
ECC-encoded *at rest in HBM* and decode each weight tile in VMEM on its way
to the MXU. Protection then costs zero HBM space AND zero extra HBM traffic;
the VPU bit-twiddling overlaps with MXU matmul work on neighbouring tiles.

Layout: W (K, N) int8 row-major -> 8-byte ECC blocks run along N, so any
(BK, BN) tile with BN % 8 == 0 contains whole blocks and decodes locally.

Grid (ceil(N/BN), ceil(M/BM), ceil(K/BK)) — K innermost so each output
tile's accumulation visits are CONSECUTIVE (a TPU output block only
persists across back-to-back grid steps; the old M-outermost order kept
that property too, this one adds decode reuse). A VMEM scratch holds the
decoded K-strip for the current N tile: the first M tile decodes each
(BK, BN) weight tile into its strip slot, every later M tile reuses it —
each weight tile is ECC-decoded ONCE per (N, K) tile instead of
``ceil(M/BM)`` times, so the VPU decode work no longer scales with batch.
The N grid dim is marked ``parallel`` (``dimension_semantics``) so Mosaic
can pipeline/split independent output column strips; M and K carry the
scratch/accumulation dependences and stay ``arbitrary``. Edge tiles are
masked (activation columns past K zeroed, flag counts restricted to real
blocks) so production shapes need no divisibility beyond N % 8 == 0.
Default tiles 128x128 with full-K strips (bk=0): VMEM footprint = BM*K (a)
+ K*BN (w enc) + ~K*BN (decoded strip) + BM*BN*4 (acc) — 16+16+16+64 KiB
per 128-wide strip of a K=128 layer. The decoded strip is ~K*BN bytes
REGARDLESS of ``bk`` (decode-once needs the whole K strip resident), so
for huge-K layers shrink ``bn`` to bound VMEM; ``bk`` only sizes the a/w
staging blocks.

Three activation paths share the kernel:

* int8 ``a`` -> int32 accumulator (the raw quantized MXU path);
* int8 ``a`` + ``a_scale`` -> the fused REQUANTIZE EPILOGUE: the int32
  accumulator is scaled by ``a_scale * w_scale`` (optionally after an int32
  bias add) and cast to ``out_dtype`` (bf16 default) in VMEM — int8 MXU
  throughput plus halved output traffic, a drop-in replacement for the
  float path in quantized serving;
* float ``a`` (bf16/f32, requires ``w_scale``) -> the decoded tile is
  dequantized in VMEM (``(q * w_scale).astype(a.dtype)``) and the matmul
  accumulates f32 — the value path is identical to decode-then-matmul, so
  fused serving stays numerically identical to the per-step baseline.

``with_flags=True`` additionally returns ``(corrected, due)`` int32 counts
over all weight blocks. Counting happens inside the same predicated block
as the decode itself (first M tile only), so the flag totals double as a
runtime witness that each weight tile decodes exactly once per (N, K) tile.

``with_abft=True`` adds algorithm-based fault tolerance over the COMPUTE
itself (FT-CNN-style checksums): for every (BM, BN) partial product the
kernel verifies the accumulator's row sums against ``a @ rowsum(w)`` and
its column sums against ``colsum(a) @ w`` — the classic ABFT pair, done
per K-tile so multi-``kk`` grids verify each partial dot. On the int8 and
requantize paths both sides live in int32 modular arithmetic, so the
comparison is BIT-EXACT (zero false positives by construction); the float
path is tolerance-gated (``ABFT_RTOL`` against an |a|·|w| checksum scale,
so reordering noise never fires but exponent-scale SDCs do). Mismatch
counts come back per output row (per-slot attributable: decode M = batch)
plus a column-check total. ``clamp=<absmax>`` fuses Geissler-style
activation-range supervision into the same epilogue: the f32 result is
clipped to ``[-clamp, +clamp]`` and out-of-range hits are counted per row
alongside the ABFT mismatches. Both knobs default off and the disabled
kernel is bit-identical to the unguarded one. ``fault_bits`` XORs a bit
pattern into accumulator element (0, 0) of the first tile — a
deterministic in-kernel SDC for tests and campaign calibration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ecc
from . import ecc_decode

# float-path ABFT tolerance: checksum reordering noise is ~K * eps(f32)
# relative to the |a|·|w| scale (~1e-5 at K=128); 1e-4 leaves a decade of
# margin while still firing on any exponent-scale corruption.
ABFT_RTOL = 1e-4
ABFT_ATOL = 1e-6


def _kernel(*refs, dims, path, has_bias, has_clamp, with_abft, fault_bits):
    m, n, k = dims
    track = with_abft or has_clamp
    it = iter(refs)
    a_ref, w_ref, scale_ref = next(it), next(it), next(it)
    ascale_ref = next(it) if path == "requant" else None
    bias_ref = next(it) if has_bias else None
    clamp_ref = next(it) if has_clamp else None
    rowmask_ref, cols_ref = next(it), next(it)
    out_ref, flags_ref = next(it), next(it)
    abft_rows_ref = next(it) if track else None
    abft_cols_ref = next(it) if track else None
    wdec_ref = next(it)
    j, i, kk = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    bm, bk = a_ref.shape

    @pl.when(jnp.logical_and(i == 0, kk == 0))
    def _init_flags():
        flags_ref[...] = jnp.zeros_like(flags_ref)

    if track:
        # per-(j, i) row counters accumulate over kk; the column-check
        # counter is per j like the decode flags (j outermost -> both
        # revisit patterns are consecutive, TPU-legal accumulation).
        @pl.when(kk == 0)
        def _init_abft_rows():
            abft_rows_ref[...] = jnp.zeros_like(abft_rows_ref)

        @pl.when(jnp.logical_and(i == 0, kk == 0))
        def _init_abft_cols():
            abft_cols_ref[...] = jnp.zeros_like(abft_cols_ref)

    # decode ONCE per (N, K) tile — the first M tile fills this K-strip slot
    # of the VMEM scratch, every later M tile reuses it. Flag counting lives
    # inside the same predicate (each real block counted exactly once,
    # M-grid independent by construction: re-decoding would multiply the
    # counts by the M tile count).
    @pl.when(i == 0)
    def _decode():
        w_enc = w_ref[...]  # (BK, BN) uint8, ECC-encoded
        bk2, bn = w_enc.shape
        dec, fl = ecc_decode._decode_tile(
            w_enc.reshape(bk2 * bn // 8, 8), rowmask_ref[...], cols_ref[...])
        wdec_ref[pl.ds(kk * bk2, bk2), :] = jax.lax.bitcast_convert_type(
            dec.reshape(bk2, bn), jnp.int8)
        blk = fl.reshape(bk2, bn // 8)
        rowv = (kk * bk2 +
                jax.lax.broadcasted_iota(jnp.int32, blk.shape, 0)) < k
        colv = (j * bn // 8 +
                jax.lax.broadcasted_iota(jnp.int32, blk.shape, 1)) < n // 8
        valid = jnp.logical_and(rowv, colv)
        single = jnp.logical_and((blk & 1) == 1, valid)
        double = jnp.logical_and((blk & 2) == 2, valid)
        flags_ref[0, 0] += jnp.sum(single.astype(jnp.int32))
        flags_ref[0, 1] += jnp.sum(double.astype(jnp.int32))

    a = a_ref[...]  # (BM, BK)
    # mask activation columns past K so edge tiles contribute nothing
    kcol = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
    a = jnp.where(kcol < k, a, jnp.zeros_like(a))
    if with_abft:
        # also zero activation rows past M: decoded weight bytes are always
        # finite int8 so garbage columns cancel in the checksum identities,
        # but float-path activation padding could be NaN and would poison
        # the column check. Valid output rows are unaffected.
        mrow = (i * bm +
                jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 0)) < m
        a = jnp.where(mrow, a, jnp.zeros_like(a))
    w_q = wdec_ref[pl.ds(kk * bk, bk), :]
    dn = (((1,), (0,)), ((), ()))

    rowv = (i * bm +
            jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)) < m
    bn_cur = out_ref.shape[-1]
    colv = (j * bn_cur +
            jax.lax.broadcasted_iota(jnp.int32, (1, bn_cur), 1)) < n

    def _flip(partial):
        """XOR fault_bits into element (0, 0) of the first tile's partial
        product — a deterministic injected SDC for tests/calibration."""
        hit = jnp.logical_and(
            jax.lax.broadcasted_iota(jnp.int32, partial.shape, 0) == 0,
            jax.lax.broadcasted_iota(jnp.int32, partial.shape, 1) == 0)
        hit = jnp.logical_and(
            hit, jnp.logical_and(j == 0, jnp.logical_and(i == 0, kk == 0)))
        if partial.dtype == jnp.int32:
            return jnp.where(hit, partial ^ jnp.int32(fault_bits), partial)
        bits = jax.lax.bitcast_convert_type(partial, jnp.int32)
        flipped = jax.lax.bitcast_convert_type(
            bits ^ jnp.int32(fault_bits), partial.dtype)
        return jnp.where(hit, flipped, partial)

    def _abft(partial, a_chk, w_chk, exact):
        """Verify this K-tile's partial product against the ABFT pair:
        row sums vs a @ rowsum(w), column sums vs colsum(a) @ w."""
        dt = partial.dtype
        rs_acc = jnp.sum(partial, axis=1, keepdims=True)              # (BM,1)
        rs_ref = jax.lax.dot_general(
            a_chk, jnp.sum(w_chk, axis=1, keepdims=True), dn,
            preferred_element_type=dt)
        cs_acc = jnp.sum(partial, axis=0, keepdims=True)              # (1,BN)
        cs_ref = jax.lax.dot_general(
            jnp.sum(a_chk, axis=0, keepdims=True), w_chk, dn,
            preferred_element_type=dt)
        if exact:
            row_bad = rs_acc != rs_ref
            col_bad = cs_acc != cs_ref
        else:
            a_abs, w_abs = jnp.abs(a_chk), jnp.abs(w_chk)
            rs_sc = jax.lax.dot_general(
                a_abs, jnp.sum(w_abs, axis=1, keepdims=True), dn,
                preferred_element_type=dt)
            cs_sc = jax.lax.dot_general(
                jnp.sum(a_abs, axis=0, keepdims=True), w_abs, dn,
                preferred_element_type=dt)
            row_bad = jnp.abs(rs_acc - rs_ref) > ABFT_ATOL + ABFT_RTOL * rs_sc
            col_bad = jnp.abs(cs_acc - cs_ref) > ABFT_ATOL + ABFT_RTOL * cs_sc
        abft_rows_ref[0, :, 0:1] += jnp.logical_and(
            row_bad, rowv).astype(jnp.int32)
        abft_cols_ref[0, 0] += jnp.sum(
            jnp.logical_and(col_bad, colv).astype(jnp.int32))

    def _clamp(res):
        """Geissler-style range supervision: clip the f32 epilogue output
        to ±clamp and count (valid-masked) out-of-range hits per row."""
        c = clamp_ref[0, 0]
        hit = jnp.abs(res) > c
        hit = jnp.logical_and(hit, jnp.logical_and(rowv, colv))
        abft_rows_ref[0, :, 1:2] += jnp.sum(
            hit.astype(jnp.int32), axis=1, keepdims=True)
        return jnp.clip(res, -c, c)

    if path == "float":
        w = (w_q.astype(jnp.float32) * scale_ref[0, 0]).astype(a.dtype)
        partial = jax.lax.dot_general(
            a, w, dimension_numbers=dn, preferred_element_type=jnp.float32)
        if fault_bits:
            partial = _flip(partial)
        if with_abft:
            _abft(partial, a.astype(jnp.float32), w.astype(jnp.float32),
                  exact=False)

        @pl.when(kk == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        out_ref[...] += partial
        if has_clamp:
            @pl.when(kk == pl.num_programs(2) - 1)
            def _clamp_final():
                out_ref[...] = _clamp(out_ref[...])
    elif path == "int8":
        partial = jax.lax.dot_general(
            a, w_q, dimension_numbers=dn, preferred_element_type=jnp.int32)
        if fault_bits:
            partial = _flip(partial)
        if with_abft:
            _abft(partial, a.astype(jnp.int32), w_q.astype(jnp.int32),
                  exact=True)

        @pl.when(kk == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        out_ref[...] += partial
    else:  # requant epilogue: full-K tile (single kk), exact int32 acc
        acc = jax.lax.dot_general(
            a, w_q, dimension_numbers=dn, preferred_element_type=jnp.int32)
        if fault_bits:
            acc = _flip(acc)
        if with_abft:
            _abft(acc, a.astype(jnp.int32), w_q.astype(jnp.int32),
                  exact=True)
        if has_bias:
            acc = acc + bias_ref[...]  # (1, BN) int32, accumulator scale
        s = ascale_ref[...] * scale_ref[0, 0]  # (BM, 1) f32
        res = acc.astype(jnp.float32) * s
        if has_clamp:
            res = _clamp(res)
        out_ref[...] = res.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "with_flags", "out_dtype",
                                             "with_abft", "fault_bits"))
def ecc_qmatmul(a: jnp.ndarray, w_enc: jnp.ndarray, w_scale=None, *,
                a_scale=None, bias=None, out_dtype=None,
                bm: int = 128, bn: int = 128, bk: int = 0,
                interpret: bool = True, with_flags: bool = False,
                with_abft: bool = False, clamp=None, fault_bits: int = 0):
    """``a (M,K) @ decode(w_enc (K,N) uint8)``, decode fused into the matmul.

    int8 ``a``   -> (M, N) int32 accumulator (``w_scale`` ignored).
    int8 ``a`` + ``a_scale`` (per-row ``(M,)``/``(M,1)`` or scalar, requires
                    ``w_scale``) -> the fused requantize epilogue:
                    ``(acc [+ bias]) * (a_scale * w_scale)`` cast to
                    ``out_dtype`` (default bf16) in VMEM. ``bias`` is an
                    optional (N,) int32 at the accumulator scale. The tile is
                    full-K (``bk`` ignored) so the int32 accumulation is one
                    exact MXU pass — bit-identical to quantize->decode->
                    matmul done in XLA.
    float ``a``  -> (M, N) f32 = ``a @ (decode(w_enc) * w_scale)`` — requires
                    ``w_scale``; pass ``bk=0`` (default: full K per tile) to
                    keep the accumulation order identical to one XLA dot.
    with_flags   -> also return ``flags (2,) int32``: (#single-corrected,
                    #double-detected) over all weight blocks.
    with_abft    -> verify ABFT checksums in-kernel (bit-exact on the int8/
                    requant paths, tolerance-gated on float). Adds a final
                    return value ``(rows, col_mm)``: ``rows (M, 2) int32``
                    is per-output-row (row-checksum mismatches, clamp hits)
                    and ``col_mm`` the scalar column-checksum mismatch
                    count.
    clamp        -> f32 absmax bound: the requantize/float epilogue output
                    is clipped to ``[-clamp, +clamp]`` with hits counted in
                    the ABFT rows channel (returned even when ``with_abft``
                    is False; the mismatch column is then all zero). Not
                    supported on the raw int8-accumulator path.
    fault_bits   -> nonzero XORs the pattern into accumulator element
                    (0, 0) of the first tile (deterministic injected SDC).

    Tiles need not divide (M, N, K) — edge tiles are masked. N % 8 == 0 is
    structural (ECC blocks run along N). The first M tile decodes each
    weight tile into a K-strip VMEM scratch that later M tiles reuse, so
    per-call decode work is ceil(N/BN) * ceil(K/BK) tiles — independent of
    M.
    """
    m, k = a.shape
    k2, n = w_enc.shape
    assert k == k2 and n % 8 == 0, (a.shape, w_enc.shape)
    float_path = jnp.issubdtype(a.dtype, jnp.floating)
    if float_path and w_scale is None:
        raise ValueError("float activations need w_scale for the in-VMEM "
                         "dequantization")
    if float_path and a_scale is not None:
        raise ValueError("a_scale is the int8 requantize epilogue; float "
                         "activations carry their own scale")
    requant = (not float_path) and a_scale is not None
    if requant and w_scale is None:
        raise ValueError("the requantize epilogue needs w_scale")
    if bias is not None and not requant:
        raise ValueError("bias is only fused by the requantize epilogue")
    path = "float" if float_path else ("requant" if requant else "int8")
    has_clamp = clamp is not None
    if has_clamp and path == "int8":
        raise ValueError("clamp guards the f32 epilogue output; the raw "
                         "int8-accumulator path has none")
    track = with_abft or has_clamp
    if bk == 0 or requant:
        bk = k  # full-K tile: one dot per output tile, XLA-identical order
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    bn = max(8, bn - bn % 8)  # whole ECC blocks per tile
    grid = (pl.cdiv(n, bn), pl.cdiv(m, bm), pl.cdiv(k, bk))
    scale = jnp.asarray(w_scale if w_scale is not None else 1.0,
                        jnp.float32).reshape(1, 1)
    if path == "float":
        out_dt = jnp.float32
    elif path == "int8":
        out_dt = jnp.int32
    else:
        out_dt = jnp.dtype(out_dtype) if out_dtype is not None else jnp.bfloat16
    kern = functools.partial(_kernel, dims=(m, n, k), path=path,
                             has_bias=bias is not None, has_clamp=has_clamp,
                             with_abft=with_abft, fault_bits=int(fault_bits))

    inputs = [a, w_enc, scale]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda j, i, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda j, i, kk: (kk, j)),
        pl.BlockSpec((1, 1), lambda j, i, kk: (0, 0)),
    ]
    if requant:
        ascale = jnp.broadcast_to(
            jnp.asarray(a_scale, jnp.float32).reshape(-1, 1)
            if jnp.ndim(a_scale) else
            jnp.asarray(a_scale, jnp.float32).reshape(1, 1), (m, 1))
        inputs.append(ascale)
        in_specs.append(pl.BlockSpec((bm, 1), lambda j, i, kk: (i, 0)))
        if bias is not None:
            inputs.append(jnp.asarray(bias, jnp.int32).reshape(1, n))
            in_specs.append(pl.BlockSpec((1, bn), lambda j, i, kk: (0, j)))
    if has_clamp:
        inputs.append(jnp.asarray(clamp, jnp.float32).reshape(1, 1))
        in_specs.append(pl.BlockSpec((1, 1), lambda j, i, kk: (0, 0)))
    inputs += [jnp.asarray(ecc.ROWMASK64), jnp.asarray(ecc.COLS64_BYBYTE)]
    in_specs += [
        pl.BlockSpec((7, 8), lambda j, i, kk: (0, 0)),
        pl.BlockSpec((8, 8), lambda j, i, kk: (0, 0)),
    ]

    out_specs = [
        pl.BlockSpec((bm, bn), lambda j, i, kk: (i, j)),
        pl.BlockSpec((1, 2), lambda j, i, kk: (j, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((m, n), out_dt),
        jax.ShapeDtypeStruct((grid[0], 2), jnp.int32),
    ]
    if track:
        out_specs += [
            pl.BlockSpec((1, bm, 2), lambda j, i, kk: (j, i, 0)),
            pl.BlockSpec((1, 2), lambda j, i, kk: (j, 0)),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((grid[0], m, 2), jnp.int32),
            jax.ShapeDtypeStruct((grid[0], 2), jnp.int32),
        ]

    res = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((grid[2] * bk, bn), jnp.int8)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*inputs)
    out, flags = res[0], res[1]
    outs = (out,)
    if with_flags:
        outs += (flags.sum(axis=0),)
    if track:
        # per-row (mismatch, clamp-hit) counts summed over N strips, plus
        # the column-check mismatch total (not row-attributable).
        outs += ((res[2].sum(axis=0), res[3].sum(axis=0)[0]),)
    return outs if len(outs) > 1 else out
