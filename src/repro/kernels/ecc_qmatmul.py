"""Pallas TPU kernel: FUSED in-place-ECC decode + matmul (beyond-paper).

The paper keeps decode in hardware. On TPU we instead keep weights
ECC-encoded *at rest in HBM* and decode each weight tile in VMEM on its way
to the MXU. Protection then costs zero HBM space AND zero extra HBM traffic;
the VPU bit-twiddling overlaps with MXU matmul work on neighbouring tiles.

Layout: W (K, N) int8 row-major -> 8-byte ECC blocks run along N, so any
(BK, BN) tile with BN % 8 == 0 contains whole blocks and decodes locally.

Grid (ceil(N/BN), ceil(M/BM), ceil(K/BK)) — K innermost so each output
tile's accumulation visits are CONSECUTIVE (a TPU output block only
persists across back-to-back grid steps; the old M-outermost order kept
that property too, this one adds decode reuse). A VMEM scratch holds the
decoded K-strip for the current N tile: the first M tile decodes each
(BK, BN) weight tile into its strip slot, every later M tile reuses it —
each weight tile is ECC-decoded ONCE per (N, K) tile instead of
``ceil(M/BM)`` times, so the VPU decode work no longer scales with batch.
The N grid dim is marked ``parallel`` (``dimension_semantics``) so Mosaic
can pipeline/split independent output column strips; M and K carry the
scratch/accumulation dependences and stay ``arbitrary``. Edge tiles are
masked (activation columns past K zeroed, flag counts restricted to real
blocks) so production shapes need no divisibility beyond N % 8 == 0.
Default tiles 128x128 with full-K strips (bk=0): VMEM footprint = BM*K (a)
+ K*BN (w enc) + ~K*BN (decoded strip) + BM*BN*4 (acc) — 16+16+16+64 KiB
per 128-wide strip of a K=128 layer. The decoded strip is ~K*BN bytes
REGARDLESS of ``bk`` (decode-once needs the whole K strip resident), so
for huge-K layers shrink ``bn`` to bound VMEM; ``bk`` only sizes the a/w
staging blocks.

Three activation paths share the kernel:

* int8 ``a`` -> int32 accumulator (the raw quantized MXU path);
* int8 ``a`` + ``a_scale`` -> the fused REQUANTIZE EPILOGUE: the int32
  accumulator is scaled by ``a_scale * w_scale`` (optionally after an int32
  bias add) and cast to ``out_dtype`` (bf16 default) in VMEM — int8 MXU
  throughput plus halved output traffic, a drop-in replacement for the
  float path in quantized serving;
* float ``a`` (bf16/f32, requires ``w_scale``) -> the decoded tile is
  dequantized in VMEM (``(q * w_scale).astype(a.dtype)``) and the matmul
  accumulates f32 — the value path is identical to decode-then-matmul, so
  fused serving stays numerically identical to the per-step baseline.

``with_flags=True`` additionally returns ``(corrected, due)`` int32 counts
over all weight blocks. Counting happens inside the same predicated block
as the decode itself (first M tile only), so the flag totals double as a
runtime witness that each weight tile decodes exactly once per (N, K) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ecc
from . import ecc_decode


def _kernel(*refs, dims, path, has_bias):
    m, n, k = dims
    if path == "requant":
        (a_ref, w_ref, scale_ref, ascale_ref) = refs[:4]
        bias_ref = refs[4] if has_bias else None
        rowmask_ref, cols_ref, out_ref, flags_ref, wdec_ref = refs[4 + has_bias:]
    else:
        (a_ref, w_ref, scale_ref, rowmask_ref, cols_ref,
         out_ref, flags_ref, wdec_ref) = refs
    j, i, kk = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    bm, bk = a_ref.shape

    @pl.when(jnp.logical_and(i == 0, kk == 0))
    def _init_flags():
        flags_ref[...] = jnp.zeros_like(flags_ref)

    # decode ONCE per (N, K) tile — the first M tile fills this K-strip slot
    # of the VMEM scratch, every later M tile reuses it. Flag counting lives
    # inside the same predicate (each real block counted exactly once,
    # M-grid independent by construction: re-decoding would multiply the
    # counts by the M tile count).
    @pl.when(i == 0)
    def _decode():
        w_enc = w_ref[...]  # (BK, BN) uint8, ECC-encoded
        bk2, bn = w_enc.shape
        dec, fl = ecc_decode._decode_tile(
            w_enc.reshape(bk2 * bn // 8, 8), rowmask_ref[...], cols_ref[...])
        wdec_ref[pl.ds(kk * bk2, bk2), :] = jax.lax.bitcast_convert_type(
            dec.reshape(bk2, bn), jnp.int8)
        blk = fl.reshape(bk2, bn // 8)
        rowv = (kk * bk2 +
                jax.lax.broadcasted_iota(jnp.int32, blk.shape, 0)) < k
        colv = (j * bn // 8 +
                jax.lax.broadcasted_iota(jnp.int32, blk.shape, 1)) < n // 8
        valid = jnp.logical_and(rowv, colv)
        single = jnp.logical_and((blk & 1) == 1, valid)
        double = jnp.logical_and((blk & 2) == 2, valid)
        flags_ref[0, 0] += jnp.sum(single.astype(jnp.int32))
        flags_ref[0, 1] += jnp.sum(double.astype(jnp.int32))

    a = a_ref[...]  # (BM, BK)
    # mask activation columns past K so edge tiles contribute nothing
    kcol = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
    a = jnp.where(kcol < k, a, jnp.zeros_like(a))
    w_q = wdec_ref[pl.ds(kk * bk, bk), :]

    if path == "float":
        w = (w_q.astype(jnp.float32) * scale_ref[0, 0]).astype(a.dtype)

        @pl.when(kk == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        out_ref[...] += jax.lax.dot_general(
            a, w, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    elif path == "int8":
        @pl.when(kk == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        out_ref[...] += jax.lax.dot_general(
            a, w_q, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    else:  # requant epilogue: full-K tile (single kk), exact int32 acc
        acc = jax.lax.dot_general(
            a, w_q, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        if has_bias:
            acc = acc + bias_ref[...]  # (1, BN) int32, accumulator scale
        s = ascale_ref[...] * scale_ref[0, 0]  # (BM, 1) f32
        out_ref[...] = (acc.astype(jnp.float32) * s).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "with_flags", "out_dtype"))
def ecc_qmatmul(a: jnp.ndarray, w_enc: jnp.ndarray, w_scale=None, *,
                a_scale=None, bias=None, out_dtype=None,
                bm: int = 128, bn: int = 128, bk: int = 0,
                interpret: bool = True, with_flags: bool = False):
    """``a (M,K) @ decode(w_enc (K,N) uint8)``, decode fused into the matmul.

    int8 ``a``   -> (M, N) int32 accumulator (``w_scale`` ignored).
    int8 ``a`` + ``a_scale`` (per-row ``(M,)``/``(M,1)`` or scalar, requires
                    ``w_scale``) -> the fused requantize epilogue:
                    ``(acc [+ bias]) * (a_scale * w_scale)`` cast to
                    ``out_dtype`` (default bf16) in VMEM. ``bias`` is an
                    optional (N,) int32 at the accumulator scale. The tile is
                    full-K (``bk`` ignored) so the int32 accumulation is one
                    exact MXU pass — bit-identical to quantize->decode->
                    matmul done in XLA.
    float ``a``  -> (M, N) f32 = ``a @ (decode(w_enc) * w_scale)`` — requires
                    ``w_scale``; pass ``bk=0`` (default: full K per tile) to
                    keep the accumulation order identical to one XLA dot.
    with_flags   -> also return ``flags (2,) int32``: (#single-corrected,
                    #double-detected) over all weight blocks.

    Tiles need not divide (M, N, K) — edge tiles are masked. N % 8 == 0 is
    structural (ECC blocks run along N). The first M tile decodes each
    weight tile into a K-strip VMEM scratch that later M tiles reuse, so
    per-call decode work is ceil(N/BN) * ceil(K/BK) tiles — independent of
    M.
    """
    m, k = a.shape
    k2, n = w_enc.shape
    assert k == k2 and n % 8 == 0, (a.shape, w_enc.shape)
    float_path = jnp.issubdtype(a.dtype, jnp.floating)
    if float_path and w_scale is None:
        raise ValueError("float activations need w_scale for the in-VMEM "
                         "dequantization")
    if float_path and a_scale is not None:
        raise ValueError("a_scale is the int8 requantize epilogue; float "
                         "activations carry their own scale")
    requant = (not float_path) and a_scale is not None
    if requant and w_scale is None:
        raise ValueError("the requantize epilogue needs w_scale")
    if bias is not None and not requant:
        raise ValueError("bias is only fused by the requantize epilogue")
    path = "float" if float_path else ("requant" if requant else "int8")
    if bk == 0 or requant:
        bk = k  # full-K tile: one dot per output tile, XLA-identical order
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    bn = max(8, bn - bn % 8)  # whole ECC blocks per tile
    grid = (pl.cdiv(n, bn), pl.cdiv(m, bm), pl.cdiv(k, bk))
    scale = jnp.asarray(w_scale if w_scale is not None else 1.0,
                        jnp.float32).reshape(1, 1)
    if path == "float":
        out_dt = jnp.float32
    elif path == "int8":
        out_dt = jnp.int32
    else:
        out_dt = jnp.dtype(out_dtype) if out_dtype is not None else jnp.bfloat16
    kern = functools.partial(_kernel, dims=(m, n, k), path=path,
                             has_bias=bias is not None)

    inputs = [a, w_enc, scale]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda j, i, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda j, i, kk: (kk, j)),
        pl.BlockSpec((1, 1), lambda j, i, kk: (0, 0)),
    ]
    if requant:
        ascale = jnp.broadcast_to(
            jnp.asarray(a_scale, jnp.float32).reshape(-1, 1)
            if jnp.ndim(a_scale) else
            jnp.asarray(a_scale, jnp.float32).reshape(1, 1), (m, 1))
        inputs.append(ascale)
        in_specs.append(pl.BlockSpec((bm, 1), lambda j, i, kk: (i, 0)))
        if bias is not None:
            inputs.append(jnp.asarray(bias, jnp.int32).reshape(1, n))
            in_specs.append(pl.BlockSpec((1, bn), lambda j, i, kk: (0, j)))
    inputs += [jnp.asarray(ecc.ROWMASK64), jnp.asarray(ecc.COLS64_BYBYTE)]
    in_specs += [
        pl.BlockSpec((7, 8), lambda j, i, kk: (0, 0)),
        pl.BlockSpec((8, 8), lambda j, i, kk: (0, 0)),
    ]

    out, flags = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bm, bn), lambda j, i, kk: (i, j)),
            pl.BlockSpec((1, 2), lambda j, i, kk: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), out_dt),
            jax.ShapeDtypeStruct((grid[0], 2), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((grid[2] * bk, bn), jnp.int8)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*inputs)
    if with_flags:
        return out, flags.sum(axis=0)
    return out
