"""Pallas TPU kernel: FUSED in-place-ECC decode + matmul (beyond-paper).

The paper keeps decode in hardware. On TPU we instead keep weights
ECC-encoded *at rest in HBM* and decode each weight tile in VMEM on its way
to the MXU. Protection then costs zero HBM space AND zero extra HBM traffic;
the VPU bit-twiddling overlaps with MXU matmul work on neighbouring tiles.

Layout: W (K, N) int8 row-major -> 8-byte ECC blocks run along N, so any
(BK, BN) tile with BN % 8 == 0 contains whole blocks and decodes locally.

Grid (ceil(M/BM), ceil(N/BN), ceil(K/BK)), K innermost; edge tiles are
masked (activation columns past K zeroed, flag counts restricted to real
blocks) so production shapes need no divisibility beyond N % 8 == 0.
Default tiles 128x128x128: MXU-aligned (multiples of 128 in every matmul
dim), VMEM footprint per step = BM*BK (a) + BK*BN (w, uint8) + BM*BN*4
(acc) = 16+16+64 KiB for the int8 path.

Two activation paths share the kernel:

* int8 ``a`` -> int32 accumulator (the quantized-serving MXU path);
* float ``a`` (bf16/f32, requires ``w_scale``) -> the decoded tile is
  dequantized in VMEM (``(q * w_scale).astype(a.dtype)``) and the matmul
  accumulates f32 — the value path is identical to decode-then-matmul, so
  fused serving stays numerically identical to the per-step baseline.

``with_flags=True`` additionally returns ``(corrected, due)`` int32 counts
over all weight blocks (each block counted ONCE, on the first M tile) — the
per-layer fault-accounting side channel the serving step surfaces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ecc
from . import ecc_decode


def _kernel(a_ref, w_ref, scale_ref, rowmask_ref, cols_ref, out_ref,
            flags_ref, *, dims, float_path):
    m, n, k = dims
    i, j, kk = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(jnp.logical_and(jnp.logical_and(i == 0, j == 0), kk == 0))
    def _init_flags():
        flags_ref[...] = jnp.zeros_like(flags_ref)

    @pl.when(kk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]  # (BM, BK)
    bm, bk = a.shape
    # mask activation columns past K so edge tiles contribute nothing
    kcol = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
    a = jnp.where(kcol < k, a, jnp.zeros_like(a))

    w_enc = w_ref[...]  # (BK, BN) uint8, ECC-encoded
    bk2, bn = w_enc.shape
    dec, fl = ecc_decode._decode_tile(
        w_enc.reshape(bk2 * bn // 8, 8), rowmask_ref[...], cols_ref[...])

    # per-block flag counts: each weight block counted once (first M tile),
    # restricted to real (non-edge-padding) blocks
    @pl.when(i == 0)
    def _count():
        blk = fl.reshape(bk2, bn // 8)
        rowv = (kk * bk2 +
                jax.lax.broadcasted_iota(jnp.int32, blk.shape, 0)) < k
        colv = (j * bn // 8 +
                jax.lax.broadcasted_iota(jnp.int32, blk.shape, 1)) < n // 8
        valid = jnp.logical_and(rowv, colv)
        single = jnp.logical_and((blk & 1) == 1, valid)
        double = jnp.logical_and((blk & 2) == 2, valid)
        flags_ref[0, 0] += jnp.sum(single.astype(jnp.int32))
        flags_ref[0, 1] += jnp.sum(double.astype(jnp.int32))

    w_q = jax.lax.bitcast_convert_type(dec.reshape(bk2, bn), jnp.int8)
    if float_path:
        w = (w_q.astype(jnp.float32) * scale_ref[0, 0]).astype(a.dtype)
        out_ref[...] += jax.lax.dot_general(
            a, w, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        out_ref[...] += jax.lax.dot_general(
            a, w_q, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "with_flags"))
def ecc_qmatmul(a: jnp.ndarray, w_enc: jnp.ndarray, w_scale=None, *,
                bm: int = 128, bn: int = 128, bk: int = 0,
                interpret: bool = True, with_flags: bool = False):
    """``a (M,K) @ decode(w_enc (K,N) uint8)``, decode fused into the matmul.

    int8 ``a``   -> (M, N) int32 accumulator (``w_scale`` ignored).
    float ``a``  -> (M, N) f32 = ``a @ (decode(w_enc) * w_scale)`` — requires
                    ``w_scale``; pass ``bk=0`` (default: full K per tile) to
                    keep the accumulation order identical to one XLA dot.
    with_flags   -> also return ``flags (2,) int32``: (#single-corrected,
                    #double-detected) over all weight blocks.

    Tiles need not divide (M, N, K) — edge tiles are masked. N % 8 == 0 is
    structural (ECC blocks run along N).
    """
    m, k = a.shape
    k2, n = w_enc.shape
    assert k == k2 and n % 8 == 0, (a.shape, w_enc.shape)
    float_path = jnp.issubdtype(a.dtype, jnp.floating)
    if float_path and w_scale is None:
        raise ValueError("float activations need w_scale for the in-VMEM "
                         "dequantization")
    if bk == 0:
        bk = k  # full-K tile: one dot per output tile, XLA-identical order
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    bn = max(8, bn - bn % 8)  # whole ECC blocks per tile
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    scale = jnp.asarray(w_scale if w_scale is not None else 1.0,
                        jnp.float32).reshape(1, 1)
    out_dtype = jnp.float32 if float_path else jnp.int32
    kern = functools.partial(_kernel, dims=(m, n, k), float_path=float_path)
    out, flags = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((7, 8), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((8, 8), lambda i, j, kk: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((1, 2), lambda i, j, kk: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), out_dtype),
            jax.ShapeDtypeStruct((1, 2), jnp.int32),
        ],
        interpret=interpret,
    )(a, w_enc, scale, jnp.asarray(ecc.ROWMASK64),
      jnp.asarray(ecc.COLS64_BYBYTE))
    if with_flags:
        return out, flags.reshape(2)
    return out
