"""Pallas TPU kernel: FUSED in-place-ECC decode + int8 matmul (beyond-paper).

The paper keeps decode in hardware. On TPU we instead keep weights
ECC-encoded *at rest in HBM* and decode each weight tile in VMEM on its way
to the MXU. Protection then costs zero HBM space AND zero extra HBM traffic;
the VPU bit-twiddling overlaps with MXU matmul work on neighbouring tiles.

Layout: W (K, N) int8 row-major -> 8-byte ECC blocks run along N, so any
(BK, BN) tile with BN % 8 == 0 contains whole blocks and decodes locally.

Grid (M/BM, N/BN, K/BK), K innermost; int32 accumulation in the output tile
(revisited across the K steps). Default tiles 128x128x128: MXU-aligned
(multiples of 128 in every matmul dim), VMEM footprint per step
= BM*BK (a, int8) + BK*BN (w, uint8) + BM*BN*4 (acc, int32) = 16+16+64 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ecc
from . import ecc_decode


def _kernel(a_ref, w_ref, rowmask_ref, cols_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]  # (BM, BK) int8
    w_enc = w_ref[...]  # (BK, BN) uint8, ECC-encoded
    bk, bn = w_enc.shape
    dec, _flags = ecc_decode._decode_tile(
        w_enc.reshape(bk * bn // 8, 8), rowmask_ref[...], cols_ref[...])
    w_q = jax.lax.bitcast_convert_type(dec.reshape(bk, bn), jnp.int8)
    out_ref[...] += jax.lax.dot_general(
        a, w_q, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def ecc_qmatmul(a_q: jnp.ndarray, w_enc: jnp.ndarray, *,
                bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: bool = True) -> jnp.ndarray:
    """a_q (M,K) int8 @ decode(w_enc (K,N) uint8) -> (M,N) int32."""
    m, k = a_q.shape
    k2, n = w_enc.shape
    assert k == k2 and n % 8 == 0
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((7, 8), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((8, 8), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a_q, w_enc, jnp.asarray(ecc.ROWMASK64), jnp.asarray(ecc.COLS64_BYBYTE))
