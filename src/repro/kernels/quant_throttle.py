"""Pallas TPU kernel: fused quantize + WOT-throttle (the QATT inner step).

After every optimizer update, QATT quantizes the fp32 masters and clamps
protected positions. Unfused, that's 3 HBM round-trips (read w, write q,
read q / write clamped); fused it is one read + one write. The scale
(max|w|/127) is computed in a first reduction pass (also a kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import quant, wot

DEFAULT_BLK = 4096


def _row_valid(i, blk, nblk, shape):
    """Row mask for the (possibly ragged) edge block: rows past nblk are
    grid padding whose contents are unspecified."""
    rows = i * blk + jax.lax.broadcasted_iota(jnp.int32, shape, dimension=0)
    return rows < nblk


def _absmax_kernel(w_ref, out_ref, *, blk, nblk):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = jnp.abs(w_ref[...])
    w = jnp.where(_row_valid(i, blk, nblk, w.shape), w, jnp.zeros_like(w))
    out_ref[0] = jnp.maximum(out_ref[0], jnp.max(w))


def _qt_kernel(w_ref, scale_ref, q_ref, *, blk, nblk):
    i = pl.program_id(0)
    w = w_ref[...]                       # (bn, 8) f32
    w = jnp.where(_row_valid(i, blk, nblk, w.shape), w, jnp.zeros_like(w))
    scale = scale_ref[0]
    q = jnp.clip(jnp.round(w / scale), -quant.QMAX, quant.QMAX)
    pos = jax.lax.broadcasted_iota(jnp.int32, w.shape, dimension=1)
    clamped = jnp.clip(q, wot.WOT_LO, wot.WOT_HI)
    q = jnp.where(pos == 7, q, clamped)
    q_ref[...] = q.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def quantize_throttle(w_blocks: jnp.ndarray, *, blk: int = DEFAULT_BLK,
                      interpret: bool = True):
    """(nblk, 8) f32 -> (int8 q (nblk, 8) WOT-compliant, scale f32 ()).

    nblk need not divide into ``blk`` tiles: the grid is ``pl.cdiv`` and the
    edge block is masked by a row-iota, so arbitrary leaf sizes quantize
    without host-side padding. Deployment-exact: equals quantize() then
    throttle_q()."""
    nblk = w_blocks.shape[0]
    blk = min(blk, nblk)
    grid = (pl.cdiv(nblk, blk),)
    absmax = pl.pallas_call(
        functools.partial(_absmax_kernel, blk=blk, nblk=nblk),
        grid=grid,
        in_specs=[pl.BlockSpec((blk, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=interpret,
    )(w_blocks.astype(jnp.float32))
    scale = jnp.maximum(absmax, 1e-12) / quant.QMAX
    q = pl.pallas_call(
        functools.partial(_qt_kernel, blk=blk, nblk=nblk),
        grid=grid,
        in_specs=[pl.BlockSpec((blk, 8), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((blk, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, 8), jnp.int8),
        interpret=interpret,
    )(w_blocks.astype(jnp.float32), scale)
    return q, scale[0]
