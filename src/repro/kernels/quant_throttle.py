"""Pallas TPU kernel: fused quantize + WOT-throttle (the QATT inner step).

After every optimizer update, QATT quantizes the fp32 masters and clamps
protected positions. Unfused, that's 3 HBM round-trips (read w, write q,
read q / write clamped); fused it is one read + one write. The scale
(max|w|/127) is computed in a first reduction pass (also a kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import quant, wot

DEFAULT_BLK = 4096


def _absmax_kernel(w_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0] = jnp.maximum(out_ref[0], jnp.max(jnp.abs(w_ref[...])))


def _qt_kernel(w_ref, scale_ref, q_ref):
    w = w_ref[...]                       # (bn, 8) f32
    scale = scale_ref[0]
    q = jnp.clip(jnp.round(w / scale), -quant.QMAX, quant.QMAX)
    pos = jax.lax.broadcasted_iota(jnp.int32, w.shape, dimension=1)
    clamped = jnp.clip(q, wot.WOT_LO, wot.WOT_HI)
    q = jnp.where(pos == 7, q, clamped)
    q_ref[...] = q.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def quantize_throttle(w_blocks: jnp.ndarray, *, blk: int = DEFAULT_BLK,
                      interpret: bool = True):
    """(nblk, 8) f32 -> (int8 q (nblk, 8) WOT-compliant, scale f32 ()).

    Deployment-exact: equals quantize() then throttle_q()."""
    nblk = w_blocks.shape[0]
    blk = min(blk, nblk)
    assert nblk % blk == 0
    absmax = pl.pallas_call(
        _absmax_kernel,
        grid=(nblk // blk,),
        in_specs=[pl.BlockSpec((blk, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=interpret,
    )(w_blocks.astype(jnp.float32))
    scale = jnp.maximum(absmax, 1e-12) / quant.QMAX
    q = pl.pallas_call(
        _qt_kernel,
        grid=(nblk // blk,),
        in_specs=[pl.BlockSpec((blk, 8), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((blk, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, 8), jnp.int8),
        interpret=interpret,
    )(w_blocks.astype(jnp.float32), scale)
    return q, scale[0]
