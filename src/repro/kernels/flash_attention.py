"""Pallas TPU kernel: causal flash attention (online softmax).

This is the kernel that closes the prefill-32k memory gap identified in
EXPERIMENTS.md §Perf cell C: the XLA-level chunked attention materializes
f32 score chunks in HBM; this kernel keeps the running (o, m, l) state in
VMEM and never writes scores out.

Grid (B*H, Sq/BQ, Sk/BK) with the KV dimension innermost; the causal
triangle is honoured per-tile: fully-masked tiles still iterate (Pallas
grids are dense) but exit without compute via @pl.when. Tiles are
MXU-aligned (BQ, BK multiples of 128, head_dim typically 64..256).

VMEM per step: BQ*D (q) + BK*D (k,v) + BQ*BK (scores) + BQ*D (o acc)
= for 128x128xD=128 fp32: ~0.4 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, bq, bk, scale):
    i = pl.program_id(1)  # q tile
    j = pl.program_id(2)  # kv tile

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(j * bk <= i * bq + bq - 1)  # tile intersects the causal triangle
    def _compute():
        q = q_ref[0]                       # (BQ, D)
        k = k_ref[0]                       # (BK, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (BQ, BK)
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_ref[0]                  # (BQ,)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[0] = o_ref[0] * alpha[:, None] + pv
        m_ref[0] = m_new


def _norm_kernel(o_ref, l_ref, out_ref):
    out_ref[...] = (o_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]).astype(
        out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention(q, k, v, *, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """Causal flash attention. q,k,v: (B, H, S, D) -> (B, H, S, D).

    GQA callers broadcast KV heads beforehand (or reshape to grouped form).
    """
    b, h, s, d = q.shape
    dtype = q.dtype
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    scale = 1.0 / np.sqrt(d)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    grid = (b * h, s // bq, s // bk)

    o, m, l = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bq), lambda g, i, j: (g, i)),
            pl.BlockSpec((1, bq), lambda g, i, j: (g, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b * h, s), jnp.float32),
            jax.ShapeDtypeStruct((b * h, s), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = pl.pallas_call(
        _norm_kernel,
        grid=(b * h, s // bq),
        in_specs=[pl.BlockSpec((1, bq, d), lambda g, i: (g, i, 0)),
                  pl.BlockSpec((1, bq), lambda g, i: (g, i))],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), dtype),
        interpret=interpret,
    )(o, l)
    return out.reshape(b, h, s, d)
