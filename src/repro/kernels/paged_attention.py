"""Pallas TPU kernel: fused ECC page decode + single-token attention.

The paged KV cache (``serving.kvcache``) keeps keys/values ECC-encoded at
rest; this kernel decodes each sequence's pages in VMEM on their way into
the attention dots — the serving-state twin of ``ecc_qmatmul``'s
decode-at-use weight path. Protection then costs zero HBM space (in-place
scheme) AND zero extra HBM traffic: the encoded strip is what streams in,
and no decoded copy of the cache ever lands in HBM.

Grid (B, KV): one step owns the whole gathered (S, hd) K and V strips for
one (batch, kv-head) pair, block-decodes them (per-token flag counts),
dequantizes with the per-token page scales, and computes all rep = H/KV
query heads of that group in full-sequence form. Deliberately NO online
softmax: the op/dtype sequence exactly mirrors ``layers.decode_attention``
(bf16 score dot -> f32 scale + mask -> ``jax.nn.softmax`` -> dtype cast ->
PV dot), which is what makes the fused path BIT-IDENTICAL to the XLA
decode-then-attend reference *compiled as one program* (the serving paths
always jit it; eager op-by-op execution materializes an intermediate bf16
rounding of the score dot that fused compilation elides, costing ~1 ulp).
VMEM holds the full strip (~2*S*hd encoded
bytes + the dequantized copies) — fine for decode contexts to a few k
tokens; a page-chunked online-softmax variant would scale further but
forfeits the bit-identity contract.

The page-table gather itself (pool -> (B, S, ...) strips) stays in XLA
before the ``pallas_call``: gathers are layout transforms XLA schedules
well, while the kernel owns everything that must not leave VMEM decoded.
Flags (corrected, DUE) are masked to valid (``<= pos``) tokens inside the
kernel, summed per (batch, kv-head) cell, and reduced outside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ecc
from . import ecc_decode

KV_SCHEMES = ("faulty", "parity-zero", "in-place")


def _kernel(q_ref, ke_ref, kch_ref, ksc_ref, ve_ref, vch_ref, vsc_ref,
            pos_ref, rowmask_ref, cols_ref, o_ref, flags_ref, *, scheme, s):
    qb = q_ref[0, 0]                                   # (rep, hd)
    hd = qb.shape[-1]
    pos = pos_ref[0, 0]
    # 2-D iotas throughout (Mosaic rejects rank-1 iota outside interpret)
    tok = jax.lax.broadcasted_iota(jnp.int32, (s, 1), 0)
    valid_col = tok <= pos                             # (s, 1)

    def dec(enc_ref, ch_ref):
        """-> (int8 (s, hd), corrected, due) — flags already valid-masked."""
        enc = enc_ref[0, :, 0, :]                      # (s, hd) uint8
        if scheme == "faulty":
            z = jnp.zeros((), jnp.int32)
            return jax.lax.bitcast_convert_type(enc, jnp.int8), z, z
        if scheme == "parity-zero":
            ch = ch_ref[0, :, 0, :]                    # (s, hd // 8)
            # constant-free restatement of ecc.decode_parity8 (whose packed
            # weight tables would be captured consts inside a Pallas kernel):
            # byte j's stored parity is bit (j % 8) of check byte j // 8.
            par = (jax.lax.population_count(enc) & 1).astype(jnp.uint8)
            sh = (jax.lax.broadcasted_iota(jnp.int32, (s, hd), 1) % 8
                  ).astype(jnp.uint8)
            stored = (jnp.repeat(ch, 8, axis=1) >> sh) & jnp.uint8(1)
            bad = par != stored
            data = jnp.where(bad, jnp.uint8(0), enc)
            cor = jnp.sum(jnp.where(valid_col, bad.astype(jnp.int32), 0))
            return (jax.lax.bitcast_convert_type(data, jnp.int8), cor,
                    jnp.zeros((), jnp.int32))
        dcd, fl = ecc_decode._decode_tile(enc.reshape(s * hd // 8, 8),
                                          rowmask_ref[...], cols_ref[...])
        fl = fl.reshape(s, hd // 8)
        cor = jnp.sum(jnp.where(valid_col, (fl & 1).astype(jnp.int32), 0))
        due = jnp.sum(jnp.where(valid_col, ((fl >> 1) & 1).astype(jnp.int32),
                                0))
        return jax.lax.bitcast_convert_type(dcd.reshape(s, hd), jnp.int8), \
            cor, due

    kq, kcor, kdue = dec(ke_ref, kch_ref)
    vq, vcor, vdue = dec(ve_ref, vch_ref)
    cdt = qb.dtype
    kf = (kq.astype(jnp.float32) * ksc_ref[0][:, None]).astype(cdt)  # (s, hd)
    vf = (vq.astype(jnp.float32) * vsc_ref[0][:, None]).astype(cdt)
    # score path mirrors layers.decode_attention op for op (bit-identity)
    sc = jax.lax.dot_general(qb, kf,
                             dimension_numbers=(((1,), (1,)), ((), ())))
    sc = sc.astype(jnp.float32) * (1.0 / np.sqrt(hd))  # (rep, s)
    sc = jnp.where(valid_col.reshape(1, s), sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1).astype(cdt)
    o_ref[0, 0] = jax.lax.dot_general(
        pr, vf, dimension_numbers=(((1,), (0,)), ((), ()))).astype(o_ref.dtype)
    flags_ref[0, 0] = jnp.stack([kcor + vcor, kdue + vdue])


@functools.partial(jax.jit, static_argnames=("scheme", "interpret"))
def fused_page_attention(q, ke, kch, ksc, ve, vch, vsc, pos, *,
                         scheme: str = "in-place", interpret: bool = True):
    """Fused decode-at-use attention over gathered encoded KV strips.

    q:        (B, H, 1, hd) float query (hd % 8 == 0).
    ke/ve:    (B, S, KV, hd) uint8 encoded strips (``kvcache._gather_seq``).
    kch/vch:  (B, S, KV, hd // 8) uint8 parity check bytes, or None.
    ksc/vsc:  (B, S) f32 per-token scales.
    pos:      (B,) int32 current positions; tokens > pos are masked.

    Returns ``(o (B, H, 1, hd) q.dtype, flags (2,) int32)`` — o bit-identical
    to decode-then-``layers.decode_attention``, flags = (corrected, DUE)
    counts over valid tokens of both strips.
    """
    if scheme not in KV_SCHEMES:
        raise ValueError(f"scheme {scheme!r}; one of {KV_SCHEMES}")
    b, h, _, hd = q.shape
    s, kv = ke.shape[1], ke.shape[2]
    rep = h // kv
    nb = hd // 8
    if kch is None:
        kch = jnp.zeros((b, s, kv, nb), jnp.uint8)
        vch = jnp.zeros((b, s, kv, nb), jnp.uint8)
    q4 = q[:, :, 0, :].reshape(b, kv, rep, hd)  # head g*rep+r -> (g, r)
    pos2 = pos.reshape(b, 1).astype(jnp.int32)

    kern = functools.partial(_kernel, scheme=scheme, s=s)
    strip = lambda bi, g: (bi, 0, g, 0)
    out, flags = pl.pallas_call(
        kern,
        grid=(b, kv),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda bi, g: (bi, g, 0, 0)),
            pl.BlockSpec((1, s, 1, hd), strip),
            pl.BlockSpec((1, s, 1, nb), strip),
            pl.BlockSpec((1, s), lambda bi, g: (bi, 0)),
            pl.BlockSpec((1, s, 1, hd), strip),
            pl.BlockSpec((1, s, 1, nb), strip),
            pl.BlockSpec((1, s), lambda bi, g: (bi, 0)),
            pl.BlockSpec((1, 1), lambda bi, g: (bi, 0)),
            pl.BlockSpec((7, 8), lambda bi, g: (0, 0)),
            pl.BlockSpec((8, 8), lambda bi, g: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda bi, g: (bi, g, 0, 0)),
            pl.BlockSpec((1, 1, 2), lambda bi, g: (bi, g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, rep, hd), q.dtype),
            jax.ShapeDtypeStruct((b, kv, 2), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(q4, ke, kch, ksc, ve, vch, vsc, pos2,
      jnp.asarray(ecc.ROWMASK64), jnp.asarray(ecc.COLS64_BYBYTE))
    return out.reshape(b, h, 1, hd), flags.sum(axis=(0, 1))
