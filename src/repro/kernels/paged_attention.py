"""Pallas TPU kernels: fused ECC page decode + single-token attention.

The paged KV cache (``serving.kvcache``) keeps keys/values ECC-encoded at
rest; these kernels decode each sequence's pages in VMEM on their way into
the attention dots — the serving-state twin of ``ecc_qmatmul``'s
decode-at-use weight path. Protection then costs zero HBM space (in-place
scheme) AND zero extra HBM traffic: the encoded strip is what streams in,
and no decoded copy of the cache ever lands in HBM.

Two kernels, one contract each:

**Strip kernel** (:func:`fused_page_attention`). Grid (B, KV): one step
owns the whole gathered (S, hd) K and V strips for one (batch, kv-head)
pair, block-decodes them (per-token flag counts), dequantizes with the
per-token page scales, and computes all rep = H/KV query heads of that
group in full-sequence form. Deliberately NO online softmax: the op/dtype
sequence exactly mirrors ``layers.decode_attention`` (bf16 score dot ->
f32 scale + mask -> ``jax.nn.softmax`` -> dtype cast -> PV dot), which is
what makes the fused path BIT-IDENTICAL to the XLA decode-then-attend
reference *compiled as one program* (the serving paths always jit it;
eager op-by-op execution materializes an intermediate bf16 rounding of
the score dot that fused compilation elides, costing ~1 ulp). VMEM holds
the full strip (see :func:`strip_vmem_bytes`) — fine for decode contexts
to a few k tokens, a hard wall long before 500k-class contexts.

**Chunked kernel** (:func:`chunked_page_attention`). Grid (B, KV,
n_chunks) with the chunk axis innermost and sequential: each step streams
ONE fixed-size page chunk through VMEM (decode ECC block -> int8 dequant
-> f32) and folds it into running online-softmax state (max m, normalizer
l, accumulator acc) held in VMEM scratch, so the VMEM working set is
bounded by the CHUNK size, not the context length
(:func:`chunked_vmem_bytes`). The price is the bit-identity contract:
online softmax reassociates the reduction and the chunked path computes
in f32 rather than replaying the reference's bf16 op sequence, so its
output is only tolerance-close to the reference. It therefore lives
behind an explicit ``KVProtectionPolicy(attention_impl="chunked")`` knob
and is validated against :func:`oracle_page_attention` — an fp64 oracle
over the SAME encoded strips — instead of a bit-equality check. Flag
counts (integer, decode-exact) still match the reference exactly.

The page-table gather itself (pool -> (B, S, ...) strips) stays in XLA
before the ``pallas_call``: gathers are layout transforms XLA schedules
well, while the kernels own everything that must not leave VMEM decoded.
Flags (corrected, DUE) are masked to valid (``<= pos``) tokens inside the
kernel, summed per (batch, kv-head) cell, and reduced outside — per
batch row (``per_slot=True``, for per-request fault attribution) or to
batch-total scalars.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ecc
from . import ecc_decode

KV_SCHEMES = ("faulty", "parity-zero", "in-place")

# ~VMEM per TPU core (v4/v5 class) — the budget the strip kernel's whole
# gathered working set must fit inside, and the denominator of the
# structural crossover recorded by benchmarks/kernel_bench.py.
VMEM_BUDGET_BYTES = 16 * 2 ** 20


def _decode_strip(enc, ch, valid_col, rowmask, cols, *, scheme):
    """Decode one (s, hd) uint8 encoded strip in-kernel.

    -> (int8 (s, hd), corrected, due) — scalar flag counts already masked
    to ``valid_col`` (s, 1) tokens. Shared by the strip and chunked
    kernels so both observe identical per-token fault accounting.
    """
    s, hd = enc.shape
    if scheme == "faulty":
        z = jnp.zeros((), jnp.int32)
        return jax.lax.bitcast_convert_type(enc, jnp.int8), z, z
    if scheme == "parity-zero":
        # constant-free restatement of ecc.decode_parity8 (whose packed
        # weight tables would be captured consts inside a Pallas kernel):
        # byte j's stored parity is bit (j % 8) of check byte j // 8.
        par = (jax.lax.population_count(enc) & 1).astype(jnp.uint8)
        sh = (jax.lax.broadcasted_iota(jnp.int32, (s, hd), 1) % 8
              ).astype(jnp.uint8)
        stored = (jnp.repeat(ch, 8, axis=1) >> sh) & jnp.uint8(1)
        bad = par != stored
        data = jnp.where(bad, jnp.uint8(0), enc)
        cor = jnp.sum(jnp.where(valid_col, bad.astype(jnp.int32), 0))
        return (jax.lax.bitcast_convert_type(data, jnp.int8), cor,
                jnp.zeros((), jnp.int32))
    dcd, fl = ecc_decode._decode_tile(enc.reshape(s * hd // 8, 8),
                                      rowmask, cols)
    fl = fl.reshape(s, hd // 8)
    cor = jnp.sum(jnp.where(valid_col, (fl & 1).astype(jnp.int32), 0))
    due = jnp.sum(jnp.where(valid_col, ((fl >> 1) & 1).astype(jnp.int32),
                            0))
    return jax.lax.bitcast_convert_type(dcd.reshape(s, hd), jnp.int8), \
        cor, due


def _kernel(q_ref, ke_ref, kch_ref, ksc_ref, ve_ref, vch_ref, vsc_ref,
            pos_ref, rowmask_ref, cols_ref, o_ref, flags_ref, *, scheme, s):
    qb = q_ref[0, 0]                                   # (rep, hd)
    hd = qb.shape[-1]
    pos = pos_ref[0, 0]
    # 2-D iotas throughout (Mosaic rejects rank-1 iota outside interpret)
    tok = jax.lax.broadcasted_iota(jnp.int32, (s, 1), 0)
    valid_col = tok <= pos                             # (s, 1)

    kq, kcor, kdue = _decode_strip(ke_ref[0, :, 0, :], kch_ref[0, :, 0, :],
                                   valid_col, rowmask_ref[...],
                                   cols_ref[...], scheme=scheme)
    vq, vcor, vdue = _decode_strip(ve_ref[0, :, 0, :], vch_ref[0, :, 0, :],
                                   valid_col, rowmask_ref[...],
                                   cols_ref[...], scheme=scheme)
    cdt = qb.dtype
    kf = (kq.astype(jnp.float32) * ksc_ref[0][:, None]).astype(cdt)  # (s, hd)
    vf = (vq.astype(jnp.float32) * vsc_ref[0][:, None]).astype(cdt)
    # score path mirrors layers.decode_attention op for op (bit-identity)
    sc = jax.lax.dot_general(qb, kf,
                             dimension_numbers=(((1,), (1,)), ((), ())))
    sc = sc.astype(jnp.float32) * (1.0 / np.sqrt(hd))  # (rep, s)
    sc = jnp.where(valid_col.reshape(1, s), sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1).astype(cdt)
    o_ref[0, 0] = jax.lax.dot_general(
        pr, vf, dimension_numbers=(((1,), (0,)), ((), ()))).astype(o_ref.dtype)
    flags_ref[0, 0] = jnp.stack([kcor + vcor, kdue + vdue])


def _reduce_flags(flags, per_slot: bool):
    """(b, kv, 2) in-grid flag cells -> (2, b) per-slot rows or (2,)
    batch totals."""
    if per_slot:
        return flags.sum(axis=1).T                     # (2, b)
    return flags.sum(axis=(0, 1))                      # (2,)


@functools.partial(jax.jit, static_argnames=("scheme", "interpret",
                                             "per_slot"))
def fused_page_attention(q, ke, kch, ksc, ve, vch, vsc, pos, *,
                         scheme: str = "in-place", interpret: bool = True,
                         per_slot: bool = False):
    """Fused decode-at-use attention over gathered encoded KV strips.

    q:        (B, H, 1, hd) float query (hd % 8 == 0).
    ke/ve:    (B, S, KV, hd) uint8 encoded strips (``kvcache._gather_seq``).
    kch/vch:  (B, S, KV, hd // 8) uint8 parity check bytes, or None.
    ksc/vsc:  (B, S) f32 per-token scales.
    pos:      (B,) int32 current positions; tokens > pos are masked.

    Returns ``(o (B, H, 1, hd) q.dtype, flags)`` — o bit-identical to
    decode-then-``layers.decode_attention``; flags are the (corrected,
    DUE) counts over valid tokens of both strips, as per-batch-row
    ``(2, B)`` rows when ``per_slot`` (per-request fault attribution for
    the serving front-end) else batch-total ``(2,)`` scalars.
    """
    if scheme not in KV_SCHEMES:
        raise ValueError(f"scheme {scheme!r}; one of {KV_SCHEMES}")
    b, h, _, hd = q.shape
    s, kv = ke.shape[1], ke.shape[2]
    rep = h // kv
    nb = hd // 8
    if kch is None:
        kch = jnp.zeros((b, s, kv, nb), jnp.uint8)
        vch = jnp.zeros((b, s, kv, nb), jnp.uint8)
    q4 = q[:, :, 0, :].reshape(b, kv, rep, hd)  # head g*rep+r -> (g, r)
    pos2 = pos.reshape(b, 1).astype(jnp.int32)

    kern = functools.partial(_kernel, scheme=scheme, s=s)
    strip = lambda bi, g: (bi, 0, g, 0)
    out, flags = pl.pallas_call(
        kern,
        grid=(b, kv),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda bi, g: (bi, g, 0, 0)),
            pl.BlockSpec((1, s, 1, hd), strip),
            pl.BlockSpec((1, s, 1, nb), strip),
            pl.BlockSpec((1, s), lambda bi, g: (bi, 0)),
            pl.BlockSpec((1, s, 1, hd), strip),
            pl.BlockSpec((1, s, 1, nb), strip),
            pl.BlockSpec((1, s), lambda bi, g: (bi, 0)),
            pl.BlockSpec((1, 1), lambda bi, g: (bi, 0)),
            pl.BlockSpec((7, 8), lambda bi, g: (0, 0)),
            pl.BlockSpec((8, 8), lambda bi, g: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda bi, g: (bi, g, 0, 0)),
            pl.BlockSpec((1, 1, 2), lambda bi, g: (bi, g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, rep, hd), q.dtype),
            jax.ShapeDtypeStruct((b, kv, 2), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(q4, ke, kch, ksc, ve, vch, vsc, pos2,
      jnp.asarray(ecc.ROWMASK64), jnp.asarray(ecc.COLS64_BYBYTE))
    return out.reshape(b, h, 1, hd), _reduce_flags(flags, per_slot)


# ---------------------------------------------------------------------------
# page-chunked online-softmax variant: VMEM bounded by chunk, not context
# ---------------------------------------------------------------------------


def _chunked_kernel(q_ref, ke_ref, kch_ref, ksc_ref, ve_ref, vch_ref,
                    vsc_ref, pos_ref, rowmask_ref, cols_ref, o_ref,
                    flags_ref, m_ref, l_ref, acc_ref, *, scheme, chunk,
                    nchunks):
    c = pl.program_id(2)
    pos = pos_ref[0, 0]
    base = c * chunk

    @pl.when(c == 0)
    def _init():
        # -1e30 is safe (not a sentinel hazard): chunk 0 always contains
        # token 0, which is valid for every pos >= 0, so m is finite after
        # the first update and exp(-1e30 - m) underflows masked scores to 0.
        m_ref[...] = jnp.full(m_ref.shape, -1e30, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)
        flags_ref[...] = jnp.zeros(flags_ref.shape, jnp.int32)

    @pl.when(base <= pos)  # chunks wholly past the valid prefix contribute 0
    def _update():
        qb = q_ref[0, 0].astype(jnp.float32)           # (rep, hd)
        hd = qb.shape[-1]
        tok = base + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        valid_col = tok <= pos                         # (chunk, 1)
        kq, kcor, kdue = _decode_strip(
            ke_ref[0, :, 0, :], kch_ref[0, :, 0, :], valid_col,
            rowmask_ref[...], cols_ref[...], scheme=scheme)
        vq, vcor, vdue = _decode_strip(
            ve_ref[0, :, 0, :], vch_ref[0, :, 0, :], valid_col,
            rowmask_ref[...], cols_ref[...], scheme=scheme)
        kf = kq.astype(jnp.float32) * ksc_ref[0][:, None]   # (chunk, hd)
        vf = vq.astype(jnp.float32) * vsc_ref[0][:, None]
        sc = jax.lax.dot_general(
            qb, kf, dimension_numbers=(((1,), (1,)), ((), ())))
        sc = sc * (1.0 / np.sqrt(hd))                  # (rep, chunk) f32
        sc = jnp.where(valid_col.reshape(1, chunk), sc, -1e30)
        m_prev = m_ref[:, :1]                          # (rep, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.maximum(m_prev, jnp.max(sc, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(sc - m_cur)                        # (rep, chunk)
        p = jnp.where(valid_col.reshape(1, chunk), p, 0.0)
        l_ref[...] = jnp.broadcast_to(
            alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True),
            l_ref.shape)
        m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, vf, dimension_numbers=(((1,), (0,)), ((), ())))
        flags_ref[0, 0] = flags_ref[0, 0] + jnp.stack([kcor + vcor,
                                                       kdue + vdue])

    @pl.when(c == nchunks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[:, :1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scheme", "chunk_tokens",
                                             "interpret", "per_slot"))
def chunked_page_attention(q, ke, kch, ksc, ve, vch, vsc, pos, *,
                           scheme: str = "in-place",
                           chunk_tokens: int = 256,
                           interpret: bool = True,
                           per_slot: bool = False):
    """Page-chunked online-softmax decode-at-use attention.

    Same operands and layout as :func:`fused_page_attention`, but the grid
    is (B, KV, n_chunks) with the chunk axis sequential: VMEM only ever
    holds one ``chunk_tokens``-sized slice of the strips plus the running
    (m, l, acc) online-softmax scratch, so context length is bounded by
    HBM, not VMEM. NOT bit-identical to the reference (see module
    docstring) — gate behind ``attention_impl="chunked"`` and validate
    against :func:`oracle_page_attention`. Flag counts ARE exact.

    ``chunk_tokens`` is clamped to S; strips whose S is not a multiple of
    the chunk are zero-padded (padded tokens sit past every valid ``pos``
    and are masked, and zero pages are codec-clean for every scheme).
    """
    if scheme not in KV_SCHEMES:
        raise ValueError(f"scheme {scheme!r}; one of {KV_SCHEMES}")
    if chunk_tokens < 1:
        raise ValueError(f"chunk_tokens must be positive, got {chunk_tokens}")
    b, h, _, hd = q.shape
    s, kv = ke.shape[1], ke.shape[2]
    rep = h // kv
    nb = hd // 8
    if kch is None:
        kch = jnp.zeros((b, s, kv, nb), jnp.uint8)
        vch = jnp.zeros((b, s, kv, nb), jnp.uint8)
    chunk = min(chunk_tokens, s)
    pad = (-s) % chunk
    if pad:
        grow = lambda a: jnp.pad(a, ((0, 0), (0, pad)) +
                                 ((0, 0),) * (a.ndim - 2))
        ke, kch, ve, vch = grow(ke), grow(kch), grow(ve), grow(vch)
        ksc, vsc = grow(ksc), grow(vsc)
    nc = (s + pad) // chunk
    q4 = q[:, :, 0, :].reshape(b, kv, rep, hd)
    pos2 = pos.reshape(b, 1).astype(jnp.int32)

    kern = functools.partial(_chunked_kernel, scheme=scheme, chunk=chunk,
                             nchunks=nc)
    cstrip = lambda bi, g, c: (bi, c, g, 0)
    out, flags = pl.pallas_call(
        kern,
        grid=(b, kv, nc),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda bi, g, c: (bi, g, 0, 0)),
            pl.BlockSpec((1, chunk, 1, hd), cstrip),
            pl.BlockSpec((1, chunk, 1, nb), cstrip),
            pl.BlockSpec((1, chunk), lambda bi, g, c: (bi, c)),
            pl.BlockSpec((1, chunk, 1, hd), cstrip),
            pl.BlockSpec((1, chunk, 1, nb), cstrip),
            pl.BlockSpec((1, chunk), lambda bi, g, c: (bi, c)),
            pl.BlockSpec((1, 1), lambda bi, g, c: (bi, 0)),
            pl.BlockSpec((7, 8), lambda bi, g, c: (0, 0)),
            pl.BlockSpec((8, 8), lambda bi, g, c: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda bi, g, c: (bi, g, 0, 0)),
            pl.BlockSpec((1, 1, 2), lambda bi, g, c: (bi, g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, rep, hd), q.dtype),
            jax.ShapeDtypeStruct((b, kv, 2), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((rep, 128), jnp.float32),   # running max m
            pltpu.VMEM((rep, 128), jnp.float32),   # running normalizer l
            pltpu.VMEM((rep, hd), jnp.float32),    # running accumulator
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q4, ke, kch, ksc, ve, vch, vsc, pos2,
      jnp.asarray(ecc.ROWMASK64), jnp.asarray(ecc.COLS64_BYBYTE))
    return out.reshape(b, h, 1, hd), _reduce_flags(flags, per_slot)


# ---------------------------------------------------------------------------
# fp64 oracle + VMEM accounting (the chunked kernel's acceptance gates)
# ---------------------------------------------------------------------------


def oracle_page_attention(q, ke, kch, ksc, ve, vch, vsc, pos, *,
                          scheme: str = "in-place",
                          backend: str = "xla") -> np.ndarray:
    """Float64 NumPy oracle over the SAME encoded strips -> (B, H, 1, hd).

    The codec decode is integer-exact (reuses ``kvcache._decode_kv``, so
    repaired/zeroed bytes match what either kernel sees bit for bit); the
    dequant, score, softmax, and PV reduction then all run in fp64 — the
    tolerance reference the chunked kernel is validated against, replacing
    the bit-identity contract it forfeits. Runs entirely on the host; no
    ``jax_enable_x64`` global flag needed.
    """
    from repro.serving import kvcache  # deferred: kvcache imports us
    kq = np.asarray(kvcache._decode_kv(ke, kch, scheme, backend)[0],
                    np.float64)
    vq = np.asarray(kvcache._decode_kv(ve, vch, scheme, backend)[0],
                    np.float64)
    kf = kq * np.asarray(ksc, np.float64)[..., None, None]  # (B, S, KV, hd)
    vf = vq * np.asarray(vsc, np.float64)[..., None, None]
    qf = np.asarray(jnp.asarray(q).astype(jnp.float32), np.float64)
    b, h, _, hd = qf.shape
    s, kv = kf.shape[1], kf.shape[2]
    rep = h // kv
    pos_np = np.asarray(pos)
    valid = np.arange(s)[None, :] <= pos_np[:, None]        # (B, S)
    out = np.zeros((b, h, 1, hd), np.float64)
    for bi in range(b):
        for g in range(kv):
            for r in range(rep):
                qv = qf[bi, g * rep + r, 0]                 # (hd,)
                sc = (kf[bi, :, g] @ qv) / math.sqrt(hd)    # (S,)
                sc = np.where(valid[bi], sc, -np.inf)
                p = np.exp(sc - sc.max())
                out[bi, g * rep + r, 0] = (p / p.sum()) @ vf[bi, :, g]
    return out


def strip_vmem_bytes(s: int, hd: int, rep: int,
                     scheme: str = "in-place") -> int:
    """Estimated VMEM working set of the strip kernel per (batch, kv-head)
    grid cell: encoded K+V strips, their int8 decodes, f32 dequants and
    compute-dtype copies, parity planes (parity-zero only), and the
    f32 score/softmax/cast-prob buffers. Linear in ``s`` — the structural
    wall the chunked kernel removes."""
    strips = 2 * s * hd * (1 + 1 + 4 + 2)   # enc + int8 + f32 + bf16, K and V
    checks = 2 * s * (hd // 8) if scheme == "parity-zero" else 0
    scores = rep * s * (4 + 4 + 2)          # f32 scores + softmax + cast
    return strips + checks + scores


def chunked_vmem_bytes(chunk_tokens: int, hd: int, rep: int,
                       scheme: str = "in-place") -> int:
    """Chunked-kernel VMEM working set per grid cell: one chunk's strip
    working set plus the f32 online-softmax scratch — independent of
    context length."""
    scratch = 4 * rep * (128 + 128 + hd)    # m, l, acc
    return strip_vmem_bytes(chunk_tokens, hd, rep, scheme) + scratch


def strip_vmem_crossover(hd: int, rep: int, scheme: str = "in-place",
                         budget: int = VMEM_BUDGET_BYTES) -> int:
    """Smallest context length whose strip-kernel working set exceeds the
    VMEM budget — past this, only the chunked kernel is honest on TPU."""
    per_token = strip_vmem_bytes(1, hd, rep, scheme)
    return budget // per_token + 1
