"""Pallas TPU kernel: in-place SEC-DED (64,57,1) encode.

Runs once at deployment (and inside the protected-checkpoint writer): takes
WOT-compliant int8 weights, computes the 7 check bits per 64-bit block and
writes them into the non-informative bits. Memory-bound one-pass kernel,
mirror image of `ecc_decode`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import ecc

DEFAULT_BLK_N = 4096
_SIGN_KEEP = np.uint8(0xFF ^ (1 << ecc.CHECK_BIT))


def _encode_tile(blocks, rowmask):
    """(bn, 8) uint8 WOT weights -> encoded blocks. rowmask = ROWMASK64."""
    keep_last = jax.lax.broadcasted_iota(jnp.int32, (8,), 0) == 7
    zeroed = jnp.where(keep_last, blocks, blocks & _SIGN_KEEP)
    masked = zeroed[:, None, :] & rowmask           # (bn, 7, 8)
    pc = jax.lax.population_count(masked).astype(jnp.uint32)
    parity = (jnp.sum(pc, axis=-1) & 1).astype(jnp.uint8)   # (bn, 7)
    rowval = (jnp.uint8(1) << jax.lax.broadcasted_iota(jnp.uint8, (7,), 0))
    syn = jnp.sum(parity * rowval, axis=-1).astype(jnp.uint8)
    i = jax.lax.broadcasted_iota(jnp.uint8, (8,), 0)
    checks = (((syn[:, None] >> i) & 1) << ecc.CHECK_BIT).astype(jnp.uint8)
    checks = jnp.where(keep_last, jnp.uint8(0), checks)
    return zeroed | checks


def _kernel(w_ref, rowmask_ref, out_ref):
    out_ref[...] = _encode_tile(w_ref[...], rowmask_ref[...])


@functools.partial(jax.jit, static_argnames=("blk_n", "interpret"))
def ecc_encode(blocks: jnp.ndarray, *, blk_n: int = DEFAULT_BLK_N,
               interpret: bool = True) -> jnp.ndarray:
    """(nblk, 8) uint8 (WOT-compliant int8 bytes) -> encoded (nblk, 8)."""
    nblk = blocks.shape[0]
    blk_n = min(blk_n, nblk)
    assert nblk % blk_n == 0
    return pl.pallas_call(
        _kernel,
        grid=(nblk // blk_n,),
        in_specs=[pl.BlockSpec((blk_n, 8), lambda i: (i, 0)),
                  pl.BlockSpec((7, 8), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((blk_n, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, 8), jnp.uint8),
        interpret=interpret,
    )(blocks, jnp.asarray(ecc.ROWMASK64))
