"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True (CPU validation per the build environment);
on real TPU pass interpret=False.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ecc
from . import ecc_decode as _dec
from . import ecc_qmatmul as _qmm
from . import throttle as _thr


def decode_weights(enc_flat: jnp.ndarray, *, interpret: bool = True):
    """Flat uint8 ECC-encoded image (n % 8 == 0) -> (int8 weights, flags)."""
    blocks = enc_flat.reshape(-1, ecc.BLOCK_BYTES)
    dec, flags = _dec.ecc_decode(blocks, interpret=interpret)
    w = jax.lax.bitcast_convert_type(dec.reshape(-1), jnp.int8)
    return w, flags


def qmatmul_protected(a_q: jnp.ndarray, w_enc: jnp.ndarray, a_scale, w_scale,
                      *, interpret: bool = True) -> jnp.ndarray:
    """float output = (a_q @ decode(w_enc)) * a_scale * w_scale."""
    acc = _qmm.ecc_qmatmul(a_q, w_enc, interpret=interpret)
    return acc.astype(jnp.float32) * (a_scale * w_scale)


def throttle_flat(q_flat: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """WOT projection on a flat int8 vector (n % 8 == 0)."""
    out = _thr.throttle(q_flat.reshape(-1, 8), interpret=interpret)
    return out.reshape(-1)


def encode_weights(q_flat: jnp.ndarray, *, interpret: bool = True):
    """Flat int8 WOT-compliant weights (n % 8 == 0) -> encoded uint8 image."""
    from . import ecc_encode as _enc
    blocks = jax.lax.bitcast_convert_type(q_flat, jnp.uint8).reshape(-1, 8)
    return _enc.ecc_encode(blocks, interpret=interpret).reshape(-1)


def attention(q, k, v, *, interpret: bool = True, bq: int = 128,
              bk: int = 128):
    """Causal flash attention (B, H, S, D) -> (B, H, S, D)."""
    from . import flash_attention as _fa
    return _fa.flash_attention(q, k, v, bq=bq, bk=bk, interpret=interpret)


def deploy_quantize(w, *, interpret: bool = True):
    """fp32 weight tensor -> (WOT-compliant int8 (same shape), scale).
    Fused quantize+throttle; requires last dim % 8 == 0."""
    from . import quant_throttle as _qt
    q, scale = _qt.quantize_throttle(w.reshape(-1, 8), interpret=interpret)
    return q.reshape(w.shape), scale
