"""ShapeDtypeStruct stand-ins for every dry-run cell (no allocation).

``cell_specs(cfg, shape, multi_pod)`` returns (step_fn, arg_specs,
in_shardings, out_shardings, meta) ready for
``jax.jit(step_fn, ...).lower(*arg_specs).compile()``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh
from repro.models import lm
from repro.models.config import ArchConfig, ShapeConfig
from repro.serving import protected
from repro.training import optim, train


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ArchConfig, shape: ShapeConfig, *, micro: bool = True):
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32),
             "targets": _sds((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = _sds((b, cfg.n_patches, cfg.d_model),
                                      jnp.bfloat16)
    if cfg.family == "encdec":
        batch["enc_embeds"] = _sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return batch


def _sanitize(spec_tree, sds_tree, mesh):
    """Drop mesh axes from dims they don't divide (B=1 cells, odd head
    counts, enc_seq=1500, ...). One rule, shared with the plan layer."""
    from repro.protection.plan import _drop_nondividing
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree.map(
        lambda spec, sds: _drop_nondividing(spec, sds.shape, sizes),
        spec_tree, sds_tree, is_leaf=lambda x: isinstance(x, P))


def param_gib(cfg: ArchConfig) -> float:
    """Analytic total param size in GiB at cfg.param_dtype."""
    import numpy as np
    specs = lm.param_specs(cfg, jnp.dtype(cfg.param_dtype))
    return float(sum(np.prod(l.shape) * l.dtype.itemsize
                     for l in jax.tree.leaves(specs))) / 2**30


def train_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *, fsdp=None,
               sp=True, chunk=2048, seqs_per_shard=8, microbatch=None):
    """Training step cell: (step_fn, args, in_shardings, out_shardings).

    Perf defaults (see EXPERIMENTS.md §Perf): few microbatches (FSDP param
    all-gathers and grad reductions repeat per microbatch, so fewer micros =
    proportionally less collective traffic), FSDP auto-off when
    params+momentum fit model-sharded-only (< 5 GiB/chip)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    dp_size = sizes.get("pod", 1) * sizes["data"]
    if microbatch is None:
        n_micro = max(1, shape.global_batch // (dp_size * seqs_per_shard))
    else:
        n_micro = microbatch
    cfg = cfg.with_(microbatch=n_micro)
    if fsdp is None:
        # params + momentum, model-axis sharded only
        fsdp = 2 * param_gib(cfg) / sizes["model"] > 5.0
    lm.set_sharding_ctx({"dp": dp, "model": "model", "sp": sp,
                         "model_size": sizes["model"]})
    dtype = jnp.dtype(cfg.param_dtype)
    params = lm.param_specs(cfg, dtype)
    opt = optim.SgdState(params)
    batch = batch_struct(cfg, shape)

    pspec = sh.param_specs(params, fsdp=fsdp)
    pspec = _sanitize(pspec, params, mesh)
    ospec = optim.SgdState(pspec)
    bspec = _sanitize(sh.batch_specs(batch, multi_pod="pod" in mesh.axis_names),
                      batch, mesh)

    step = train.make_train_step(cfg, chunk=chunk)
    in_sh = (pspec, ospec, bspec)
    out_sh = (pspec, ospec, P())
    return step, (params, opt, batch), in_sh, out_sh


def _serving_fsdp_auto(cfg, mesh) -> bool:
    """int8 weight images: shard over 'data' too only when model-axis-only
    sharding would blow HBM (count GiB / model_shards > 5)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    count_gib = param_gib(cfg.with_(param_dtype="float32")) / 4
    return count_gib / sizes["model"] > 5.0


def serving_plan(cfg: ArchConfig, mesh, *, fsdp=None, policy=None):
    """One materialized ProtectionPlan per serving cell: resolved scheme /
    layout / backend / sharding spec for every weight leaf (abstract params,
    nothing allocated)."""
    if fsdp is None:
        fsdp = _serving_fsdp_auto(cfg, mesh)
    abstract = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32))
    return protected.make_plan(
        abstract, policy, mesh=mesh,
        param_spec_fn=functools.partial(sh.param_spec, fsdp=fsdp)), abstract


def decode_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *, fsdp=None,
                decode_per_step=True, decode_at_use=None, with_flags=False,
                policy=None, plan=None, abstract=None, act_quant=None,
                kv_policy=None):
    """Protected-serving decode cell (one new token, KV cache of seq_len).

    The cell is plan-driven: ``plan`` (or ``policy``, materialized here)
    decides scheme/backend per leaf and supplies the encoded tree's sharding
    specs — including 1-D sharded specs for flat-padded images. Callers
    that already hold the ``serving_plan`` pair pass both ``plan`` and
    ``abstract`` to skip re-tracing the param init.

    decode_at_use (default: follows decode_per_step) picks the fused
    decode-at-use step; False compiles the whole-tree decode-per-step
    ablation. with_flags adds the per-layer (corrected, DUE) counts as a
    third (replicated) output. act_quant ("dynamic" | "static" | "plan")
    compiles the int8 activation-quantized at-use step instead of the
    float one. kv_policy (a KVProtectionPolicy or preset name) swaps the
    dense ring buffers for the paged protected KV cache."""
    from repro.serving import kvcache
    lm.set_sharding_ctx(None)
    kvp = kvcache.get_kv_policy(kv_policy)
    if plan is None:
        plan, abstract = serving_plan(cfg, mesh, fsdp=fsdp, policy=policy)
    elif abstract is None:
        abstract = jax.eval_shape(
            lambda: lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32))
    b, s = shape.global_batch, shape.seq_len
    enc = jax.eval_shape(plan.encode_tree, abstract)
    cache = jax.eval_shape(
        lambda: kvcache.init_cache(cfg, b, s, kv_policy=kvp))
    tokens = _sds((b, 1), jnp.int32)
    pos = _sds((b,), jnp.int32)

    espec = plan.spec_tree(enc)   # plan sanitizes against the real mesh
    cspec = _sanitize(sh.cache_specs(cache), cache, mesh)
    tspec, posspec = _sanitize((P("data", None), P("data")),
                               (tokens, pos), mesh)

    step_inner = protected.make_serve_step(cfg, plan=plan,
                                           decode_per_step=decode_per_step,
                                           decode_at_use=decode_at_use,
                                           with_flags=with_flags,
                                           act_quant=act_quant,
                                           kv_policy=kvp)

    def step(enc_params, cache, tokens, pos):
        return step_inner(enc_params, cache, tokens, pos)

    data_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
    in_sh = (espec, cspec, tspec, posspec)
    lspec = (P("data", None, "model") if b % data_size == 0
             else P(None, None, "model"))
    out_sh = (lspec, cspec, P()) if with_flags else (lspec, cspec)
    return step, (enc, cache, tokens, pos), in_sh, out_sh


def prefill_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *, fsdp=None,
                 chunk=2048, sp=None, decode_at_use=True, with_flags=False,
                 policy=None, plan=None, abstract=None, act_quant=None):
    """Protected-serving prefill cell: full-sequence forward -> logits.

    sp auto: OFF when head-sharded attention can engage (n_heads divides the
    model axis — enables the triangle-unrolled chunk loop too; measured
    1.66x on deepseek-7b prefill_32k) or for attention-free archs; ON
    otherwise (non-divisible head counts regress 1.5-2x without SP)."""
    if fsdp is None:
        fsdp = _serving_fsdp_auto(cfg, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if sp is None:
        heads_ok = cfg.n_heads and cfg.n_heads % sizes["model"] == 0
        sp = not (heads_ok or cfg.family == "ssm")
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    lm.set_sharding_ctx({"dp": dp, "model": "model", "sp": sp,
                         "model_size": dict(zip(mesh.axis_names,
                                                mesh.devices.shape))["model"]})
    b, s = shape.global_batch, shape.seq_len
    if plan is None:
        plan, abstract = serving_plan(cfg, mesh, fsdp=fsdp, policy=policy)
    elif abstract is None:
        abstract = jax.eval_shape(
            lambda: lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32))
    enc = jax.eval_shape(plan.encode_tree, abstract)
    tokens = _sds((b, s), jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["prefix_embeds"] = _sds((b, cfg.n_patches, cfg.d_model),
                                       jnp.bfloat16)
    if cfg.family == "encdec":
        extras["enc_embeds"] = _sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)

    espec = plan.spec_tree(enc)   # plan sanitizes against the real mesh
    tspec = _sanitize(P(dp, None), tokens, mesh)
    xspec = _sanitize({k: sh.batch_spec(k, v, dp=dp) for k, v in extras.items()},
                      extras, mesh)

    prefill = protected.make_prefill(cfg, plan=plan, chunk=chunk,
                                     decode_at_use=decode_at_use,
                                     with_flags=with_flags,
                                     act_quant=act_quant)

    def step(enc_params, tokens, extras):
        return prefill(enc_params, tokens, extras)

    in_sh = (espec, tspec, xspec)
    s_out = s + (cfg.n_patches if cfg.family == "vlm" else 0)
    logits_sds = _sds((b, s_out, cfg.vocab_padded), jnp.bfloat16)
    lspec = _sanitize(P(dp, None, "model"), logits_sds, mesh)
    out_sh = (lspec, P()) if with_flags else lspec
    return step, (enc, tokens, extras), in_sh, out_sh


def cell(cfg: ArchConfig, shape: ShapeConfig, mesh, **kw):
    if shape.kind == "train":
        return train_cell(cfg, shape, mesh,
                          **{k: v for k, v in kw.items()
                             if k not in ("policy", "plan", "abstract",
                                          "decode_at_use", "with_flags",
                                          "act_quant")})
    if shape.kind == "prefill":
        return prefill_cell(cfg, shape, mesh, **kw)
    return decode_cell(cfg, shape, mesh,
                       **{k: v for k, v in kw.items()
                          if k in ("fsdp", "decode_per_step", "decode_at_use",
                                   "with_flags", "policy", "plan",
                                   "abstract", "act_quant", "kv_policy")})


def cell_supported(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (O(S^2) " \
                      "attention / O(S) KV cache at 524k is not deployable)"
    return True, ""
