"""Structural analysis of compiled (post-SPMD) HLO text for the roofline.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified), so for
scan-over-layers programs it under-counts by ~L x n_micro. This module walks
the HLO module properly:

* per-computation symbol table (%name -> shape) so operand shapes resolve,
* dot/convolution FLOPs from shapes + contracting dims,
* buffer-traffic bytes (result + operand bytes of materializing ops),
* collective wire bytes per device with ring-algorithm factors:
    all-gather          (n-1)/n * result_bytes
    all-reduce          2*(n-1)/n * operand_bytes
    reduce-scatter      (n-1)/n * operand_bytes
    all-to-all          (n-1)/n * operand_bytes
    collective-permute  operand_bytes
* call-graph aggregation with while trip-count multipliers
  (backend_config known_trip_count, else condition-constant inference).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_DOT_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_WHILE_BODY = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count["\s:{]+"?n"?\s*:?\s*"?(\d+)')
_CALLS_RE = re.compile(r"(?:to_apply|calls|body|branch_computations)="
                       r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# ops whose result+operand buffers we count as memory traffic
_TRAFFIC_OPS = {"dot", "convolution", "fusion", "copy", "gather", "scatter",
                "dynamic-slice", "dynamic-update-slice", "concatenate",
                "pad", "transpose", "broadcast", "reduce", "reduce-window",
                "sort", "select-and-scatter", "slice", "reverse", "add",
                "multiply", "subtract", "divide", "exponential", "tanh",
                "maximum", "minimum", "compare", "select", "convert",
                "rsqrt", "negate", "and", "or", "xor", "popcnt",
                "shift-left", "shift-right-logical", "iota"} | set(COLLECTIVES)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    return [int(d) for d in m.group(2).split(",") if d] if m else []


def _group_size(tail: str) -> int:
    m = _GROUPS_RE.search(tail)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS2_RE.search(tail)
    if m:
        return int(m.group(2))
    return 2


def _wire(kind: str, ob: float, rb: float, n: int) -> float:
    frac = (n - 1) / max(n, 1)
    if kind == "all-gather":
        return frac * rb
    if kind == "all-reduce":
        return 2 * frac * ob
    if kind in ("reduce-scatter", "all-to-all"):
        return frac * ob
    return float(ob)


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


class _CompStats:
    __slots__ = ("flops", "bytes", "coll", "children")

    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = defaultdict(lambda: [0, 0.0])
        self.children: list[tuple[str, int]] = []


def _parse_computation(lines: list[str], comp_names) -> _CompStats:
    st = _CompStats()
    table: dict[str, str] = {}  # %name -> type text
    parsed = []
    for line in lines:
        m = _INSTR_RE.match(line)
        if not m:
            # parameter lines: "%p = f32[..] parameter(0)" match too; others skip
            continue
        name, typ, opcode, rest = m.groups()
        table[name] = typ
        parsed.append((name, typ, opcode, rest, line))

    for name, typ, opcode, rest, line in parsed:
        base = opcode.replace("-start", "").replace("-done", "")
        if opcode.endswith("-done"):
            continue
        # operand byte resolution (first segment of rest, up to "), ")
        op_names = _OPERAND_RE.findall(rest.split("), ")[0] if ")," in rest
                                       else rest)
        ob = sum(_shape_bytes(table.get(o, "")) for o in op_names)
        rb = _shape_bytes(typ)

        if base in _TRAFFIC_OPS:
            st.bytes += rb + (ob if base in ("dot", "convolution", "fusion",
                                             "gather", "scatter", "copy",
                                             "dynamic-update-slice",
                                             "concatenate") else 0)
        if base == "dot":
            lhs = table.get(op_names[0], "") if op_names else ""
            lhs_dims = _first_dims(lhs)
            cm = _DOT_CDIMS.search(line)
            cdims = [int(x) for x in cm.group(1).split(",") if x] if cm else []
            contract = 1
            for c in cdims:
                if c < len(lhs_dims):
                    contract *= lhs_dims[c]
            res = 1
            for d in _first_dims(typ):
                res *= d
            st.flops += 2.0 * res * contract
        elif base == "convolution":
            ker = _first_dims(table.get(op_names[1], "")) if len(op_names) > 1 \
                else []
            k = 1
            for d in ker[:-1]:
                k *= d
            res = 1
            for d in _first_dims(typ):
                res *= d
            st.flops += 2.0 * res * k
        elif base in COLLECTIVES:
            n = _group_size(line)
            st.coll[base][0] += 1
            st.coll[base][1] += _wire(base, ob, rb, n)

        if base == "while":
            bm = _WHILE_BODY.search(line)
            if bm and bm.group(1) in comp_names:
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                st.children.append((bm.group(1), trips))
        elif base in ("fusion", "call", "conditional", "reduce",
                      "reduce-window", "scatter", "sort", "map",
                      "select-and-scatter", "all-reduce", "reduce-scatter",
                      "custom-call", "async-start"):
            cm2 = _CALLS_RE.search(line)
            if cm2:
                for callee in re.findall(r"[\w.\-]+", cm2.group(1)):
                    if callee in comp_names and base in ("call", "conditional",
                                                         "fusion"):
                        # fusion subcomputations already counted via traffic;
                        # only real calls multiply
                        if base in ("call", "conditional"):
                            st.children.append((callee, 1))
    return st


def compute_stats(hlo_text: str) -> dict:
    """{"flops", "buffer_bytes", "collectives": {kind: {count, wire_bytes}},
    "total_wire_bytes"} for one device's program, loop-trip aware."""
    comps = _split_computations(hlo_text)
    if not comps:
        comps = {"__all__": hlo_text.splitlines()}
    names = set(comps)
    stats = {n: _parse_computation(ls, names) for n, ls in comps.items()}

    called = {c for s in stats.values() for c, _ in s.children}
    roots = [n for n in comps if n not in called]
    # prefer the ENTRY computation if identifiable; else all uncalled
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry in names:
        roots = [entry]

    memo: dict[str, tuple] = {}

    def agg(name: str, depth=0):
        if name in memo:
            return memo[name]
        if depth > 100:
            return (0.0, 0.0, {})
        s = stats[name]
        f, b = s.flops, s.bytes
        coll = {k: list(v) for k, v in s.coll.items()}
        for callee, trips in s.children:
            cf, cb, cc = agg(callee, depth + 1)
            f += cf * trips
            b += cb * trips
            for kind, (c, w) in cc.items():
                coll.setdefault(kind, [0, 0.0])
                coll[kind][0] += c * trips
                coll[kind][1] += w * trips
        memo[name] = (f, b, coll)
        return memo[name]

    t_f = t_b = 0.0
    t_coll: dict = {}
    for r in roots:
        f, b, coll = agg(r)
        t_f += f
        t_b += b
        for kind, (c, w) in coll.items():
            t_coll.setdefault(kind, [0, 0.0])
            t_coll[kind][0] += c
            t_coll[kind][1] += w
    collectives = {k: {"count": v[0], "wire_bytes": v[1]}
                   for k, v in t_coll.items()}
    return {"flops": t_f, "buffer_bytes": t_b, "collectives": collectives,
            "total_wire_bytes": sum(v[1] for v in t_coll.values())}


def collective_bytes(hlo_text: str) -> dict:
    s = compute_stats(hlo_text)
    out = dict(s["collectives"])
    out["total_wire_bytes"] = s["total_wire_bytes"]
    return out
