"""Multi-pod dry-run: AOT lower+compile every (arch x shape x mesh) cell.

Placeholder host devices (512 by default — 2 pods x 256 TPU v5e chips)
stand in for the real fleet. For each cell we jit the real step function
with production in/out shardings, ``.lower().compile()``, and record
memory_analysis + cost_analysis + parsed collective traffic to JSONL for
the roofline (§Roofline in EXPERIMENTS.md).

The ``--policy`` axis sweeps named protection presets (see
``repro.protection.POLICY_PRESETS`` and docs/plans.md) over the serving
cells: each record carries the materialized ProtectionPlan's per-scheme
stored bytes plus peak-HBM and collective-traffic deltas against the
``unprotected`` (int8, zero checks) baseline of the same cell.

The ``--kv-policy`` axis does the same for serving STATE: decode cells
compile against the paged protected KV cache
(``repro.serving.kvcache``) under each named KV preset, the record
carries the cache's stored/check/scale byte split (see docs/kvcache.md),
and each protected-KV cell is diffed against the ``unprotected`` paged
cell of the same (cell, policy, mode) — the CI envelope asserts that
delta stays under 10% of the unprotected-KV peak.

Importing this module is side-effect-free; the CLI entry point calls
:func:`setup_host_devices` (which mutates ``XLA_FLAGS``) before touching
jax, and tests can import :func:`run_cell` without clobbering their
environment.

Usage:
  python -m repro.launch.dryrun --arch phi3-medium-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
  python -m repro.launch.dryrun --smoke --arch deepseek-7b --shape decode_32k \
      --policy attn-inplace-mlp-secded --mesh 2x4 --devices 8
"""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback


def setup_host_devices(n: int = 512) -> None:
    """Point XLA at ``n`` placeholder host devices. Must run before jax
    initializes its backend — the CLI calls it first thing in :func:`main`;
    importing this module never does."""
    os.environ["XLA_FLAGS"] = (
        os.environ.get("_EXTRA_XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={n}").strip()


def _mem_analysis(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
            if hasattr(ma, f):
                out[f] = int(getattr(ma, f))
    except Exception as e:  # noqa: BLE001 — record, don't die
        out["error"] = str(e)
    return out


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def _peak_bytes(mem: dict):
    """Per-device peak bytes: XLA's own number on TPU; on host backends
    (no peak_memory_in_bytes) the live-set estimate args+outputs+temps
    minus donated aliases."""
    if "peak_memory_in_bytes" in mem:
        return mem["peak_memory_in_bytes"]
    if "argument_size_in_bytes" not in mem:
        return None
    return (mem.get("argument_size_in_bytes", 0) +
            mem.get("output_size_in_bytes", 0) +
            mem.get("temp_size_in_bytes", 0) -
            mem.get("alias_size_in_bytes", 0))


def _mesh_name(multi_pod: bool, mesh_shape) -> str:
    if mesh_shape is not None:
        return "x".join(str(s) for s in mesh_shape)
    return "2x16x16" if multi_pod else "16x16"


def _plan_record(plan) -> dict:
    """The JSONL protection block: per-scheme stored bytes + totals."""
    s = plan.summary()
    return {"protected_bytes": s["protected_bytes"],
            "unprotected_bytes": s["unprotected_bytes"],
            "weight_bytes": s["weight_bytes"],
            "check_bytes": s["check_bytes"],
            "pad_bytes": s["pad_bytes"],
            "by_scheme": {sid: d["stored_bytes"]
                          for sid, d in s["by_scheme"].items()},
            "by_backend": s["by_backend"],
            "n_flat_sharded": s["n_flat_sharded"]}


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, fsdp=None,
             sp=True, decode_per_step=True, decode_at_use=None, chunk=2048,
             save_hlo: str | None = None, microbatch=None,
             policy: str | None = None, smoke: bool = False, layers=None,
             with_flags=None, mesh_shape=None, act_quant: str | None = None,
             baseline: dict | None = None,
             kv_policy: str | None = None) -> dict:
    """Compile one cell and return its JSONL record.

    policy:        named protection preset for serving cells (train cells
                   ignore it); the record gains the plan's per-scheme bytes.
    decode_at_use: serving decode mode — True (default) fuses the decode
                   into each weight's point of use; False compiles the
                   whole-tree decode-per-step ablation. The record carries
                   ``decode_mode`` so the two compile side by side.
    act_quant:     "dynamic" compiles the int8 activation-quantized at-use
                   step (``decode_mode`` becomes "at-use-int8"); the record
                   carries ``act_quant`` so the int8 cell diffs against the
                   float at-use cell of the same policy.
    layers:        optional n_layers override (depth scaling for the
                   decoded-tree HBM story at smoke scale).
    baseline:      a previous record (same cell, ``unprotected`` policy) to
                   diff against — fills ``hbm_delta_bytes`` /
                   ``wire_delta_bytes``.
    kv_policy:     named KV protection preset (decode cells only): compile
                   against the paged protected KV cache and record its
                   stored/check/scale byte split under ``kv``.
    """
    import jax
    import numpy as np

    from repro import configs, protection
    from repro.launch import hlo_analysis, specs
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPES

    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    if layers:
        cfg = cfg.with_(n_layers=layers)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": _mesh_name(multi_pod, mesh_shape), "fsdp": fsdp, "sp": sp,
           "smoke": smoke}
    serving = shape.kind != "train"
    if kv_policy is not None and shape.kind != "decode":
        kv_policy = None  # the paged cache is decode-step state
    if decode_at_use is None:
        decode_at_use = decode_per_step
    if shape.kind == "decode" and not decode_per_step:
        decode_at_use = False  # decode-once baseline: weights arrive decoded
    if act_quant and not (serving and decode_at_use):
        act_quant = None  # int8 activations ride the at-use serving path only
    if serving:
        rec["decode_mode"] = (
            "at-use-int8" if act_quant else
            "at-use" if decode_at_use else
            "per-step" if (decode_per_step or shape.kind == "prefill")
            else "once")
        if act_quant:
            rec["act_quant"] = act_quant
    if policy and serving:
        rec["policy"] = policy
    ok, why = specs.cell_supported(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return _tag_cell(rec)
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
        kw = ({"decode_per_step": decode_per_step} if shape.kind == "decode"
              else {"chunk": chunk})
        if serving:
            kw["decode_at_use"] = decode_at_use
            if act_quant:
                kw["act_quant"] = act_quant
        if shape.kind == "train" and microbatch is not None:
            kw["microbatch"] = microbatch
        if shape.kind == "train":
            kw["sp"] = sp  # prefill uses its own default (sp off)
        if policy and serving:
            pol = protection.get_policy_preset(policy)
            plan, abstract = specs.serving_plan(cfg, mesh, fsdp=fsdp,
                                                policy=pol)
            flags = decode_at_use if with_flags is None else with_flags
            kw.update(plan=plan, abstract=abstract, with_flags=flags)
            rec["protection"] = _plan_record(plan)
            rec["protection"]["flags_output"] = bool(flags)
        if kv_policy:
            from repro.serving import kvcache
            kvp = kvcache.get_kv_policy(kv_policy)
            kw["kv_policy"] = kvp
            rec["kv_policy"] = kv_policy
            b_, s_ = shape.global_batch, shape.seq_len
            cache_abs = jax.eval_shape(
                lambda: kvcache.init_paged_cache(cfg, b_, s_, kvp))
            rec["kv"] = {**kvcache.kv_bytes(cache_abs),
                         "dense_bytes": kvcache.dense_kv_bytes(cfg, b_, s_),
                         "scheme": kvp.scheme, "fused": kvp.fused,
                         "page_size": kvp.page_size}
        step, args, in_sh, out_sh = specs.cell(cfg, shape, mesh, fsdp=fsdp, **kw)
        from jax.sharding import NamedSharding, PartitionSpec as P
        as_named = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
            tree, is_leaf=lambda x: isinstance(x, P))
        # donate the big state buffers (params+opt for train, cache for
        # decode) so update-in-place aliases instead of doubling HBM
        donate = (0, 1) if shape.kind == "train" else \
            ((1,) if shape.kind == "decode" else ())
        with mesh:
            jitted = jax.jit(step, in_shardings=as_named(in_sh),
                             out_shardings=as_named(out_sh),
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        hlo = compiled.as_text()
        stats = hlo_analysis.compute_stats(hlo)
        rec.update(
            status="ok", lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=_mem_analysis(compiled), cost=_cost_analysis(compiled),
            hlo_flops=stats["flops"], hlo_buffer_bytes=stats["buffer_bytes"],
            collectives={"total_wire_bytes": stats["total_wire_bytes"],
                         **stats["collectives"]},
            n_devices=int(np.prod(mesh.devices.shape)),
        )
        rec["hbm_bytes"] = _peak_bytes(rec["memory"])
        if baseline and baseline.get("status") == "ok":
            base_peak = _peak_bytes(baseline.get("memory", {}))
            if rec["hbm_bytes"] is not None and base_peak is not None:
                rec["hbm_delta_bytes"] = rec["hbm_bytes"] - base_peak
            rec["wire_delta_bytes"] = (
                rec["collectives"]["total_wire_bytes"] -
                baseline.get("collectives", {}).get("total_wire_bytes", 0))
            rec["baseline_policy"] = baseline.get("policy")
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:],
                   elapsed_s=round(time.time() - t0, 1))
    return _tag_cell(rec)


def _tag_cell(rec: dict) -> dict:
    """Stamp the record with its unique grid coordinate — one string key
    downstream scripts (CI envelope asserts, telemetry joins) can group
    on instead of reconstructing axis tuples per schema version."""
    parts = [rec["arch"], rec["shape"], rec["mesh"]]
    for axis in ("policy", "decode_mode", "act_quant", "kv_policy"):
        if rec.get(axis):
            parts.append(f"{axis}={rec[axis]}")
    rec["cell"] = ":".join(parts)
    return rec


def _parse_mesh(s: str | None):
    if not s:
        return None
    return tuple(int(d) for d in s.lower().split("x"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--fsdp", choices=["auto", "on", "off"], default="auto")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--no-sp", action="store_true")
    ap.add_argument("--no-decode-per-step", action="store_true")
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's smoke config (CI-sized grids)")
    ap.add_argument("--layers", type=int, default=None,
                    help="override n_layers (depth scaling for the "
                         "decoded-tree HBM accounting at smoke scale)")
    ap.add_argument("--serve-modes", default="at-use,per-step",
                    help="comma list of decode modes compiled per policy "
                         "serving cell (at-use | per-step)")
    ap.add_argument("--act-quant", action="store_true",
                    help="also compile an int8 activation-quantized at-use "
                         "cell per policy serving cell (decode_mode "
                         "'at-use-int8', dynamic per-token scales), diffed "
                         "against the float at-use cell")
    ap.add_argument("--mesh", default=None, metavar="DxM[xP]",
                    help="override mesh dims, e.g. 2x4 (data x model)")
    ap.add_argument("--devices", type=int, default=512,
                    help="placeholder host device count (XLA_FLAGS)")
    ap.add_argument("--policy", default=None,
                    help="comma-separated protection presets to sweep over "
                         "serving cells (each diffed vs the 'unprotected' "
                         "baseline cell)")
    ap.add_argument("--kv-policy", default=None,
                    help="comma-separated KV protection presets (see "
                         "repro.serving.kvcache.KV_POLICY_PRESETS) swept "
                         "over decode cells; protected-KV cells diff their "
                         "peak HBM vs the 'unprotected' paged cell of the "
                         "same (cell, policy, mode)")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already recorded ok in --out")
    args = ap.parse_args()

    setup_host_devices(args.devices)
    from repro import configs, protection  # after XLA_FLAGS — see docstring
    from repro.models.config import SHAPES

    mesh_shape = _parse_mesh(args.mesh)
    policies = [p.strip() for p in args.policy.split(",") if p.strip()] \
        if args.policy else []
    for p in policies:
        if p not in protection.POLICY_PRESETS:
            ap.error(f"unknown policy preset {p!r}; one of "
                     f"{sorted(protection.POLICY_PRESETS)}")
    from repro.serving import kvcache
    kv_policies = [p.strip() for p in args.kv_policy.split(",") if p.strip()] \
        if args.kv_policy else []
    for p in kv_policies:
        if p not in kvcache.KV_POLICY_PRESETS:
            ap.error(f"unknown kv policy preset {p!r}; one of "
                     f"{sorted(kvcache.KV_POLICY_PRESETS)}")
    # the unprotected paged cell is every protected-KV cell's HBM baseline:
    # compile it first so the deltas land on the same pass
    kv_policies.sort(key=lambda p: p != "unprotected")

    cells = []
    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    modes = [m.strip() for m in args.serve_modes.split(",") if m.strip()]
    for m in modes:
        if m not in ("at-use", "per-step"):
            ap.error(f"unknown serve mode {m!r}; one of at-use, per-step")
    if args.act_quant:
        if args.no_decode_per_step:
            ap.error("--act-quant needs the decode-at-use serving path; "
                     "drop --no-decode-per-step")
        modes.append("at-use-int8")
    if args.no_decode_per_step:
        modes = [None]  # decode-once baseline: the mode axis is meaningless

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    prev = {}  # resumed records, so delta baselines survive --resume
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    key = (r["arch"], r["shape"], r["mesh"], r.get("policy"),
                           r.get("decode_mode"), r.get("kv_policy"))
                    done.add(key)
                    prev[key] = r

    fsdp = {"auto": None, "on": True, "off": False}[args.fsdp]
    common = dict(fsdp=fsdp, sp=not args.no_sp,
                  decode_per_step=not args.no_decode_per_step,
                  chunk=args.chunk, save_hlo=args.save_hlo,
                  microbatch=args.microbatch, smoke=args.smoke,
                  layers=args.layers, mesh_shape=mesh_shape)

    def emit(rec):
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        status = rec["status"]
        extra = rec.get("reason") or rec.get("error", "")
        flops = rec.get("cost", {}).get("flops", 0)
        deltas = ""
        if "wire_delta_bytes" in rec:
            deltas = (f" dHBM={rec.get('hbm_delta_bytes', 0):+.3g}B "
                      f"dwire={rec['wire_delta_bytes']:+.3g}B")
        print(f"  -> {status} flops={flops:.3g} "
              f"coll={rec.get('collectives', {}).get('total_wire_bytes', 0):.3g}B"
              f"{deltas} {extra[:120]}", flush=True)

    for a, s, mp in cells:
        mesh_name = _mesh_name(mp, mesh_shape)
        serving = SHAPES[s].kind != "train"
        cell_policies = policies if (policies and serving) else [None]
        cell_modes = modes if (policies and serving) else [None]
        cell_kvs = kv_policies if (kv_policies
                                   and SHAPES[s].kind == "decode") else [None]
        baseline = None
        base_mode = ("at-use" if not args.no_decode_per_step else
                     "per-step" if SHAPES[s].kind == "prefill" else "once")
        if cell_policies != [None] and any(p != "unprotected"
                                           for p in cell_policies):
            # the delta baseline: same cell, int8 storage, zero checks,
            # decode-at-use (no whole-tree decode inflating its peak)
            bkey = (a, s, mesh_name, "unprotected", base_mode, None)
            if bkey in done:
                baseline = prev.get(bkey)
            else:
                print(f"[cell] {a} {s} {mesh_name} policy=unprotected "
                      f"(baseline) ...", flush=True)
                baseline = run_cell(a, s, mp, policy="unprotected", **common)
                emit(baseline)
                done.add(bkey)
                prev[bkey] = baseline
        for pol in cell_policies:
            for mode in cell_modes:
              for kvp in cell_kvs:
                key_mode = mode if mode is not None else \
                    (base_mode if serving else None)
                if (pol == "unprotected" and baseline is not None
                        and mode == base_mode and kvp is None):
                    continue  # already emitted as the baseline
                if (a, s, mesh_name, pol, key_mode, kvp) in done:
                    print(f"[skip-done] {a} {s} {mesh_name} {pol or ''} "
                          f"{key_mode or ''} {kvp or ''}", flush=True)
                    continue
                print(f"[cell] {a} {s} {mesh_name}"
                      f"{f' policy={pol}' if pol else ''}"
                      f"{f' mode={mode}' if mode else ''}"
                      f"{f' kv={kvp}' if kvp else ''} ...", flush=True)
                kw = dict(common)
                if mode is not None:
                    kw["decode_at_use"] = mode != "per-step"
                    if mode == "at-use-int8":
                        kw["act_quant"] = "dynamic"
                rec = run_cell(a, s, mp, policy=pol, baseline=baseline,
                               kv_policy=kvp, **kw)
                if mode == "at-use-int8":
                    # the delta the int8 path is judged by: vs the FLOAT
                    # at-use cell of the same (cell, policy); null deltas
                    # when that cell is missing (e.g. --serve-modes without
                    # at-use) rather than silently diffing against nothing
                    fkey = (a, s, mesh_name, pol, "at-use", kvp)
                    frec = prev.get(fkey)
                    if rec.get("status") == "ok":
                        deltas = {"hbm_delta_bytes": None,
                                  "wire_delta_bytes": None}
                        if frec and frec.get("status") == "ok":
                            fpeak = _peak_bytes(frec.get("memory", {}))
                            peak = _peak_bytes(rec.get("memory", {}))
                            if None not in (peak, fpeak):
                                deltas["hbm_delta_bytes"] = peak - fpeak
                            fwire = frec.get("collectives", {}).get(
                                "total_wire_bytes")
                            if fwire is not None:
                                deltas["wire_delta_bytes"] = (
                                    rec["collectives"]["total_wire_bytes"]
                                    - fwire)
                        rec["vs_float_at_use"] = deltas
                if (kvp not in (None, "unprotected")
                        and rec.get("status") == "ok"):
                    # the CI envelope delta: protected-KV vs the unprotected
                    # paged cell of the same (cell, policy, mode)
                    tkey = (a, s, mesh_name, pol, key_mode, "unprotected")
                    trec = prev.get(tkey)
                    kv_delta = {"hbm_delta_bytes": None, "hbm_ratio": None}
                    if trec and trec.get("status") == "ok":
                        tpeak = _peak_bytes(trec.get("memory", {}))
                        peak = _peak_bytes(rec.get("memory", {}))
                        if None not in (peak, tpeak) and tpeak:
                            kv_delta["hbm_delta_bytes"] = peak - tpeak
                            kv_delta["hbm_ratio"] = (peak - tpeak) / tpeak
                    rec["kv_vs_unprotected"] = kv_delta
                emit(rec)
                if rec.get("status") in ("ok", "skipped"):
                    done.add((a, s, mesh_name, pol, key_mode, kvp))
                    prev[(a, s, mesh_name, pol, key_mode, kvp)] = rec


if __name__ == "__main__":
    main()
