import os
os.environ["XLA_FLAGS"] = (os.environ.get("_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: AOT lower+compile every (arch x shape x mesh) cell.

512 placeholder host devices stand in for 2 pods x 256 TPU v5e chips. For
each cell we jit the real step function with production in/out shardings,
``.lower().compile()``, and record memory_analysis + cost_analysis + parsed
collective traffic to JSONL for the roofline (§Roofline in EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch phi3-medium-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro import configs
from repro.launch import hlo_analysis, specs
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES


def _mem_analysis(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
            if hasattr(ma, f):
                out[f] = int(getattr(ma, f))
    except Exception as e:  # noqa: BLE001 — record, don't die
        out["error"] = str(e)
    return out


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, fsdp=None,
             sp=True, decode_per_step=True, chunk=2048,
             save_hlo: str | None = None, microbatch=None) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "fsdp": fsdp, "sp": sp}
    ok, why = specs.cell_supported(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        kw = ({"decode_per_step": decode_per_step} if shape.kind == "decode"
              else {"chunk": chunk})
        if shape.kind == "train" and microbatch is not None:
            kw["microbatch"] = microbatch
        if shape.kind == "train":
            kw["sp"] = sp  # prefill uses its own default (sp off)
        step, args, in_sh, out_sh = specs.cell(cfg, shape, mesh, fsdp=fsdp, **kw)
        from jax.sharding import NamedSharding, PartitionSpec as P
        as_named = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
            tree, is_leaf=lambda x: isinstance(x, P))
        # donate the big state buffers (params+opt for train, cache for
        # decode) so update-in-place aliases instead of doubling HBM
        donate = (0, 1) if shape.kind == "train" else \
            ((1,) if shape.kind == "decode" else ())
        with mesh:
            jitted = jax.jit(step, in_shardings=as_named(in_sh),
                             out_shardings=as_named(out_sh),
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        hlo = compiled.as_text()
        stats = hlo_analysis.compute_stats(hlo)
        rec.update(
            status="ok", lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=_mem_analysis(compiled), cost=_cost_analysis(compiled),
            hlo_flops=stats["flops"], hlo_buffer_bytes=stats["buffer_bytes"],
            collectives={"total_wire_bytes": stats["total_wire_bytes"],
                         **stats["collectives"]},
            n_devices=int(np.prod(mesh.devices.shape)),
        )
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:],
                   elapsed_s=round(time.time() - t0, 1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--fsdp", choices=["auto", "on", "off"], default="auto")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--no-sp", action="store_true")
    ap.add_argument("--no-decode-per-step", action="store_true")
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already recorded ok in --out")
    args = ap.parse_args()

    cells = []
    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    for a, s, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        if (a, s, mesh_name) in done:
            print(f"[skip-done] {a} {s} {mesh_name}", flush=True)
            continue
        print(f"[cell] {a} {s} {mesh_name} ...", flush=True)
        fsdp = {"auto": None, "on": True, "off": False}[args.fsdp]
        rec = run_cell(a, s, mp, fsdp=fsdp, sp=not args.no_sp,
                       decode_per_step=not args.no_decode_per_step,
                       chunk=args.chunk, save_hlo=args.save_hlo,
                       microbatch=args.microbatch)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        status = rec["status"]
        extra = rec.get("reason") or rec.get("error", "")
        flops = rec.get("cost", {}).get("flops", 0)
        print(f"  -> {status} flops={flops:.3g} "
              f"coll={rec.get('collectives', {}).get('total_wire_bytes', 0):.3g}B"
              f" {extra[:120]}", flush=True)


if __name__ == "__main__":
    main()
