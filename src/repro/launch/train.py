"""End-to-end training driver.

Local mode (default, CPU): trains a reduced config of any assigned arch on
synthetic data with the full production stack — QAT + WOT throttling, SGD
momentum, grad accumulation, async ECC-protected checkpointing, resume after
failure. Production mode (--mesh 16x16 on real hardware) uses the same code
path with the sharded mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import synthetic
from repro.models import lm
from repro.training import checkpoint, optim, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--no-wot", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg = cfg.with_(microbatch=max(1, args.batch // 4))
    print(f"[train] {cfg.name} ({cfg.family}) layers={cfg.n_layers} "
          f"d={cfg.d_model} vocab={cfg.vocab_padded}")

    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = optim.sgd_init(params)
    step0 = 0

    ckpt_mgr = None
    if args.ckpt:
        ckpt_mgr = checkpoint.AsyncCheckpointer(args.ckpt, protected=True)
        last = checkpoint.latest_step(args.ckpt)
        if last is not None:
            (params, opt), step0 = checkpoint.restore(args.ckpt, (params, opt))
            print(f"[train] resumed from step {step0}")

    step_fn = jax.jit(train.make_train_step(
        cfg, lr=args.lr, wot_throttle=not args.no_wot, chunk=64))

    extras = {}
    if cfg.family == "vlm":
        extras["prefix_embeds"] = jnp.zeros((args.batch, cfg.n_patches,
                                             cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        extras["enc_embeds"] = jnp.asarray(np.random.default_rng(0).normal(
            size=(args.batch, cfg.enc_seq, cfg.d_model)), jnp.bfloat16)

    t0 = time.time()
    for step in range(step0, args.steps):
        batch = synthetic.token_batch(cfg.vocab_padded, args.batch, args.seq,
                                      seed=args.seed, step=step)
        batch = {**{k: jnp.asarray(v) for k, v in batch.items()}, **extras}
        params, opt, loss = step_fn(params, opt, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")
        if ckpt_mgr and (step + 1) % args.ckpt_every == 0:
            ckpt_mgr.save((params, opt), step + 1)
    if ckpt_mgr:
        ckpt_mgr.save((params, opt), args.steps)
        ckpt_mgr.wait()
        print(f"[train] checkpointed to {args.ckpt}")
    return params


if __name__ == "__main__":
    main()
