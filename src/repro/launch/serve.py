"""Protected-serving driver: batched decode with ECC-encoded weights.

Demonstrates the full serving path at local scale: build a
``ProtectionPolicy`` (scheme + backend selectable), encode the weights,
report coverage, inject memory faults at a chosen rate, and decode-serve
batched requests — faults are corrected on the fly.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b \
      --fault-rate 1e-4 --tokens 32 [--scheme in-place] [--backend xla]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs, protection
from repro.models import lm
from repro.serving import protected


def inject_tree(enc_params, rate: float, seed: int):
    """Flip random bits in every encoded weight image (memory fault model).

    Kept as the serving-facing name; delegates to
    :func:`repro.protection.inject_tree`.
    """
    return protection.inject_tree(enc_params, rate, seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--fault-rate", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheme", default="in-place",
                    choices=sorted(set(protection.scheme_ids()) |
                                   set(protection.ALIASES)))
    ap.add_argument("--backend", default="xla",
                    choices=sorted(protection.BACKENDS))
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    print(f"[serve] {cfg.name} smoke config, scheme={args.scheme}, "
          f"backend={args.backend}, fault_rate={args.fault_rate}")
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    policy = protection.ProtectionPolicy(default_scheme=args.scheme,
                                         backend=args.backend)
    print("[serve] " +
          policy.coverage(params).summary().replace("\n", "\n[serve] "))
    enc = policy.encode_tree(params)
    if args.fault_rate:
        enc = inject_tree(enc, args.fault_rate, args.seed)
        print("[serve] injected faults into the resident weight images")

    serve_step = jax.jit(protected.make_serve_step(cfg, backend=args.backend))
    cache = lm.init_cache(cfg, args.batch, max(64, args.tokens * 2))
    tokens = jnp.zeros((args.batch, 1), jnp.int32)
    t0 = time.time()
    out = []
    for t in range(args.tokens):
        pos = jnp.full((args.batch,), t, jnp.int32)
        logits, cache = serve_step(enc, cache, tokens, pos)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(int(tokens[0, 0]))
    dt = time.time() - t0
    print(f"[serve] {args.tokens} steps x batch {args.batch} in {dt:.2f}s "
          f"({args.tokens * args.batch / dt:.1f} tok/s)")
    print(f"[serve] sample continuation: {out}")


if __name__ == "__main__":
    main()
