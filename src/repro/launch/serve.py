"""Protected-serving driver: batched decode with ECC-encoded weights.

Demonstrates the full serving path at local scale: build a
``ProtectionPolicy`` (scheme + backend selectable), encode the weights,
report coverage, inject memory faults at a chosen rate, and decode-serve
batched requests — faults are corrected on the fly.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b \
      --fault-rate 1e-4 --tokens 32 [--scheme in-place] [--backend xla] \
      [--policy attn-inplace-mlp-secded] [--autotune BENCH_kernels.json] \
      [--abft] [--act-clamp]

``--abft`` turns on in-kernel ABFT checksum verification for every
protected matmul (compute-fault detection next to the memory-fault ECC
flags; see docs/abft.md); ``--act-clamp`` calibrates per-leaf activation
absmax bounds from a seeded batch and fuses the Geissler-style range
clamps into the same epilogue. Both report through the ``*_abft`` flags
channel after the run.

``--policy`` serves under a named mixed-scheme preset: the materialized
``ProtectionPlan`` decides scheme and backend per leaf (``--autotune``
feeds the shape-keyed backend table), and the serve step decodes each
leaf accordingly — one model, many schemes, many backends.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs, protection
from repro.models import lm
from repro.serving import kvcache, protected


def inject_tree(enc_params, rate: float, seed: int):
    """Flip random bits in every encoded weight image (memory fault model).

    Kept as the serving-facing name; delegates to the on-device
    :func:`repro.protection.inject_tree_device` (jit-safe, no host
    round-trip per leaf).  Injection builds a transient per-bit parity
    vector per leaf (8x its stored bytes), sized for smoke/eval-scale
    weights — production-scale leaves should shard the image first.
    """
    return protection.inject_tree_device(enc_params, rate,
                                         jax.random.PRNGKey(seed))


def fault_smoke_check(enc, policy, rate: float, seed: int, *,
                      trials: int = 2, campaign_key: int | None = None,
                      out_path: str | None = None):
    """Compiled campaign smoke-check before serving with injected faults:
    sweep {rate/10, rate, 10*rate} x ``trials`` in one device program and
    report the decode fidelity (fraction of protected weights that still
    decode to their clean values) AND the DUE (detected-uncorrectable)
    count at each rate.  ``batch="scan"`` keeps peak memory at one cell's
    buffers — serving trees are the big-model case of the vmap-vs-scan
    guidance in docs/campaigns.md.

    ``campaign_key`` seeds the campaigns' own key stream (default: derive
    from ``seed``); ``out_path`` writes the full JSON record — trials,
    key, per-rate fidelity and DUE means — next to the printed digest."""
    rates = tuple(sorted({rate / 10, rate, min(rate * 10, 0.01)}))
    ckey = seed + 1 if campaign_key is None else campaign_key
    res = protection.fidelity_campaign(enc, policy, rates=rates,
                                       trials=trials,
                                       key=jax.random.PRNGKey(ckey),
                                       batch="scan")
    cells = "  ".join(f"{r:.0e}:{m * 100:6.2f}%"
                      for r, m in zip(res.rates, res.mean()))
    print(f"[serve] fault smoke-check ({res.scheme}, {res.batch} campaign, "
          f"{trials} trials, compile {res.compile_s:.1f}s, sweep "
          f"{res.wall_clock_s:.2f}s): decode fidelity {cells}")
    due = protection.due_campaign(enc, policy, rates=rates, trials=trials,
                                  key=jax.random.PRNGKey(ckey + 1),
                                  batch="scan")
    cells = "  ".join(f"{r:.0e}:{m:7.1f}"
                      for r, m in zip(due.rates, due.mean()))
    print(f"[serve] DUE (double-error) counts per rate: {cells}")
    if out_path:
        import json
        rec = {"trials": trials, "campaign_key": ckey,
               "rates": list(res.rates), "scheme": res.scheme,
               "batch": res.batch,
               "fidelity_mean": [float(m) for m in res.mean()],
               "due_mean": [float(m) for m in due.mean()]}
        with open(out_path, "w") as fh:
            json.dump(rec, fh, indent=2)
            fh.write("\n")
        print(f"[serve] wrote campaign record to {out_path}")
    return res


def run_burst_mode(cfg, enc, plan, args, repair_kit=None):
    """``--burst``: replay a seeded wave workload through the
    request-level front-end (see :mod:`repro.serving.frontend` and
    docs/serving.md) and print the telemetry roll-up."""
    import os

    from repro.serving import frontend, telemetry

    kvp = args.kv_policy or "in-place"
    waves = frontend.make_waves(seed=args.seed, n_waves=2,
                                wave_size=args.batch, vocab=cfg.vocab,
                                prompt_len=(4, 8),
                                max_new=(4, args.tokens),
                                gap_steps=6)
    tpath = None
    if args.burst_out:
        os.makedirs(args.burst_out, exist_ok=True)
        tpath = os.path.join(args.burst_out, "telemetry.jsonl")
    events, summ, _ = frontend.run_burst(
        cfg, enc, plan=plan, waves=waves, slots=max(2, args.batch // 2),
        max_len=max(32, args.tokens * 2), kv_policy=kvp,
        fault_rate=args.fault_rate, fault_seed=args.seed,
        telemetry_path=tpath, scrub_every=args.scrub_every,
        repair=args.repair, repair_kit=repair_kit)
    r, t, d, p = (summ["requests"], summ["throughput"], summ["due"],
                  summ["pool"])
    print(f"[serve] burst ({kvp} KV): {r['finished']}/{r['submitted']} "
          f"requests in {summ['steps']} steps "
          f"({t['tokens_per_step']:.2f} tok/step)")
    print(f"[serve] TTFT p50/p95/p99: {summ['ttft_steps']['p50']}/"
          f"{summ['ttft_steps']['p95']}/{summ['ttft_steps']['p99']} steps; "
          f"per-token p99 {summ['per_token_ms']['p99']:.2f}ms")
    print(f"[serve] KV faults: {d['corrected_total']} corrected, "
          f"{d['total']} DUE ({d['requests_with_due']} requests); "
          f"pages leaked {p['leaked_pages']}")
    heal = summ["healing"]
    if heal["scrub_passes"]:
        fd = heal["final_due"]
        tail = (f", final at-rest DUE {fd['w']}w/{fd['kv']}kv"
                if fd else "")
        print(f"[serve] self-healing: {heal['scrub_passes']} scrub passes, "
              f"corrected w={heal['w_corrected']} kv={heal['kv_corrected']}"
              f", repairs {heal['repairs'] or '{}'}{tail}")
    if args.burst_out:
        telemetry.write_requests_csv(
            events, os.path.join(args.burst_out, "requests.csv"))
        telemetry.write_summary(summ,
                                os.path.join(args.burst_out,
                                             "summary.json"))
        print(f"[serve] wrote {args.burst_out}/telemetry.jsonl, "
              f"requests.csv, summary.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--fault-rate", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheme", default="in-place",
                    choices=sorted(set(protection.scheme_ids()) |
                                   set(protection.ALIASES)))
    ap.add_argument("--backend", default="xla",
                    choices=sorted(protection.BACKENDS))
    ap.add_argument("--policy", default=None,
                    choices=sorted(protection.POLICY_PRESETS),
                    help="serve under a named mixed-scheme preset "
                         "(overrides --scheme)")
    ap.add_argument("--autotune", default=None, metavar="BENCH_kernels.json",
                    help="shape-keyed backend table for per-leaf dispatch")
    ap.add_argument("--kv-policy", default=None,
                    choices=sorted(kvcache.KV_POLICY_PRESETS),
                    help="serve against the paged protected KV cache under "
                         "this preset; with --fault-rate, faults are also "
                         "injected into the LIVE cache pools mid-run")
    ap.add_argument("--burst", action="store_true",
                    help="serve a seeded burst workload through the "
                         "request-level front-end (continuous batching, "
                         "admission control, telemetry summary) instead of "
                         "the fixed-batch loop; uses --kv-policy (default "
                         "in-place), --fault-rate as the live-KV injection "
                         "rate, and --seed for the workload")
    ap.add_argument("--burst-out", default=None, metavar="DIR",
                    help="with --burst: write telemetry JSONL + "
                         "requests CSV + summary JSON here")
    ap.add_argument("--trials", type=int, default=2,
                    help="trials per rate for the fault smoke-check "
                         "campaigns (fidelity + DUE)")
    ap.add_argument("--campaign-key", type=int, default=None,
                    help="explicit base key for the smoke-check campaign "
                         "streams (default: seed + 1)")
    ap.add_argument("--campaign-out", default=None, metavar="FILE",
                    help="write the smoke-check campaign record "
                         "(trials, key, per-rate means) as JSON")
    ap.add_argument("--scrub-every", type=int, default=0,
                    help="self-healing: scrub weights (and, in --burst "
                         "mode, live KV pages) every N steps")
    ap.add_argument("--repair", action="store_true",
                    help="pin a MILR repair kit from the clean tree and "
                         "repair/quarantine scrub-detected weight DUEs")
    ap.add_argument("--abft", action="store_true",
                    help="verify ABFT checksums inside every protected "
                         "matmul (row/col sums vs the accumulator, same "
                         "kernel pass); mismatches surface on the "
                         "*_abft flags channel")
    ap.add_argument("--act-clamp", action="store_true",
                    help="calibrate per-leaf activation absmax bounds from "
                         "a seeded batch and fuse the range clamps into "
                         "the matmul epilogue; clamp hits ride the *_abft "
                         "flags channel")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    label = f"policy={args.policy}" if args.policy else f"scheme={args.scheme}"
    print(f"[serve] {cfg.name} smoke config, {label}, "
          f"backend={args.backend}, fault_rate={args.fault_rate}")
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.policy:
        policy = protection.get_policy_preset(args.policy,
                                              backend=args.backend,
                                              autotune=args.autotune)
    else:
        policy = protection.ProtectionPolicy(default_scheme=args.scheme,
                                             backend=args.backend,
                                             autotune=args.autotune)
    plan = policy.plan(params)
    s = plan.summary()
    print("[serve] " +
          plan.coverage().summary().replace("\n", "\n[serve] "))
    schemes = ", ".join(f"{k}={v['stored_bytes']}B"
                        for k, v in sorted(s["by_scheme"].items()))
    print(f"[serve] plan: schemes {{{schemes}}}, backends {s['by_backend']}, "
          f"{s['n_flat_padded']} flat-padded leaves")
    enc = plan.encode_tree(params)
    if args.abft or args.act_clamp:
        clamps = None
        if args.act_clamp:
            from repro.core import quant
            cal = jax.random.randint(jax.random.PRNGKey(args.seed + 7),
                                     (2, 16), 0, cfg.vocab, jnp.int32)
            scales = protected.calibrate_act_scales(cfg, enc, cal, plan=plan,
                                                    backend=args.backend)
            clamps = {p: s * quant.QMAX for p, s in scales.items()}
        # use-time knobs only — the encoded images above stay valid
        plan = plan.with_abft(args.abft, clamps=clamps)
        s = plan.summary()
        print(f"[serve] ABFT guard: {s['n_abft']} checksum-verified leaves, "
              f"{s['n_clamped']} activation-clamped")
    kit = None
    if args.repair:
        from repro.protection import repair as repair_mod
        kit = repair_mod.build_repair_kit(enc, seed=args.seed)
        print(f"[serve] pinned MILR repair kit over {len(kit)} leaves")
    if args.fault_rate:
        fault_smoke_check(enc, policy, args.fault_rate, args.seed,
                          trials=args.trials,
                          campaign_key=args.campaign_key,
                          out_path=args.campaign_out)
        enc = inject_tree(enc, args.fault_rate, args.seed)
        print("[serve] injected faults into the resident weight images")

    if args.burst:
        run_burst_mode(cfg, enc, plan, args, repair_kit=kit)
        return

    kvp = kvcache.get_kv_policy(args.kv_policy)
    serve_step = jax.jit(protected.make_serve_step(cfg, plan=plan,
                                                   with_flags=True,
                                                   kv_policy=kvp))
    max_len = max(64, args.tokens * 2)
    cache = kvcache.init_cache(cfg, args.batch, max_len, kv_policy=kvp)
    if kvp is not None:
        kb = kvcache.kv_bytes(cache)
        dense = kvcache.dense_kv_bytes(cfg, args.batch, max_len)
        print(f"[serve] paged KV cache ({kvp.scheme}, page_size="
              f"{kvp.page_size}): stored {kb['stored']}B + checks "
              f"{kb['checks']}B + scales {kb['scales']}B (dense bf16 cache: "
              f"{dense}B)")
    tokens = jnp.zeros((args.batch, 1), jnp.int32)
    scrubber_obj = None
    scrub_tot = {"corrected": 0, "repaired": 0, "quarantined": 0}
    if args.scrub_every:
        from repro.serving.scrubber import Scrubber
        scrubber_obj = Scrubber(leaves_per_step=2)
    t0 = time.time()
    out, step_flags = [], []
    for t in range(args.tokens):
        if scrubber_obj is not None and t % args.scrub_every == 0:
            enc, wst = scrubber_obj.scrub_weights(enc)
            scrub_tot["corrected"] += wst["corrected"]
            if wst["due_paths"] and kit is not None:
                from repro.protection import repair as repair_mod
                enc, reps = repair_mod.repair_tree(enc, kit,
                                                   paths=wst["due_paths"])
                for r in reps:
                    key = ("repaired" if r["status"] == "repaired"
                           else "quarantined")
                    scrub_tot[key] += 1
        if (kvp is not None and args.fault_rate and t == args.tokens // 2
                and t > 0):
            # the serving-state fault story: hit the LIVE pools mid-run, so
            # every later step decodes (and corrects) a faulted history
            tree = kvcache.as_protected_tree(cache, kvp)
            dirty = protection.inject_tree_device(
                tree, args.fault_rate, jax.random.PRNGKey(args.seed + 3))
            cache = kvcache.from_protected_tree(cache, dirty)
            print(f"[serve] injected faults into the live KV pools at "
                  f"step {t}")
        pos = jnp.full((args.batch,), t, jnp.int32)
        logits, cache, flags = serve_step(enc, cache, tokens, pos)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(int(tokens[0, 0]))
        step_flags.append(flags)  # device arrays; summed after the timer
    dt = time.time() - t0
    corrected = due = kv_corrected = kv_due = 0
    abft_mm = clamp_hits = 0
    for flags in step_flags:
        for k, v in flags.items():
            pair = jnp.sum(jnp.asarray(v).reshape(-1, 2), axis=0)
            if k.endswith("_abft"):  # (mismatches, clamp hits), not ECC
                abft_mm += int(pair[0])
                clamp_hits += int(pair[1])
            elif k == "layers_kv":
                kv_corrected += int(pair[0])
                kv_due += int(pair[1])
            else:
                corrected += int(pair[0])
                due += int(pair[1])
    print(f"[serve] {args.tokens} steps x batch {args.batch} in {dt:.2f}s "
          f"({args.tokens * args.batch / dt:.1f} tok/s)")
    print(f"[serve] decode-at-use fault accounting over the run: "
          f"{corrected} corrected, {due} DUE (detected-uncorrectable)")
    if kvp is not None:
        print(f"[serve] KV decode-at-use accounting: {kv_corrected} "
              f"corrected, {kv_due} DUE")
    if args.abft or args.act_clamp:
        print(f"[serve] ABFT compute-fault accounting: {abft_mm} checksum "
              f"mismatches, {clamp_hits} activation clamp hits")
    if scrubber_obj is not None:
        from repro.serving.scrubber import scrub_tree
        enc, fin = scrub_tree(enc)
        scrub_tot["corrected"] += fin["corrected"]
        residual = fin["due_paths"]
        if residual and kit is not None:
            from repro.protection import repair as repair_mod
            enc, reps = repair_mod.repair_tree(enc, kit, paths=residual)
            for r in reps:
                key = ("repaired" if r["status"] == "repaired"
                       else "quarantined")
                scrub_tot[key] += 1
            enc, fin = scrub_tree(enc)
            residual = fin["due_paths"]
        print(f"[serve] self-healing: wrote back "
              f"{scrub_tot['corrected']} corrected bits during the run, "
              f"{scrub_tot['repaired']} leaves repaired, "
              f"{scrub_tot['quarantined']} quarantined; residual DUE "
              f"leaves after the final pass: {len(residual)}")
    print(f"[serve] sample continuation: {out}")


if __name__ == "__main__":
    main()
