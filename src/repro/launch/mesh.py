"""Production mesh builders (functions, never module-level constants — the
dry-run must set XLA_FLAGS before any jax device initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, shape=None):
    """Default 16x16 (one pod) or 2x16x16; ``shape`` overrides the dims —
    a 2-tuple maps to ('data', 'model'), a 3-tuple to ('pod', 'data',
    'model') — so the dry-run grid can run micro-meshes on host devices."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    shape = tuple(int(s) for s in shape)
    if len(shape) not in (2, 3):
        raise ValueError(f"mesh shape must have 2 or 3 dims, got {shape}")
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the same axis names (smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def axis_names(multi_pod: bool):
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)
