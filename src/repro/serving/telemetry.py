"""JSONL telemetry for the request-level serving front-end.

The front-end (:mod:`repro.serving.frontend`) emits one flat JSON event
per lifecycle transition plus one per serve step; this module owns the
event stream (:class:`TelemetryCollector`), the determinism contract
(:func:`deterministic_view`), and the roll-up into SLO-facing numbers
(:func:`summarize`).

Determinism contract
--------------------
Every event field is derived from the logical step counter — the
deterministic clock — EXCEPT wall-clock measurements, which are suffixed
``_s`` (seconds) or ``_ms`` (milliseconds). ``deterministic_view`` strips
exactly those fields; two runs of the same seeded burst must produce
bit-identical deterministic views (asserted in tests and CI), while the
wall fields feed the latency percentiles.

Event schema (one table per type in docs/serving.md):

========== =================================================================
event      fields
========== =================================================================
init       slots, n_pages, pool_free, page_size, max_len, scheme, fused,
           attention_impl, per_slot_flags, prefix_sharing, scrub_every,
           repair
enqueue    rid, step, prompt_len, max_new, [t_s]
reject     rid, step, reason
admit      rid, step, slot, n_pages, queue_depth, pool_free; with prefix
           sharing also n_pages_solo, pages_shared, tokens_reused,
           cow_copied
cow        rid, step, slot, src, dst  (a shared page got a private clone)
first_token rid, step, slot, ttft_steps, [ttft_s]
finish     rid, step, slot, n_generated, kv_corrected, kv_due, pool_free,
           [ttft_s, tpot_ms]; when the plan guards matmuls (ABFT /
           clamps) and the request saw hits, also abft_mismatches,
           clamp_hits
step       step, active, queue_depth, pool_free, pool_cached,
           kv_corrected, kv_due, w_corrected, w_due, [step_ms]; with an
           ABFT/clamp-guarded plan also abft_mismatches, clamp_hits
           (integer counts from the compute-fault channel — no wall
           suffix, so they sit INSIDE the deterministic view and seeded
           replays must reproduce them bit for bit)
scrub      step, w_scanned, w_corrected, w_due, kv_scanned, kv_corrected,
           kv_due  (one budgeted healing pass; w_due counts leaves left
           un-written-back for repair)
scrub_final step, w_scanned, w_corrected, w_repaired, w_due, kv_scanned,
           kv_corrected, kv_due  (the full at-rest pass after drain;
           w_due / kv_due here are RESIDUAL uncorrectable state)
migrate    step, phase="start", pending | step, phase="promote", path,
           from, to, corrected, due, pending  (rolling plan migration)
repair     step, path, status ("repaired"|"quarantined"|"unrecoverable"),
           scheme, rows, due_blocks, residual
========== =================================================================

All healing events are pure functions of the logical step and the seeded
fault stream — no wall fields — so they sit inside the deterministic
view. ``pool_cached`` counts prefix-cache-held pages; the leak check is
``initial_free - final_free - final_cached == 0`` (cached pages are
referenced on purpose, not leaked)."""

from __future__ import annotations

import csv
import json
import math
from typing import IO, Optional

__all__ = [
    "TelemetryCollector", "deterministic_view", "percentile",
    "summarize", "write_summary", "load_summary", "write_requests_csv",
    "SUMMARY_SCHEMA", "SUPPORTED_SCHEMAS",
]

# v2 adds the ``healing`` roll-up (scrub / migrate / repair totals and the
# residual at-rest DUE state); v1 summaries still load via load_summary.
# The ``abft`` roll-up (compute-fault mismatches + clamp hits) extends v2
# ADDITIVELY — abft-less event streams roll up to all-zero counts, so v2
# consumers keep working and no v3 fork is needed.
SUMMARY_SCHEMA = "burst_sim/v2"
SUPPORTED_SCHEMAS = ("burst_sim/v1", "burst_sim/v2")

_WALL_SUFFIXES = ("_s", "_ms")


class TelemetryCollector:
    """Accumulates events in order; optionally streams them to a JSONL
    file as they arrive. Events are plain dicts with an ``event`` type
    key — see the module docstring for the vocabulary."""

    def __init__(self, path: Optional[str] = None):
        self.events: list = []
        self._fh: Optional[IO] = open(path, "w") if path else None

    def emit(self, event: str, **fields) -> dict:
        rec = {"event": event, **fields}
        self.events.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec) + "\n")
        return rec

    def close(self):
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def deterministic_view(events) -> list:
    """Strip wall-clock fields (``*_s`` / ``*_ms``) — what's left must be
    bit-identical across two runs of the same seeded burst."""
    return [{k: v for k, v in e.items()
             if not k.endswith(_WALL_SUFFIXES)} for e in events]


def percentile(xs, q: float):
    """Nearest-rank percentile (deterministic, no interpolation):
    the smallest x such that at least ``q``% of samples are <= x."""
    if not xs:
        return None
    xs = sorted(xs)
    rank = max(1, math.ceil(q / 100.0 * len(xs)))
    return xs[rank - 1]


def _pcts(xs) -> dict:
    return {"p50": percentile(xs, 50), "p95": percentile(xs, 95),
            "p99": percentile(xs, 99)}


def summarize(events) -> dict:
    """Roll an event stream up into the burst summary: throughput,
    p50/p95/p99 TTFT and per-token latency, queue depth, per-request DUE,
    and the page-pool accounting (leaked == initial free - final free)."""
    by = {}
    for e in events:
        by.setdefault(e["event"], []).append(e)
    steps = by.get("step", [])
    finishes = by.get("finish", [])
    n_gen = sum(f["n_generated"] for f in finishes)
    wall = sum(s.get("step_ms", 0.0) for s in steps) / 1e3
    due_per_req = [f["kv_due"] for f in finishes]
    init = by.get("init", [])
    pool0 = init[0]["pool_free"] if init else (
        steps[0]["pool_free"] if steps else None)
    pool1 = steps[-1]["pool_free"] if steps else None
    cached = steps[-1].get("pool_cached", 0) if steps else 0
    admits = by.get("admit", [])
    peak_in_use = max(((pool0 - s["pool_free"]) for s in steps),
                      default=0) if pool0 is not None else None
    return {
        "schema": SUMMARY_SCHEMA,
        "requests": {
            "submitted": len(by.get("enqueue", [])),
            "finished": len(finishes),
            "rejected": len(by.get("reject", [])),
        },
        "steps": len(steps),
        "gen_tokens": n_gen,
        "throughput": {
            "tokens_per_step": (n_gen / len(steps)) if steps else 0.0,
            "tokens_per_s": (n_gen / wall) if wall > 0 else None,
        },
        "ttft_steps": _pcts([f["ttft_steps"]
                             for f in by.get("first_token", [])]),
        "ttft_s": _pcts([f["ttft_s"] for f in by.get("first_token", [])
                         if "ttft_s" in f]),
        "per_token_ms": _pcts([f["tpot_ms"] for f in finishes
                               if "tpot_ms" in f]),
        "queue_depth": {
            "max": max((s["queue_depth"] for s in steps), default=0),
            "mean": (sum(s["queue_depth"] for s in steps) / len(steps))
                    if steps else 0.0,
        },
        "due": {
            "total": sum(due_per_req),
            "corrected_total": sum(f["kv_corrected"] for f in finishes),
            "max_per_request": max(due_per_req, default=0),
            "requests_with_due": sum(1 for d in due_per_req if d > 0),
        },
        "pool": {
            "initial_free": pool0,
            "final_free": pool1,
            "cached_pages": cached,
            "peak_pages_in_use": peak_in_use,
            # cached pages are referenced on purpose (the prefix index
            # pins them) — everything else must have come back
            "leaked_pages": (pool0 - pool1 - cached)
                            if pool0 is not None else None,
        },
        "sharing": {
            "pages_shared": sum(a.get("pages_shared", 0) for a in admits),
            "tokens_reused": sum(a.get("tokens_reused", 0)
                                 for a in admits),
            "cow_copies": len(by.get("cow", [])),
            "pages_allocated_total": sum(a["n_pages"] for a in admits),
            "solo_pages_total": sum(a.get("n_pages_solo", a["n_pages"])
                                    for a in admits),
        },
        "healing": _healing_rollup(by),
        "abft": _abft_rollup(steps, finishes),
    }


def _abft_rollup(steps, finishes) -> dict:
    """Additive v2 extension: the compute-fault (ABFT) channel. Step
    events carry per-step mismatch/clamp totals; finish events carry the
    per-request attribution. Streams from abft-less runs roll up to all
    zeros — same summary shape either way, no schema fork."""
    mm_req = [f.get("abft_mismatches", 0) for f in finishes]
    return {
        "mismatches_total": sum(s.get("abft_mismatches", 0) for s in steps),
        "clamp_hits_total": sum(s.get("clamp_hits", 0) for s in steps),
        "max_per_request": max(mm_req, default=0),
        "requests_with_mismatch": sum(1 for m in mm_req if m > 0),
        "requests_with_clamp": sum(
            1 for f in finishes if f.get("clamp_hits", 0) > 0),
    }


def _healing_rollup(by: dict) -> dict:
    """The v2 self-healing roll-up: scrub totals, migration progress,
    repair outcomes, and the residual at-rest DUE state from the final
    full pass (None when the run never scrubbed at the end)."""
    scrubs = by.get("scrub", [])
    repairs = by.get("repair", [])
    promotes = [m for m in by.get("migrate", [])
                if m.get("phase") == "promote"]
    finals = by.get("scrub_final", [])
    statuses = {}
    for r in repairs:
        statuses[r["status"]] = statuses.get(r["status"], 0) + 1
    return {
        "scrub_passes": len(scrubs),
        "w_scanned": sum(s["w_scanned"] for s in scrubs),
        "w_corrected": sum(s["w_corrected"] for s in scrubs),
        "kv_scanned": sum(s["kv_scanned"] for s in scrubs),
        "kv_corrected": sum(s["kv_corrected"] for s in scrubs),
        "due_leaves_seen": sum(s["w_due"] for s in scrubs),
        "repairs": statuses,
        "migrated_leaves": len(promotes),
        "final_due": ({"w": finals[-1]["w_due"],
                       "kv": finals[-1]["kv_due"],
                       "w_corrected": finals[-1]["w_corrected"],
                       "kv_corrected": finals[-1]["kv_corrected"],
                       "w_repaired": finals[-1]["w_repaired"]}
                      if finals else None),
    }


def write_summary(summary: dict, path: str):
    with open(path, "w") as fh:
        json.dump(summary, fh, indent=2)
        fh.write("\n")


def load_summary(path: str) -> dict:
    """Load a burst summary, accepting every schema in
    ``SUPPORTED_SCHEMAS``. v1 summaries (pre-healing) are upgraded in
    memory — ``healing`` becomes None so v2 consumers can branch on it —
    and keep their original ``schema`` string so provenance is visible."""
    with open(path) as fh:
        s = json.load(fh)
    schema = s.get("schema")
    if schema not in SUPPORTED_SCHEMAS:
        raise ValueError(f"unsupported burst summary schema {schema!r} "
                         f"(supported: {SUPPORTED_SCHEMAS})")
    if schema == "burst_sim/v1":
        s.setdefault("healing", None)
    # pre-ABFT summaries (either schema) lack the additive abft roll-up
    s.setdefault("abft", None)
    return s


def write_requests_csv(events, path: str):
    """One CSV row per request joining its lifecycle events — the
    analytics-friendly flat view next to the summary JSON."""
    rows: dict = {}
    for e in events:
        rid = e.get("rid")
        if rid is None:
            continue
        row = rows.setdefault(rid, {"rid": rid})
        ev = e["event"]
        if ev == "enqueue":
            row.update(enqueue_step=e["step"], prompt_len=e["prompt_len"],
                       max_new=e["max_new"])
        elif ev == "reject":
            row.update(rejected=1, reject_reason=e["reason"])
        elif ev == "admit":
            row.update(admit_step=e["step"], slot=e["slot"],
                       n_pages=e["n_pages"],
                       pages_shared=e.get("pages_shared"),
                       tokens_reused=e.get("tokens_reused"),
                       cow_copied=e.get("cow_copied"))
        elif ev == "first_token":
            row.update(first_token_step=e["step"],
                       ttft_steps=e["ttft_steps"],
                       ttft_s=e.get("ttft_s"))
        elif ev == "finish":
            row.update(finish_step=e["step"], n_generated=e["n_generated"],
                       kv_corrected=e["kv_corrected"], kv_due=e["kv_due"],
                       abft_mismatches=e.get("abft_mismatches"),
                       clamp_hits=e.get("clamp_hits"),
                       tpot_ms=e.get("tpot_ms"))
    fields = ["rid", "enqueue_step", "prompt_len", "max_new", "rejected",
              "reject_reason", "admit_step", "slot", "n_pages",
              "pages_shared", "tokens_reused", "cow_copied",
              "first_token_step", "ttft_steps", "ttft_s", "finish_step",
              "n_generated", "kv_corrected", "kv_due", "abft_mismatches",
              "clamp_hits", "tpot_ms"]
    with open(path, "w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=fields, restval="")
        w.writeheader()
        for rid in sorted(rows):
            w.writerow(rows[rid])
