"""Background scrub + rolling plan migration — the self-healing loop.

The paper's in-place (64,57,1) code *corrects* a single flipped bit only at
decode time; nothing ever writes the corrected bytes back.  Under serve
traffic that means correctable errors sit in memory until a second hit in
the same 8-byte block turns them into an uncorrectable DUE — exactly in
the weights and KV pages that decode least often.  This module closes the
loop with two host-driven maintenance actors that ride the serve loop:

``Scrubber``
    Walks the encoded weight tree and the live KV page pool on a
    traffic-aware budget (``leaves_per_step`` / ``pages_per_step`` per
    serve step), decode -> re-encode -> write back, so corrected bits
    actually land.  Two safety rules:

    * a leaf (or a layer x page slab) that decodes with ``due > 0`` is
      NEVER written back — re-encoding corrupted data would recompute
      checks consistent with the corruption and silently erase detection.
      It is reported instead (``due_paths`` / per-pool due counts) so the
      caller can hand it to :mod:`repro.protection.repair`.
    * free and parking pages have KNOWN content (all-zero after the
      free-time zeroing), so :meth:`Scrubber.scrub_free` restores them by
      re-zeroing — clearing even uncorrectable patterns.

    Scrub is value-exact: the decoded int8 image of a clean codeword
    re-encodes to the identical bytes, so scrubbing an uncorrupted leaf is
    a bit-level no-op (asserted in tests).

``Migrator``
    Drains a :meth:`ProtectionPlan.diff` shard-by-shard *while serving*:
    each :meth:`Migrator.step` transcodes the next ``leaves_per_step``
    leaves to their target scheme (``ProtectionPlan.migrate_step``) and
    swaps in the promoted plan.  The serve step keeps working across the
    swap because decode dispatches on each ``ProtectedTensor.scheme_id``;
    the only cost is one planned retrace per promoted tree structure.

Both actors are deliberately host-side and synchronous with the serve
loop (the repo's determinism contract): "background" means *budgeted per
step*, not a thread.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.protection.backends import get_backend
from repro.protection.policy import path_str
from repro.protection.schemes import get_scheme
from repro.protection.tensor import ProtectedTensor, is_protected_tensor

from . import kvcache

__all__ = ["Scrubber", "Migrator", "scrub_tree"]


# ---------------------------------------------------------------------------
# jitted per-(scheme, backend) scrub kernels
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _leaf_scrub_fn(scheme_id: str, backend: str):
    """enc[, checks] -> (enc', checks', corrected, due); write-back is
    suppressed (old bytes pass through) whenever the leaf has a DUE."""
    sch = get_scheme(scheme_id)
    be = get_backend(backend)

    @jax.jit
    def f(enc, checks):
        q, cor, due = sch.decode_with_flags(enc, checks, be)
        new_enc, new_checks = sch.encode(q, be)
        keep = due > 0                       # scalar: whole-leaf skip
        out_enc = jnp.where(keep, enc, new_enc)
        out_checks = (None if new_checks is None
                      else jnp.where(keep, checks, new_checks))
        return out_enc, out_checks, cor, due

    return f


@functools.lru_cache(maxsize=None)
def _kv_scrub_fn(scheme_id: str, backend: str, has_checks: bool):
    """(k_pages, v_pages, k_checks, v_checks, ids) -> scrubbed pools +
    (corrected, due_slabs, skipped) totals.  Write-back is masked per
    (layer, page) slab: one DUE token poisons only its own slab."""
    sch = get_scheme(scheme_id)

    @jax.jit
    def f(k_pages, v_pages, k_checks, v_checks, ids):
        stats = []
        outs = []
        for pool, checks in ((k_pages, k_checks), (v_pages, v_checks)):
            enc = pool[:, ids]                       # (nl, n, ps, kv, hd)
            ch = checks[:, ids] if has_checks else None
            q, cor, due = kvcache._decode_kv(enc, ch, scheme_id, backend)
            new_enc, new_ch = sch.encode(q, backend)
            bad = due.sum(axis=-1) > 0               # (nl, n) slab DUE
            keep = bad[:, :, None, None, None]
            pool = pool.at[:, ids].set(jnp.where(keep, enc, new_enc))
            if has_checks:
                checks = checks.at[:, ids].set(
                    jnp.where(keep, ch, new_ch))
            outs.append((pool, checks))
            stats.append((cor.sum(), due.sum(), bad.sum()))
        (kp, kc), (vp, vc) = outs
        (kcor, kdue, kbad), (vcor, vdue, vbad) = stats
        return kp, vp, kc, vc, kcor + vcor, kdue + vdue, kbad + vbad

    return f


def _protected_indices(flat):
    """Indices of scrubbable leaves in a flattened tree: protected tensors
    whose scheme actually stores a codeword ("faulty" stores raw bytes —
    nothing to correct, nothing to write back)."""
    return [i for i, (_, leaf) in enumerate(flat)
            if is_protected_tensor(leaf) and leaf.scheme_id != "faulty"]


def scrub_tree(enc_tree, *, backend: str = "xla"):
    """One full pass over every protected leaf (no budget, no cursor).
    Returns ``(new_tree, stats)`` — the "final scrub" used to assert the
    at-rest state is clean after a run drains."""
    s = Scrubber(leaves_per_step=0, backend=backend)
    return s.scrub_weights(enc_tree, n=-1)


# ---------------------------------------------------------------------------
# the scrubber
# ---------------------------------------------------------------------------


class Scrubber:
    """Budgeted decode -> re-encode -> write-back over weights + KV pages.

    Holds two wrap-around cursors (weight leaf index, KV worklist
    position) so successive calls cover the whole tree / pool round-robin
    regardless of per-step budget.  Stateless w.r.t. the data it scrubs —
    trees and caches are passed in and handed back (jax functional
    update), so the caller decides what the scrubbed state replaces.
    """

    def __init__(self, *, leaves_per_step: int = 1, pages_per_step: int = 4,
                 backend: str = "xla"):
        if leaves_per_step < 0 or pages_per_step < 0:
            raise ValueError("scrub budgets must be >= 0")
        self.leaves_per_step = leaves_per_step
        self.pages_per_step = pages_per_step
        self.backend = backend
        self._wcur = 0          # weight-leaf cursor
        self._pcur = 0          # KV worklist cursor

    # -- weights ------------------------------------------------------------

    def scrub_weights(self, enc_tree, *, n: int | None = None):
        """Scrub the next ``n`` protected leaves (default: the per-step
        budget; ``n=-1`` scrubs every leaf — a full pass).  Returns
        ``(new_tree, stats)`` with stats keys ``scanned / corrected /
        due / wrote / due_paths``; ``due_paths`` lists leaves left
        untouched for :mod:`repro.protection.repair`."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            enc_tree, is_leaf=is_protected_tensor)
        idxs = _protected_indices(flat)
        stats = {"scanned": 0, "corrected": 0, "due": 0, "wrote": 0,
                 "due_paths": []}
        if not idxs:
            return enc_tree, stats
        budget = self.leaves_per_step if n is None else n
        budget = len(idxs) if budget < 0 else min(budget, len(idxs))
        if budget == 0:
            return enc_tree, stats
        leaves = [leaf for _, leaf in flat]
        start = self._wcur % len(idxs)
        for j in range(budget):
            i = idxs[(start + j) % len(idxs)]
            pt = leaves[i]
            fn = _leaf_scrub_fn(pt.scheme_id, self.backend)
            enc, checks, cor, due = fn(pt.enc, pt.checks)
            cor, due = int(cor), int(due)
            stats["scanned"] += 1
            stats["corrected"] += cor
            stats["due"] += due
            if due:
                stats["due_paths"].append(path_str(flat[i][0]))
            else:
                stats["wrote"] += 1
                leaves[i] = ProtectedTensor(
                    enc=enc, checks=checks, scale=pt.scale,
                    scheme_id=pt.scheme_id,
                    orig_shape=tuple(pt.orig_shape))
        self._wcur = (start + budget) % len(idxs)
        return jax.tree_util.tree_unflatten(treedef, leaves), stats

    # -- KV pages -----------------------------------------------------------

    def scrub_kv(self, cache: dict, policy, *, occupied, busy=(),
                 n: int | None = None):
        """Scrub the next ``n`` live pages (default: the per-step budget;
        ``n=-1`` scrubs the whole worklist).  ``occupied`` is the live-page
        worklist (:meth:`PageAllocator.live_pages`); ``busy`` pages —
        in-flight slots' current write targets — are skipped this pass.
        Returns ``(new_cache, stats)`` with ``scanned / corrected / due /
        due_slabs`` (a slab is one layer x page write-back unit)."""
        stats = {"scanned": 0, "corrected": 0, "due": 0, "due_slabs": 0}
        sch = policy.scheme_obj
        if sch.scheme_id == "faulty":
            return cache, stats
        work = sorted(set(occupied) - set(busy))
        if not work:
            return cache, stats
        budget = self.pages_per_step if n is None else n
        budget = len(work) if budget < 0 else min(budget, len(work))
        if budget == 0:
            return cache, stats
        start = self._pcur % len(work)
        ids = [work[(start + j) % len(work)] for j in range(budget)]
        self._pcur = (start + budget) % len(work)
        fn = _kv_scrub_fn(sch.scheme_id, policy.backend, policy.has_checks)
        kp, vp, kc, vc, cor, due, bad = fn(
            cache["k_pages"], cache["v_pages"],
            cache.get("k_checks"), cache.get("v_checks"),
            jnp.asarray(ids, jnp.int32))
        cache = dict(cache)
        cache["k_pages"], cache["v_pages"] = kp, vp
        if policy.has_checks:
            cache["k_checks"], cache["v_checks"] = kc, vc
        stats.update(scanned=len(ids), corrected=int(cor), due=int(due),
                     due_slabs=int(bad))
        return cache, stats

    def scrub_free(self, cache: dict, alloc) -> dict:
        """Restore every free + parking page to its known content (zero).
        Unlike the decode path this clears even DUE patterns — the pool
        invariant 'free means zero' is re-established unconditionally."""
        ids = tuple(range(alloc.reserved)) + alloc.free_pages()
        return kvcache.zero_pages(cache, ids) if ids else cache


# ---------------------------------------------------------------------------
# rolling plan migration
# ---------------------------------------------------------------------------


class Migrator:
    """Drains ``plan.diff(target)`` a few shards per step, while serving.

    State machine: ``pending`` (scheme-change paths in plan order) ->
    :meth:`step` promotes the next ``leaves_per_step`` of them via
    ``ProtectionPlan.migrate_step`` -> ``done`` when the worklist is
    empty.  ``self.plan`` always reflects the promotions applied so far,
    so a restart resumes from the mixed plan, and ``records`` accumulates
    one ``{path, from, to, corrected, due}`` dict per promoted leaf.

    The serve step is NOT rebuilt: mixed-scheme dispatch reads each
    ``ProtectedTensor.scheme_id``, so promoting a leaf costs exactly the
    retrace its new tree structure triggers (bounded by ``len(diff)`` —
    asserted in tests via the jitted step's cache size).
    """

    def __init__(self, plan, target, *, leaves_per_step: int = 1):
        if leaves_per_step < 1:
            raise ValueError("leaves_per_step must be >= 1")
        self.diff = plan.diff(target)
        self.pending = list(self.diff.paths)
        self.plan = plan
        self.target = target
        self.leaves_per_step = leaves_per_step
        self.records: list = []

    @property
    def done(self) -> bool:
        return not self.pending

    @property
    def promoted(self) -> int:
        return len(self.records)

    def step(self, enc_tree):
        """Promote the next batch of shards.  Returns ``(new_tree,
        records)``; records is empty once the migration has drained."""
        if not self.pending:
            return enc_tree, []
        batch = self.pending[:self.leaves_per_step]
        self.pending = self.pending[self.leaves_per_step:]
        enc_tree, self.plan, recs = self.plan.migrate_step(
            enc_tree, self.target, batch)
        self.records.extend(recs)
        return enc_tree, recs
