"""Request-level serving front-end: slot-based continuous batching over
the paged protected KV cache.

Everything below ``make_serve_step`` was batch-shaped until now; this
module adds the request layer — a :class:`RequestQueue` with admission
control, a per-slot lifecycle (prefill -> decode -> finish/evict, pages
freed back to the pool), and a seeded burst-load driver — all driven by
ONE compiled serve step over a churning request mix.

Design points
-------------
* **One compiled step.** Prefill is fed token-by-token through the same
  jitted ``serve_step`` as decode: an active slot's next input token is
  ``prompt[consumed]`` while the prompt lasts, then its own last sampled
  token; ``pos = consumed``. The step that consumes the LAST prompt token
  yields the request's first generated token. No separate prefill
  executable, no recompiles as the mix churns.
* **Parking pages.** Pool pages ``0..slots-1`` are reserved, one per
  slot (:func:`~repro.serving.kvcache.init_paged_cache` with
  ``n_pages``). An idle slot's page-table row points wholly at its own
  parking page, so the keep-alive token it writes each step (pos 0) can
  never scribble on a live request's pages. The
  :class:`~repro.serving.kvcache.PageAllocator` never hands them out.
* **Determinism.** Sampling is greedy argmax; admission is FIFO;
  page allocation is lowest-id-first; fault injection keys fold in the
  logical step. A seeded burst replay is bit-deterministic — asserted via
  :func:`~repro.serving.telemetry.deterministic_view`.
* **Per-request fault attribution.** The front-end forces
  ``per_slot_flags`` on EVERY KV policy — the fused and chunked Pallas
  kernels reduce (corrected, DUE) per batch row in-grid — so
  ``flags["layers_kv"]`` is (n_layers, 2, B) and each finish event
  carries the counts *that request's* cached tokens saw. When the plan
  guards matmuls (``plan.with_abft`` / activation clamps) the same
  per-slot routing applies to the compute channel: a decode step's
  output rows ARE the batch slots, so ``flags["layers_abft"]`` comes
  back (n_layers, 2, B) and finish events carry ``abft_mismatches`` /
  ``clamp_hits`` per request.
* **Prefix sharing + copy-on-write.** With ``prefix_sharing=True`` the
  front-end keeps an index of published full-page prompt prefixes
  (key = the ENTIRE token prefix through that page, since cached K/V at
  any position depends on every token before it). Admission maps index
  hits into the new slot's table via allocator refcounts and skips their
  prefill steps; the index holds its own reference, so cached pages
  survive their publisher. A prompt ending exactly on a shared page
  boundary re-consumes its last token (that step yields the first
  sampled token) and therefore writes into the last shared page — that
  page gets a private copy-on-write clone instead of a reference. Pages
  re-enter the pool (and are zeroed) only when their LAST reference
  drops; under pool pressure admission evicts cached pages LRU-by-hit
  (least recently *hit* prefix first, publication order as tiebreak).
* **Self-healing.** With ``scrub_every > 0`` each matching step runs a
  budgeted scrub pass (``repro.serving.scrubber``) over the encoded
  weights and live KV pages BEFORE the serve compute, so corrected bits
  land before anything decodes them; weight leaves that scrub refuses to
  write back (DUE) go to MILR repair/quarantine when a ``repair_kit`` is
  attached. :meth:`start_migration` drains a plan diff shard-by-shard
  between steps. All of it emits ``scrub`` / ``migrate`` / ``repair``
  telemetry and stays inside the determinism contract.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.serving import kvcache, scrubber, telemetry
from repro.serving import protected as sp

__all__ = [
    "Request", "RequestQueue", "ServingFrontend",
    "make_waves", "run_burst",
]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request: generate up to ``max_new`` tokens after
    ``prompt``. ``arrival_step`` is the logical step the burst driver
    submits it at (0 = immediately)."""
    rid: int
    prompt: tuple
    max_new: int
    arrival_step: int = 0

    def __post_init__(self):
        if len(self.prompt) == 0:
            raise ValueError("empty prompt")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")

    @property
    def total_tokens(self) -> int:
        return len(self.prompt) + self.max_new


class RequestQueue:
    """FIFO admission queue. ``push`` validates that the request can EVER
    be served (fits the per-slot table and the allocatable pool) — those
    are rejected outright; transient exhaustion just queues."""

    def __init__(self, max_total_tokens: int, max_pages: int,
                 page_size: int):
        self.max_total_tokens = max_total_tokens
        self.max_pages = max_pages
        self.page_size = page_size
        self._q: deque = deque()

    def __len__(self) -> int:
        return len(self._q)

    def reject_reason(self, req: Request) -> Optional[str]:
        if req.total_tokens > self.max_total_tokens:
            return (f"prompt+max_new {req.total_tokens} exceeds max_len "
                    f"{self.max_total_tokens}")
        need = kvcache.pages_needed(req.total_tokens, self.page_size)
        if need > self.max_pages:
            return (f"needs {need} pages, pool only has "
                    f"{self.max_pages} allocatable")
        return None

    def push(self, req: Request) -> Optional[str]:
        """Queue ``req``; returns a rejection reason instead if it can
        never be admitted."""
        reason = self.reject_reason(req)
        if reason is None:
            self._q.append(req)
        return reason

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def pop(self) -> Request:
        return self._q.popleft()


class _Slot:
    """Mutable per-slot lifecycle state (host side)."""

    __slots__ = ("req", "consumed", "generated", "pages", "enqueue_step",
                 "admit_step", "first_step", "enqueue_s", "first_s",
                 "kv_corrected", "kv_due", "abft_mismatches", "clamp_hits")

    def __init__(self, req: Request, pages, step: int,
                 enqueue_step: int, enqueue_s: float):
        self.req = req
        self.consumed = 0
        self.generated: list = []
        self.pages = pages
        self.enqueue_step = enqueue_step
        self.admit_step = step
        self.first_step: Optional[int] = None
        self.enqueue_s = enqueue_s
        self.first_s: Optional[float] = None
        self.kv_corrected = 0
        self.kv_due = 0
        self.abft_mismatches = 0
        self.clamp_hits = 0


class ServingFrontend:
    """Continuous-batching loop: ``submit`` requests, call :meth:`step`
    (or :meth:`run`) until drained. Emits telemetry throughout; finished
    requests land in :attr:`results` as ``{rid: [token, ...]}``."""

    def __init__(self, cfg: ArchConfig, enc_params, *, plan=None,
                 slots: int = 4, max_len: int = 128,
                 n_pages: Optional[int] = None, kv_policy="in-place",
                 serve_step=None, collector=None, dtype=jnp.bfloat16,
                 act_quant: Optional[str] = None,
                 prefix_sharing: bool = False,
                 scrub_every: int = 0, scrub_weight_leaves: int = 1,
                 scrub_kv_pages: int = 4, repair_kit=None):
        kvp = kvcache.get_kv_policy(kv_policy)
        # per-request attribution on every path (see module docstring)
        kvp = dataclasses.replace(kvp, per_slot_flags=True)
        self.cfg, self.policy, self.slots_n = cfg, kvp, slots
        self.plan = plan
        self.prefix_sharing = bool(prefix_sharing)
        self._prefix_index: dict = {}   # full-prefix tokens -> page id
        self._published: dict = {}      # page id -> its index key
        self._prefix_meta: dict = {}    # index key -> [last_hit, seq]
        self._prefix_seq = 0
        npg = -(-max_len // kvp.page_size)
        self.max_len = npg * kvp.page_size
        if n_pages is None:
            n_pages = slots + slots * npg      # parking + full occupancy
        self.cache = kvcache.init_paged_cache(cfg, batch=slots,
                                              max_len=self.max_len,
                                              policy=kvp, n_pages=n_pages)
        self.allocator = kvcache.PageAllocator(n_pages, reserved=slots)
        self.queue = RequestQueue(self.max_len,
                                  self.allocator.free_count,
                                  kvp.page_size)
        if serve_step is None:
            serve_step = jax.jit(sp.make_serve_step(
                cfg, plan=plan, with_flags=True, kv_policy=kvp,
                dtype=dtype, act_quant=act_quant))
        self.serve_step = serve_step
        self.enc_params = enc_params
        self.telemetry = collector or telemetry.TelemetryCollector()
        self.step_no = 0
        self.results: dict = {}
        self._slots: list = [None] * slots
        self._pending_meta: dict = {}   # rid -> (enqueue_step, enqueue_s)
        if scrub_every < 0:
            raise ValueError("scrub_every must be >= 0")
        self.scrub_every = scrub_every
        self.repair_kit = repair_kit
        self.scrubber = scrubber.Scrubber(
            leaves_per_step=scrub_weight_leaves,
            pages_per_step=scrub_kv_pages)
        self._migrator: Optional[scrubber.Migrator] = None
        self._migrate_every = 1
        self.telemetry.emit("init", slots=slots, n_pages=n_pages,
                            pool_free=self.allocator.free_count,
                            page_size=kvp.page_size, max_len=self.max_len,
                            scheme=kvp.scheme, fused=kvp.fused,
                            attention_impl=kvp.attention_impl,
                            per_slot_flags=kvp.per_slot_flags,
                            prefix_sharing=self.prefix_sharing,
                            scrub_every=scrub_every,
                            repair=repair_kit is not None)

    # -- request intake ----------------------------------------------------

    def submit(self, req: Request):
        now = time.perf_counter()
        reason = self.queue.push(req)
        if reason is not None:
            self.telemetry.emit("reject", rid=req.rid, step=self.step_no,
                                reason=reason)
            return
        self._pending_meta[req.rid] = (self.step_no, now)
        self.telemetry.emit("enqueue", rid=req.rid, step=self.step_no,
                            prompt_len=len(req.prompt),
                            max_new=req.max_new, t_s=now)

    # -- prefix sharing ----------------------------------------------------

    def _lookup_shared(self, prompt) -> tuple:
        """Longest run of published full-page prefixes of ``prompt``.
        Matching is on the ENTIRE token prefix through each page — cached
        K/V at any position depends on every token before it, so a page
        is reusable only when everything upstream of it matches too."""
        ps = self.policy.page_size
        pids, j = [], 1
        while j * ps <= len(prompt):
            key = tuple(prompt[:j * ps])
            pid = self._prefix_index.get(key)
            if pid is None:
                break
            self._prefix_meta[key][0] = self.step_no   # LRU touch
            pids.append(pid)
            j += 1
        return tuple(pids)

    def _evict_prefix_cache(self, need: int, keep=()):
        """Drop cached prefix pages LRU-by-hit (least recently *hit*
        first — publication counts as the first hit, publication order
        breaks ties — never the ones the in-flight admission is about to
        map) until the allocator can serve ``need`` fresh pages. Evicting
        an entry only releases the page if no live slot still maps it."""
        keep = set(keep)
        order = sorted(self._prefix_index,
                       key=lambda k: tuple(self._prefix_meta[k]))
        for key in order:
            if self.allocator.can(need):
                return
            pid = self._prefix_index[key]
            if pid in keep:
                continue
            del self._prefix_index[key]
            del self._prefix_meta[key]
            del self._published[pid]
            released = self.allocator.free((pid,))
            if released:
                self.cache = kvcache.zero_pages(self.cache, released)

    def drop_prefix_cache(self) -> int:
        """Release every cached prefix page (the index's own references);
        pages still mapped by live slots survive until those finish.
        Returns the number of entries dropped."""
        n = len(self._prefix_index)
        self._evict_prefix_cache(self.allocator.n_pages + 1)
        return n

    def _maybe_publish(self, s: "_Slot"):
        """After ``s.consumed`` advanced: if it just crossed a page
        boundary inside the prompt, that page now holds a complete,
        final prefix — publish it (the index takes its own reference)."""
        ps = self.policy.page_size
        if s.consumed % ps != 0 or s.consumed > len(s.req.prompt):
            return
        key = tuple(s.req.prompt[:s.consumed])
        if key in self._prefix_index:
            return
        pid = s.pages[s.consumed // ps - 1]
        self._prefix_index[key] = pid
        self._prefix_meta[key] = [self.step_no, self._prefix_seq]
        self._prefix_seq += 1
        self._published[pid] = key
        self.allocator.retain((pid,))

    # -- admission ---------------------------------------------------------

    def _admit(self):
        """FIFO head-of-line admission: admit while a slot is free AND the
        pool can serve the head request's page budget up front. With
        prefix sharing the budget shrinks by the cached full-page prefix
        (mapped via refcounts), plus one CoW target when the prompt ends
        exactly on a shared page boundary."""
        while self.queue.peek() is not None:
            free_slot = next((i for i, s in enumerate(self._slots)
                              if s is None), None)
            if free_slot is None:
                return
            req = self.queue.peek()
            ps = self.policy.page_size
            npg = kvcache.pages_needed(req.total_tokens, ps)
            shared = (self._lookup_shared(req.prompt)
                      if self.prefix_sharing else ())
            plen = len(req.prompt)
            # a fully-shared prompt still re-consumes its last token
            # (that step yields the first sampled token) and therefore
            # WRITES into the last shared page -> private CoW clone
            cow = bool(shared) and len(shared) * ps == plen
            need = npg - len(shared) + (1 if cow else 0)
            if not self.allocator.can(need) and self.prefix_sharing:
                self._evict_prefix_cache(need, keep=shared)
            if not self.allocator.can(need):
                return                      # transient exhaustion: wait
            self.queue.pop()
            fresh = self.allocator.alloc(need)
            if cow:
                src, dst = shared[-1], fresh[0]
                self.allocator.retain(shared[:-1])
                self.cache = kvcache.copy_page(self.cache, src, dst)
                pages = shared[:-1] + (dst,) + fresh[1:]
            else:
                self.allocator.retain(shared)
                pages = shared + fresh
            self.cache = kvcache.set_slot_pages(self.cache, free_slot,
                                                pages)
            enq_step, enq_s = self._pending_meta.pop(req.rid)
            slot = _Slot(req, pages, self.step_no, enq_step, enq_s)
            # shared pages' K/V is already in the pool: skip straight
            # past those prompt tokens
            slot.consumed = min(len(shared) * ps, plen - 1)
            self._slots[free_slot] = slot
            ev = dict(rid=req.rid, step=self.step_no, slot=free_slot,
                      n_pages=need, queue_depth=len(self.queue),
                      pool_free=self.allocator.free_count)
            if self.prefix_sharing:
                ev.update(n_pages_solo=npg, pages_shared=len(shared),
                          tokens_reused=slot.consumed,
                          cow_copied=int(cow))
            self.telemetry.emit("admit", **ev)
            if cow:
                self.telemetry.emit("cow", rid=req.rid, step=self.step_no,
                                    slot=free_slot, src=shared[-1],
                                    dst=fresh[0])

    # -- self-healing: scrub, repair, migrate ------------------------------

    def start_migration(self, target_plan, *, leaves_per_step: int = 1,
                        every: int = 1) -> "scrubber.Migrator":
        """Begin a rolling migration to ``target_plan``: every ``every``
        steps the next ``leaves_per_step`` scheme-changed leaves are
        transcoded in place and the front-end's plan is swapped for the
        promoted one. Serving continues throughout — decode dispatches on
        each leaf's own scheme id."""
        if self.plan is None:
            raise ValueError("front-end was built without a plan — "
                             "nothing to diff a migration against")
        if self._migrator is not None and not self._migrator.done:
            raise RuntimeError("a migration is already in flight")
        self._migrator = scrubber.Migrator(self.plan, target_plan,
                                           leaves_per_step=leaves_per_step)
        self._migrate_every = max(1, every)
        self.telemetry.emit("migrate", step=self.step_no, phase="start",
                            pending=len(self._migrator.pending))
        return self._migrator

    @property
    def migration_done(self) -> bool:
        return self._migrator is None or self._migrator.done

    def _busy_pages(self) -> set:
        """Each active slot's current write-target page — the one page per
        slot this step's serve compute will scribble into."""
        ps = self.policy.page_size
        busy = set()
        for s in self._slots:
            if s is not None:
                busy.add(s.pages[min(s.consumed // ps,
                                     len(s.pages) - 1)])
        return busy

    def _repair(self, due_paths):
        """Hand scrub-detected DUE leaves to MILR repair/quarantine."""
        from repro.protection import repair as repair_mod
        self.enc_params, reports = repair_mod.repair_tree(
            self.enc_params, self.repair_kit, paths=due_paths)
        for r in reports:
            self.telemetry.emit("repair", step=self.step_no, **r)
        return reports

    def _heal(self):
        """The per-step maintenance slice, run AFTER admission and BEFORE
        the serve compute so written-back corrections land before anything
        decodes them."""
        mig = self._migrator
        if (mig is not None and not mig.done
                and self.step_no % self._migrate_every == 0):
            self.enc_params, recs = mig.step(self.enc_params)
            self.plan = mig.plan
            for r in recs:
                self.telemetry.emit("migrate", step=self.step_no,
                                    phase="promote",
                                    pending=len(mig.pending), **r)
        if self.scrub_every and self.step_no % self.scrub_every == 0:
            self.enc_params, wst = self.scrubber.scrub_weights(
                self.enc_params)
            if wst["due_paths"] and self.repair_kit is not None:
                self._repair(wst["due_paths"])
            self.cache, kst = self.scrubber.scrub_kv(
                self.cache, self.policy,
                occupied=self.allocator.live_pages(),
                busy=self._busy_pages())
            self.telemetry.emit(
                "scrub", step=self.step_no,
                w_scanned=wst["scanned"], w_corrected=wst["corrected"],
                w_due=wst["due"], kv_scanned=kst["scanned"],
                kv_corrected=kst["corrected"], kv_due=kst["due"])

    def final_scrub(self) -> dict:
        """One full at-rest pass, meant for after the loop drains: every
        protected weight leaf (with repair/quarantine for DUE leaves, then
        a recount), every live KV page, and an unconditional re-zero of
        free + parking pages. Emits ``scrub_final`` and returns its
        fields — ``w_due`` / ``kv_due`` are the *residual* uncorrectable
        state, the quantity CI pins to zero."""
        tree, wst = self.scrubber.scrub_weights(self.enc_params, n=-1)
        self.enc_params = tree
        repaired = 0
        if wst["due_paths"] and self.repair_kit is not None:
            repaired = len(self._repair(wst["due_paths"]))
            tree, wst2 = self.scrubber.scrub_weights(self.enc_params, n=-1)
            self.enc_params = tree
        else:
            wst2 = wst
        self.cache, kst = self.scrubber.scrub_kv(
            self.cache, self.policy,
            occupied=self.allocator.live_pages(), n=-1)
        self.cache = self.scrubber.scrub_free(self.cache, self.allocator)
        out = {"w_scanned": wst["scanned"], "w_corrected": wst["corrected"],
               "w_repaired": repaired, "w_due": wst2["due"],
               "kv_scanned": kst["scanned"],
               "kv_corrected": kst["corrected"], "kv_due": kst["due"]}
        self.telemetry.emit("scrub_final", step=self.step_no, **out)
        return out

    # -- the serving loop --------------------------------------------------

    @property
    def active(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    def _finish(self, idx: int):
        s = self._slots[idx]
        now = time.perf_counter()
        n_gen = len(s.generated)
        self.results[s.req.rid] = list(s.generated)
        # park the row, then drop this slot's references; only pages whose
        # LAST reference died re-enter the pool — zero exactly those
        # before anything can re-allocate them (pages still mapped by
        # other slots or the prefix cache must keep their bytes)
        self.cache = kvcache.set_slot_pages(self.cache, idx, ())
        released = self.allocator.free(s.pages)
        if released:
            self.cache = kvcache.zero_pages(self.cache, released)
        self._slots[idx] = None
        ev = {"rid": s.req.rid, "step": self.step_no, "slot": idx,
              "n_generated": n_gen, "kv_corrected": int(s.kv_corrected),
              "kv_due": int(s.kv_due),
              "pool_free": self.allocator.free_count}
        if s.abft_mismatches or s.clamp_hits:
            ev["abft_mismatches"] = int(s.abft_mismatches)
            ev["clamp_hits"] = int(s.clamp_hits)
        if s.first_s is not None:
            ev["ttft_s"] = s.first_s - s.enqueue_s
            ev["tpot_ms"] = ((now - s.first_s) / max(1, n_gen - 1)) * 1e3
        self.telemetry.emit("finish", **ev)

    def step(self):
        """One loop iteration: admit, run the compiled step over all
        slots (idle slots feed a keep-alive token into their parking
        page), sample greedily, advance lifecycles, emit telemetry."""
        self._admit()
        self._heal()
        t0 = time.perf_counter()
        tokens = np.zeros((self.slots_n, 1), np.int32)
        pos = np.zeros((self.slots_n,), np.int32)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            if s.consumed < len(s.req.prompt):
                tokens[i, 0] = s.req.prompt[s.consumed]
            else:
                tokens[i, 0] = s.generated[-1]
            pos[i] = s.consumed
        logits, self.cache, flags = self.serve_step(
            self.enc_params, self.cache, jnp.asarray(tokens),
            jnp.asarray(pos))
        sampled = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        kv = np.asarray(flags["layers_kv"]).sum(axis=0)   # (2,) | (2, B)
        w = np.asarray(flags["top"]) + np.asarray(flags["layers"]).sum(0)
        # ABFT channel (only present when the plan guards some leaves):
        # layer rows (L, 2) or per-slot (L, 2, B), plus the top row — the
        # decode step's output rows ARE the batch slots, so per-slot rows
        # attribute compute faults to requests exactly
        ab = flags.get("layers_abft")
        if ab is not None:
            ab = np.asarray(ab).sum(axis=0) + np.asarray(flags["top_abft"])
        t1 = time.perf_counter()

        per_slot = kv.ndim == 2
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            if per_slot:
                s.kv_corrected += int(kv[0, i])
                s.kv_due += int(kv[1, i])
            else:                # fused: batch totals as upper bound
                s.kv_corrected += int(kv[0])
                s.kv_due += int(kv[1])
            if ab is not None:
                if ab.ndim == 2:
                    s.abft_mismatches += int(ab[0, i])
                    s.clamp_hits += int(ab[1, i])
                else:            # scalar channel: batch totals
                    s.abft_mismatches += int(ab[0])
                    s.clamp_hits += int(ab[1])
            s.consumed += 1
            if self.prefix_sharing:
                self._maybe_publish(s)
            if s.consumed >= len(s.req.prompt):
                s.generated.append(int(sampled[i]))
                if s.first_step is None:
                    s.first_step, s.first_s = self.step_no, t1
                    self.telemetry.emit(
                        "first_token", rid=s.req.rid, step=self.step_no,
                        slot=i, ttft_steps=self.step_no - s.enqueue_step,
                        ttft_s=t1 - s.enqueue_s)
        for i, s in enumerate(self._slots):
            if s is not None and len(s.generated) >= s.req.max_new:
                self._finish(i)
        # emitted after finishes so pool_free reflects this step's frees —
        # summarize() reads the last step's pool_free as the leak check
        ev = dict(
            step=self.step_no, active=self.active,
            queue_depth=len(self.queue),
            pool_free=self.allocator.free_count,
            pool_cached=len(self._prefix_index),
            kv_corrected=int(kv.sum(axis=-1)[0] if per_slot else kv[0]),
            kv_due=int(kv.sum(axis=-1)[1] if per_slot else kv[1]),
            w_corrected=int(w[0]), w_due=int(w[1]))
        if ab is not None:
            ev["abft_mismatches"] = int(ab[0].sum())
            ev["clamp_hits"] = int(ab[1].sum())
        self.telemetry.emit("step", **ev, step_ms=(t1 - t0) * 1e3)
        self.step_no += 1

    def run(self, max_steps: int = 10_000):
        """Step until queue and slots drain (or ``max_steps``)."""
        for _ in range(max_steps):
            if not self.queue.peek() and self.active == 0:
                return
            self.step()
        if self.queue.peek() or self.active:
            raise RuntimeError(f"not drained after {max_steps} steps: "
                               f"{len(self.queue)} queued, "
                               f"{self.active} active")


# ---------------------------------------------------------------------------
# burst-load driver
# ---------------------------------------------------------------------------


def make_waves(*, seed: int, n_waves: int, wave_size: int, vocab: int,
               prompt_len=(4, 12), max_new=(4, 8),
               gap_steps: int = 8, shared_prefix_len: int = 0) -> list:
    """Deterministic burst workload: ``n_waves`` waves of ``wave_size``
    requests each, wave *w* arriving at step ``w * gap_steps``. Prompt
    tokens and per-request lengths draw from a ``numpy`` generator seeded
    with ``seed`` only — same seed, same workload, bit for bit.

    ``shared_prefix_len > 0`` draws ONE common prefix of that many tokens
    and prepends it to every prompt (``prompt_len`` then ranges over the
    per-request suffix, which may be 0) — the shared-prefix serving
    scenario the front-end's prefix cache exists for."""
    rng = np.random.default_rng(seed)
    shared = tuple(int(t) for t in
                   rng.integers(1, vocab, size=shared_prefix_len))
    lo_p, hi_p = prompt_len
    lo_n, hi_n = max_new
    reqs, rid = [], 0
    for w in range(n_waves):
        for _ in range(wave_size):
            plen = int(rng.integers(lo_p, hi_p + 1))
            reqs.append(Request(
                rid=rid,
                prompt=shared + tuple(int(t) for t in
                                      rng.integers(1, vocab, size=plen)),
                max_new=int(rng.integers(lo_n, hi_n + 1)),
                arrival_step=w * gap_steps))
            rid += 1
    return reqs


def run_burst(cfg: ArchConfig, enc_params, *, plan=None, waves: Sequence,
              slots: int = 4, max_len: int = 128,
              n_pages: Optional[int] = None, kv_policy="in-place",
              fault_rate: float = 0.0, fault_seed: int = 0,
              inject_every: int = 4, telemetry_path: Optional[str] = None,
              serve_step=None, max_steps: int = 10_000,
              dtype=jnp.bfloat16, prefix_sharing: bool = False,
              scrub_every: int = 0, scrub_weight_leaves: int = 1,
              scrub_kv_pages: int = 4, repair: bool = False,
              repair_kit=None, weight_fault_rate: float = 0.0):
    """Replay a seeded wave workload through the front-end, optionally
    injecting faults into the live KV pools every ``inject_every`` steps
    at per-bit ``fault_rate`` (keys fold in the logical step, so a replay
    injects the identical bits). ``weight_fault_rate`` additionally
    injects into the encoded weight tree on the same cadence (its own key
    stream — KV and weight injections never alias). Returns ``(events,
    summary, results)``.

    ``scrub_every > 0`` turns on the budgeted self-healing slice
    (``scrub_weight_leaves`` / ``scrub_kv_pages`` per pass) and ends the
    run with :meth:`ServingFrontend.final_scrub`, so the summary's
    ``healing`` roll-up reports the residual at-rest DUE state;
    ``repair=True`` pins a MILR repair kit from the (clean) entry tree
    first — or pass a prebuilt ``repair_kit`` when the entry tree already
    carries faults.

    Pass a prebuilt jitted ``serve_step`` to share the compiled executable
    across runs (the protected/unprotected twin comparison and
    bit-determinism replays rely on this to avoid recompiles)."""
    col = telemetry.TelemetryCollector(telemetry_path)
    kit = repair_kit
    if repair and kit is None:
        from repro.protection import repair as repair_mod
        kit = repair_mod.build_repair_kit(enc_params, seed=fault_seed)
    fe = ServingFrontend(cfg, enc_params, plan=plan, slots=slots,
                         max_len=max_len, n_pages=n_pages,
                         kv_policy=kv_policy, serve_step=serve_step,
                         collector=col, dtype=dtype,
                         prefix_sharing=prefix_sharing,
                         scrub_every=scrub_every,
                         scrub_weight_leaves=scrub_weight_leaves,
                         scrub_kv_pages=scrub_kv_pages, repair_kit=kit)
    pending = sorted(waves, key=lambda r: (r.arrival_step, r.rid))
    i = 0
    base_key = jax.random.PRNGKey(fault_seed)
    wkey = jax.random.PRNGKey(fault_seed + 1_000_003)
    for _ in range(max_steps):
        while i < len(pending) and pending[i].arrival_step <= fe.step_no:
            fe.submit(pending[i])
            i += 1
        if i >= len(pending) and not fe.queue.peek() and fe.active == 0:
            break
        if (fault_rate > 0 and fe.active > 0
                and fe.step_no % inject_every == 0):
            from repro import protection
            tree = kvcache.as_protected_tree(fe.cache, fe.policy)
            dirty = protection.inject_tree_device(
                tree, fault_rate, jax.random.fold_in(base_key, fe.step_no))
            fe.cache = kvcache.from_protected_tree(fe.cache, dirty)
        if (weight_fault_rate > 0 and fe.active > 0
                and fe.step_no % inject_every == 0):
            from repro import protection
            fe.enc_params = protection.inject_tree_device(
                fe.enc_params, weight_fault_rate,
                jax.random.fold_in(wkey, fe.step_no))
        fe.step()
    else:
        raise RuntimeError(f"burst not drained after {max_steps} steps")
    if scrub_every > 0:
        fe.final_scrub()
    col.close()
    return col.events, telemetry.summarize(col.events), fe.results
