"""Protected paged KV cache — zero-space ECC over serving *state*.

Weights are protected end-to-end (schemes/policy/serving); at production
batch x context the KV cache dominates HBM and sits in the same fault
domain completely unprotected — one flipped bit in a cached key silently
corrupts every later token of that sequence. The paper's trick applies
directly because the cache is quantizable: keys/values are int8-quantized
per token (absmax over the token's ``(kv_heads, head_dim)`` slab, the
scale riding the page like the fused matmul's ``a_scale``), and the freed
bit space carries the (64,57,1) SEC-DED check bits.

Layout: fixed-size pages ``(page_size, kv_heads, head_dim)`` — head_dim a
multiple of 8, so ECC blocks run along head_dim and every page is
block-aligned — live in a global pool ``(n_pages, page_size, kv_heads,
head_dim)`` uint8. Each sequence owns a page-table row mapping logical
page ``j`` to its pool slot; the pool is statically partitioned today
(sequence ``b`` owns rows ``b*np .. (b+1)*np``) but every access goes
through the table, which is what continuous batching needs next.

Attention decodes pages **at use**: the XLA reference path here gathers
the sequence's encoded strips, block-decodes them (per-token flags),
dequantizes, and runs the stock :func:`layers.decode_attention`; the
fused path (:mod:`repro.kernels.paged_attention`) does decode +
dequantize + attention in VMEM and must match the reference
bit-identically. Per-token (corrected, DUE) flags are masked to valid
(``<= pos``) tokens and recorded into the layers-module KV flags sink, so
``decode_step(collect_flags=True)`` reports them per layer alongside the
weight flags.

The pools round-trip through :func:`as_protected_tree` /
:func:`from_protected_tree` as same-shape :class:`ProtectedTensor` leaves,
so the generic campaign machinery (``inject_tree_device``,
``decode_tree_with_flags``, ``due_campaign(target="kv")``) drives KV fault
campaigns unchanged.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ecc, quant, wot
from repro.models import layers as L
from repro.models.config import ArchConfig
from repro.protection.backends import get_backend
from repro.protection.schemes import ALIASES, get_scheme
from repro.protection.tensor import ProtectedTensor

__all__ = ["KVProtectionPolicy", "KV_POLICY_PRESETS", "get_kv_policy",
           "supports_paged", "pages_per_seq", "pages_needed",
           "init_paged_cache", "init_cache", "paged_gqa_decode",
           "paged_gqa_prefill", "as_protected_tree", "from_protected_tree",
           "tree_layer_flags", "kv_bytes", "dense_kv_bytes",
           "PageAllocator", "set_slot_pages", "zero_pages", "copy_page"]

# the paper's serving-state menu: parity detects+zeroes, in-place corrects
# singles / detects doubles at zero space. secded72 is excluded on purpose —
# its out-of-place check bytes would change the page stride, and the paper's
# claim under test here is the zero-space one.
KV_SCHEMES = ("faulty", "parity-zero", "in-place")


@dataclasses.dataclass(frozen=True)
class KVProtectionPolicy:
    """Static (hashable) KV protection knobs — the cache-side analogue of
    ``protection.ProtectionPolicy``.

    scheme:    "faulty" (unprotected int8 baseline) | "parity-zero" |
               "in-place". All three store int8 pages + per-token scales,
               so protection deltas measure the *codec*, not quantization.
    backend:   block-codec route for the reference path ("xla" | "pallas").
    fused:     decode-at-use attention through the fused Pallas kernel
               (``kernels.paged_attention``) instead of the XLA
               decode-then-attend reference. Bit-identical by construction.
    page_size: tokens per page.
    interpret: Pallas interpret mode for the fused kernel (CPU-safe).
    per_slot_flags: report KV (corrected, DUE) flags per BATCH SLOT
               instead of batch-summed scalars — ``flags["layers_kv"]``
               becomes (n_layers, 2, B) so the request front-end can
               attribute state faults to the request occupying each slot
               (MILR-style recovery needs to know WHICH request a DUE
               hit). Supported on every attention path: the reference
               masks per-token flags per row, the fused kernels reduce
               their in-grid (B, KV, 2) flag cells per batch row.
    attention_impl: decode-attention kernel choice for the Pallas path.
               "strip" (default) holds the whole gathered strip in VMEM
               and is bit-identical to the XLA reference — a hard VMEM
               wall at a few k tokens (``paged_attention.
               strip_vmem_bytes``). "chunked" streams fixed-size page
               chunks through a running online-softmax — VMEM bounded by
               ``chunk_pages``, context bounded by HBM — but FORFEITS
               the bit-identity contract: it is validated against an
               fp64 oracle (``paged_attention.oracle_page_attention``)
               within tolerance instead, which is why it must be asked
               for explicitly.
    chunk_pages: pages per chunk for ``attention_impl="chunked"``
               (chunk_tokens = chunk_pages * page_size).
    """

    scheme: str = "in-place"
    backend: str = "xla"
    fused: bool = False
    page_size: int = 16
    interpret: bool = True
    per_slot_flags: bool = False
    attention_impl: str = "strip"
    chunk_pages: int = 16

    def __post_init__(self):
        sid = ALIASES.get(self.scheme, self.scheme)
        if sid not in KV_SCHEMES:
            raise ValueError(f"KV scheme {self.scheme!r}; one of {KV_SCHEMES}")
        object.__setattr__(self, "scheme", sid)
        if self.page_size <= 0:
            raise ValueError(f"page_size must be positive, got {self.page_size}")
        if self.attention_impl not in ("strip", "chunked"):
            raise ValueError(f"attention_impl {self.attention_impl!r}; one "
                             f"of ('strip', 'chunked')")
        if self.chunk_pages <= 0:
            raise ValueError(f"chunk_pages must be positive, "
                             f"got {self.chunk_pages}")

    @property
    def scheme_obj(self):
        return get_scheme(self.scheme)

    @property
    def has_checks(self) -> bool:
        return self.scheme == "parity-zero"


KV_POLICY_PRESETS = {
    "unprotected": KVProtectionPolicy(scheme="faulty"),
    "parity-zero": KVProtectionPolicy(scheme="parity-zero"),
    "in-place": KVProtectionPolicy(scheme="in-place"),
    "unprotected-fused": KVProtectionPolicy(scheme="faulty", fused=True),
    "parity-zero-fused": KVProtectionPolicy(scheme="parity-zero", fused=True),
    "in-place-fused": KVProtectionPolicy(scheme="in-place", fused=True),
    # long-context fast path: page-chunked online-softmax Pallas attention.
    # NOT bit-identical to the reference (fp64-oracle tolerance gated) —
    # which is why it only runs when named explicitly.
    "unprotected-chunked": KVProtectionPolicy(scheme="faulty", fused=True,
                                              attention_impl="chunked"),
    "parity-zero-chunked": KVProtectionPolicy(scheme="parity-zero",
                                              fused=True,
                                              attention_impl="chunked"),
    "in-place-chunked": KVProtectionPolicy(scheme="in-place", fused=True,
                                           attention_impl="chunked"),
}


def get_kv_policy(policy) -> Optional[KVProtectionPolicy]:
    """Resolve a preset name (scheme aliases + optional "-fused" /
    "-chunked" suffix) or pass a :class:`KVProtectionPolicy` / None
    through."""
    if policy is None or isinstance(policy, KVProtectionPolicy):
        return policy
    name = str(policy)
    suffix = next((s for s in ("-fused", "-chunked")
                   if name.endswith(s)), "")
    base = name[: -len(suffix)] if suffix else name
    base = ALIASES.get(base, base)
    base = "unprotected" if base == "faulty" else base
    key = base + suffix
    try:
        return KV_POLICY_PRESETS[key]
    except KeyError:
        raise ValueError(f"unknown KV policy {policy!r}; one of "
                         f"{sorted(KV_POLICY_PRESETS)} (or a "
                         f"KVProtectionPolicy)") from None


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def supports_paged(cfg: ArchConfig) -> bool:
    """Families whose decode KV state is the dense (B, S, kv, hd) GQA cache
    the paged pool replaces. MLA's compressed latents and the SSM/RG-LRU
    recurrent states are different objects (open item)."""
    return cfg.family in ("dense", "vlm") or \
        (cfg.family == "moe" and not cfg.use_mla)


def pages_per_seq(max_len: int, page_size: int) -> int:
    return -(-max_len // page_size)


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pool pages a request writing ``n_tokens`` positions needs."""
    return -(-n_tokens // page_size)


def init_paged_cache(cfg: ArchConfig, batch: int, max_len: int,
                     policy, *, n_pages: Optional[int] = None) -> dict:
    """Paged replacement for ``lm.init_cache``'s dense k/v buffers.

    Keys (all with a leading stacked-layer axis so ``lax.scan`` slices them
    like the dense cache):

      k_pages/v_pages   (nl, P, page_size, kv, hd) uint8 encoded pools
      k_checks/v_checks (nl, P, page_size, kv, hd // 8) uint8 (parity only)
      k_scale/v_scale   (nl, P, page_size) f32 per-token scales
      kv_table          (nl, B, pages_per_seq) int32 page tables

    By default the pool is statically partitioned (sequence ``b`` owns rows
    ``b*np .. (b+1)*np`` via an identity table). With ``n_pages`` the pool
    is sized independently of ``batch`` for the request front-end: pages
    ``0..batch-1`` are per-slot PARKING pages (an idle slot's table points
    wholly at its own parking page, so its keep-alive writes can never
    scribble on a page owned by a live request) and pages ``batch..`` are
    the allocatable pool a :class:`PageAllocator` hands to admitted
    requests via :func:`set_slot_pages`.

    Zero pages are codec-clean for every scheme (zero blocks have syndrome
    0), so untouched pool slots decode without phantom flags.
    """
    policy = get_kv_policy(policy)
    if policy is None:
        raise ValueError("init_paged_cache needs a KV policy")
    if not supports_paged(cfg):
        raise ValueError(f"paged KV cache supports dense/vlm/moe-gqa decode "
                         f"caches, not family {cfg.family!r}"
                         + (" with MLA" if cfg.use_mla else ""))
    if cfg.head_dim % ecc.BLOCK_BYTES:
        raise ValueError(f"head_dim {cfg.head_dim} must be a multiple of "
                         f"{ecc.BLOCK_BYTES} (ECC blocks run along head_dim)")
    from repro.models import lm  # deferred: lm routes back into this module
    nl = lm.n_scan_layers(cfg)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    ps = policy.page_size
    npg = pages_per_seq(max_len, ps)
    if n_pages is None:
        pool = batch * npg
        table = jnp.tile(
            jnp.arange(pool, dtype=jnp.int32).reshape(1, batch, npg),
            (nl, 1, 1))
    else:
        if n_pages <= batch:
            raise ValueError(f"n_pages={n_pages} leaves no allocatable pages "
                             f"beyond the {batch} per-slot parking pages")
        pool = n_pages
        table = jnp.tile(                         # slot b parks on page b
            jnp.arange(batch, dtype=jnp.int32).reshape(1, batch, 1),
            (nl, 1, npg))
    cache = {
        "k_pages": jnp.zeros((nl, pool, ps, kv, hd), jnp.uint8),
        "v_pages": jnp.zeros((nl, pool, ps, kv, hd), jnp.uint8),
        "k_scale": jnp.zeros((nl, pool, ps), jnp.float32),
        "v_scale": jnp.zeros((nl, pool, ps), jnp.float32),
        "kv_table": table,
    }
    if policy.has_checks:
        cache["k_checks"] = jnp.zeros((nl, pool, ps, kv, hd // 8), jnp.uint8)
        cache["v_checks"] = jnp.zeros((nl, pool, ps, kv, hd // 8), jnp.uint8)
    return cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *, kv_policy=None,
               dtype=jnp.bfloat16) -> dict:
    """``lm.init_cache`` with a KV-policy switch: paged+protected when a
    policy is given, the stock dense cache otherwise."""
    if kv_policy is None:
        from repro.models import lm
        return lm.init_cache(cfg, batch, max_len, dtype)
    return init_paged_cache(cfg, batch, max_len, kv_policy)


# ---------------------------------------------------------------------------
# codec: per-token quantize (+WOT throttle) -> scheme encode; block decode
# with per-token flags
# ---------------------------------------------------------------------------


def _encode_kv(kf: jnp.ndarray, policy: KVProtectionPolicy):
    """float (..., kv, hd) -> (enc uint8, checks | None, scale (...,) f32).

    Per-token absmax scale over the (kv, hd) slab. The in-place scheme
    additionally WOT-throttles the quantized slab (positions 0..6 of each
    8-value block clamp to [-64, 63]) so bit 6 is free for check bits —
    the serving-state analogue of QATT's weight constraint.
    """
    kf32 = kf.astype(jnp.float32)
    scale = quant.compute_scale(kf32, axis=(-2, -1))         # (..., 1, 1)
    q = jnp.clip(jnp.round(kf32 / scale), -quant.QMAX,
                 quant.QMAX).astype(jnp.int8)
    scheme = policy.scheme_obj
    if scheme.requires_wot:
        q = wot.throttle_q(q.reshape(-1)).reshape(q.shape)
    enc, checks = scheme.encode(q, policy.backend)
    return enc, checks, scale[..., 0, 0]


def _decode_kv(enc: jnp.ndarray, checks, scheme_id: str, backend="xla"):
    """uint8 (..., kv, hd) -> (q int8, corrected (...,), due (...,)).

    Flags are per-TOKEN int32 counts (summed over the token's blocks/bytes)
    so callers can mask them by token validity — the scalar counts of
    ``Scheme.decode_with_flags`` cannot tell a live token's fault from a
    stale slot's.
    """
    if scheme_id == "faulty":
        q = jax.lax.bitcast_convert_type(enc, jnp.int8)
        z = jnp.zeros(enc.shape[:-2], jnp.int32)
        return q, z, z
    if scheme_id == "parity-zero":
        data, bad = ecc.decode_parity8(enc, checks)
        q = jax.lax.bitcast_convert_type(data, jnp.int8)
        # zeroing a detected-faulty byte IS this scheme's repair action
        cor = jnp.sum(bad.astype(jnp.int32), axis=(-2, -1))
        return q, cor, jnp.zeros_like(cor)
    if scheme_id != "in-place":
        raise ValueError(f"KV scheme {scheme_id!r}; one of {KV_SCHEMES}")
    be = get_backend(backend)
    blocks = enc.reshape(*enc.shape[:-1], enc.shape[-1] // 8, 8)
    dec, single, double = be.decode64(blocks)
    q = jax.lax.bitcast_convert_type(dec.reshape(enc.shape), jnp.int8)
    cor = jnp.sum(single.astype(jnp.int32), axis=(-2, -1))
    due = jnp.sum(double.astype(jnp.int32), axis=(-2, -1))
    return q, cor, due


# ---------------------------------------------------------------------------
# page-pool plumbing: scatter writes, table gathers
# ---------------------------------------------------------------------------


def _write_token(pages, checks, scales, table, enc, ch, sc, pos):
    """Scatter one decode token into its page. enc (B, kv, hd); sc/pos (B,)."""
    ps = pages.shape[1]
    page = pos // ps
    phys = jnp.take_along_axis(table, page[:, None], axis=1)[:, 0]   # (B,)
    slot = pos % ps
    pages = pages.at[phys, slot].set(enc)
    if checks is not None:
        checks = checks.at[phys, slot].set(ch)
    scales = scales.at[phys, slot].set(sc)
    return pages, checks, scales


def _write_pages(pages, checks, scales, table, enc, ch, sc):
    """Scatter whole prefill pages. enc (B, npg*ps, kv, hd); sc (B, npg*ps)."""
    b = table.shape[0]
    ps = pages.shape[1]
    npg = enc.shape[1] // ps
    idx = table[:, :npg].reshape(-1)                         # (B*npg,)
    pages = pages.at[idx].set(
        enc.reshape(b * npg, ps, *enc.shape[2:]))
    if checks is not None:
        checks = checks.at[idx].set(ch.reshape(b * npg, ps, *ch.shape[2:]))
    scales = scales.at[idx].set(sc.reshape(b * npg, ps))
    return pages, checks, scales


def _gather_seq(pages, checks, scales, table):
    """Pool -> per-sequence encoded strips: (enc (B, S, kv, hd), checks |
    None, scale (B, S)) with S = pages_per_seq * page_size."""
    b, npg = table.shape
    ps = pages.shape[1]
    enc = pages[table].reshape(b, npg * ps, *pages.shape[2:])
    ch = None
    if checks is not None:
        ch = checks[table].reshape(b, npg * ps, *checks.shape[2:])
    sc = scales[table].reshape(b, npg * ps)
    return enc, ch, sc


# ---------------------------------------------------------------------------
# page free/reuse: the allocator and table-rewrite API continuous batching
# runs on (see repro.serving.frontend)
# ---------------------------------------------------------------------------


class PageAllocator:
    """Host-side REFCOUNTED free-list over the pool's allocatable pages.

    Page ids ``0..reserved-1`` are per-slot parking pages (see
    :func:`init_paged_cache` with ``n_pages``) and are never handed out.
    Allocation is deterministic — lowest ids first via a heap — so a seeded
    request replay reuses the exact same physical pages run-to-run (the
    burst trace's bit-determinism contract depends on this).

    Prefix sharing maps one physical page into several slots' tables, so
    every live page carries a reference count: :meth:`alloc` hands pages
    out at refcount 1, :meth:`retain` adds a reference (a sharer's
    read-only mapping, or the front-end's prefix index), and :meth:`free`
    drops ONE reference per page — a page re-enters the heap only when
    its count hits zero, and :meth:`free` returns exactly those released
    pages so the caller knows which ones to zero. Freeing a page with no
    live reference is an accounting bug ("double free") and raises
    explicitly rather than silently re-heapifying a page some other slot
    still reads — the invariant the hypothesis suite hammers:
    ``free_count + live_count == n_pages - reserved`` always.
    """

    def __init__(self, n_pages: int, reserved: int = 0):
        if not 0 <= reserved < n_pages:
            raise ValueError(f"reserved={reserved} outside pool of "
                             f"{n_pages} pages")
        self.n_pages = n_pages
        self.reserved = reserved
        self._free = list(range(reserved, n_pages))
        heapq.heapify(self._free)
        self._refs: dict = {}       # page id -> live reference count

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def live_count(self) -> int:
        """Distinct pages currently out of the pool (any refcount)."""
        return len(self._refs)

    def refcount(self, pid: int) -> int:
        return self._refs.get(pid, 0)

    def can(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> tuple:
        """Pop the ``n`` lowest free page ids (each at refcount 1); raises
        if the pool cannot serve the request (admission control checks
        :meth:`can` first)."""
        if not self.can(n):
            raise ValueError(f"page pool exhausted: need {n}, "
                             f"free {len(self._free)}")
        ids = tuple(heapq.heappop(self._free) for _ in range(n))
        for pid in ids:
            self._refs[pid] = 1
        return ids

    def retain(self, page_ids: Sequence[int]) -> None:
        """Add one reference per page (prefix sharing / index pin). Only
        live pages can be retained — retaining a free page would resurrect
        content the pool may already have handed to someone else."""
        for pid in page_ids:
            if self._refs.get(pid, 0) < 1:
                raise ValueError(f"retain of page {pid} with no live "
                                 f"reference")
            self._refs[pid] += 1

    def free(self, page_ids: Sequence[int]) -> tuple:
        """Drop one reference per page; returns the pages whose count hit
        zero and re-entered the pool (the caller zeroes exactly those).
        Double-frees and parking-page frees are accounting bugs — fail
        loudly instead of corrupting the refcount invariant."""
        released = []
        for pid in page_ids:
            if pid < self.reserved or pid >= self.n_pages:
                raise ValueError(f"page {pid} is not allocatable "
                                 f"(reserved < {self.reserved}, "
                                 f"pool {self.n_pages})")
            refs = self._refs.get(pid, 0)
            if refs < 1:
                raise ValueError(f"double free of page {pid}")
            if refs == 1:
                del self._refs[pid]
                heapq.heappush(self._free, pid)
                released.append(pid)
            else:
                self._refs[pid] = refs - 1
        return tuple(released)

    def live_pages(self) -> tuple:
        """Sorted ids of pages currently out of the pool (refcount > 0) —
        the scrubber's worklist: only these hold content worth decoding."""
        return tuple(sorted(self._refs))

    def free_pages(self) -> tuple:
        """Sorted ids of free (allocatable, unreferenced) pages. Their
        content is known — all-zero after the free-time zeroing — so a
        scrubber restores them by re-zeroing, clearing even uncorrectable
        patterns that injection may have left behind."""
        return tuple(sorted(self._free))


def set_slot_pages(cache: dict, slot: int, page_ids: Sequence[int],
                   *, fill: Optional[int] = None) -> dict:
    """Point ``slot``'s page-table row at ``page_ids`` (logical order),
    padding the unallocated tail with ``fill`` (default: the slot's parking
    page). Tail entries are only ever gathered — never written, and masked
    by token validity — so parking is safe. Returns the updated cache."""
    npg = cache["kv_table"].shape[2]
    if len(page_ids) > npg:
        raise ValueError(f"{len(page_ids)} pages > pages_per_seq {npg}")
    row = np.full((npg,), slot if fill is None else fill, np.int32)
    row[:len(page_ids)] = page_ids
    return {**cache,
            "kv_table": cache["kv_table"].at[:, slot, :].set(
                jnp.asarray(row))}


def copy_page(cache: dict, src: int, dst: int) -> dict:
    """Copy one pool page (encoded bytes, parity planes AND per-token
    scales) across all layers — the copy-on-write primitive: when a slot
    first appends into a page it only holds a shared read-only mapping to,
    the front-end copies the page into a private one it owns, repoints its
    table entry, and drops the shared reference."""
    new = dict(cache)
    for key in ("k_pages", "v_pages", "k_scale", "v_scale",
                "k_checks", "v_checks"):
        if key in new:
            new[key] = new[key].at[:, dst].set(new[key][:, src])
    return new


def zero_pages(cache: dict, page_ids: Sequence[int]) -> dict:
    """Zero the given pool pages (encoded bytes, parity planes, AND
    per-token scales) across all layers. Zero pages are codec-clean for
    every scheme, so a freed page re-enters the pool with no stale-scale or
    stale-parity carryover — the free-side half of page reuse hygiene."""
    if len(page_ids) == 0:
        return cache
    ids = jnp.asarray(tuple(page_ids), jnp.int32)
    new = dict(cache)
    for key in ("k_pages", "v_pages", "k_scale", "v_scale",
                "k_checks", "v_checks"):
        if key in new:
            new[key] = new[key].at[:, ids].set(0)
    return new


# ---------------------------------------------------------------------------
# decode-at-use attention
# ---------------------------------------------------------------------------


def _reference_paged_attention(q, ke, kch, ksc, ve, vch, vsc, pos,
                               policy: KVProtectionPolicy):
    """XLA decode-then-attend reference over gathered strips: block decode
    -> dequantize -> stock ``layers.decode_attention``. Returns
    (o (B, H, 1, hd), corrected, due) with flags counted over valid
    (``<= pos``) tokens only — the fused kernel must match ``o``
    bit-for-bit."""
    dtype = q.dtype
    kq, kcor, kdue = _decode_kv(ke, kch, policy.scheme, policy.backend)
    vq, vcor, vdue = _decode_kv(ve, vch, policy.scheme, policy.backend)
    kf = (kq.astype(jnp.float32) * ksc[..., None, None]).astype(dtype)
    vf = (vq.astype(jnp.float32) * vsc[..., None, None]).astype(dtype)
    s = ke.shape[1]
    rep = q.shape[1] // kf.shape[2]
    kh = jnp.repeat(kf, rep, axis=2).transpose(0, 2, 1, 3)   # (B, H, S, hd)
    vh = jnp.repeat(vf, rep, axis=2).transpose(0, 2, 1, 3)
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    o = L.decode_attention(q, kh, vh, valid)
    vm = valid.astype(jnp.int32)
    if policy.per_slot_flags:  # (B,) rows — per-request fault attribution
        return (o, jnp.sum((kcor + vcor) * vm, axis=1),
                jnp.sum((kdue + vdue) * vm, axis=1))
    return o, jnp.sum((kcor + vcor) * vm), jnp.sum((kdue + vdue) * vm)


def paged_gqa_decode(p, x, cfg: ArchConfig, lc, *, pos, wt=L.Identity,
                     policy: KVProtectionPolicy):
    """Paged, protected drop-in for ``layers.gqa_decode``. x: (B, 1, D);
    ``lc`` is this layer's slice of the paged cache (see
    :func:`init_paged_cache`). Encodes the new token into its page, then
    attends over the decoded-at-use pool. Returns (out, new_lc) and records
    the masked (corrected, DUE) counts into the KV flags sink."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L._proj(x, p["wq"], p.get("bq"), wt).reshape(b, 1, h, hd)
    k = L._proj(x, p["wk"], p.get("bk"), wt).reshape(b, 1, kv, hd)
    v = L._proj(x, p["wv"], p.get("bv"), wt).reshape(b, 1, kv, hd)
    q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
    k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
    table = lc["kv_table"]
    ke1, kch1, ksc1 = _encode_kv(k[:, 0], policy)            # (B, kv, hd)
    ve1, vch1, vsc1 = _encode_kv(v[:, 0], policy)
    kp, kc, ks = _write_token(lc["k_pages"], lc.get("k_checks"),
                              lc["k_scale"], table, ke1, kch1, ksc1, pos)
    vp, vc, vs = _write_token(lc["v_pages"], lc.get("v_checks"),
                              lc["v_scale"], table, ve1, vch1, vsc1, pos)
    new_lc = {"k_pages": kp, "v_pages": vp, "k_scale": ks, "v_scale": vs,
              "kv_table": table}
    if kc is not None:
        new_lc["k_checks"], new_lc["v_checks"] = kc, vc

    ke, kch, ksc = _gather_seq(kp, kc, ks, table)
    ve, vch, vsc = _gather_seq(vp, vc, vs, table)
    qh = q.transpose(0, 2, 1, 3)                             # (B, H, 1, hd)
    if policy.attention_impl == "chunked":
        # page-chunked online-softmax fast path: VMEM bounded by the chunk,
        # tolerance-gated against the fp64 oracle (NOT bit-identical)
        from repro.kernels import paged_attention
        o, flags = paged_attention.chunked_page_attention(
            qh, ke, kch, ksc, ve, vch, vsc, pos,
            scheme=policy.scheme,
            chunk_tokens=policy.chunk_pages * policy.page_size,
            interpret=policy.interpret, per_slot=policy.per_slot_flags)
        L.record_kv_flags(flags[0], flags[1])
    elif policy.fused:
        from repro.kernels import paged_attention
        o, flags = paged_attention.fused_page_attention(
            qh, ke, kch, ksc, ve, vch, vsc, pos,
            scheme=policy.scheme, interpret=policy.interpret,
            per_slot=policy.per_slot_flags)
        L.record_kv_flags(flags[0], flags[1])
    else:
        o, corrected, due = _reference_paged_attention(
            qh, ke, kch, ksc, ve, vch, vsc, pos, policy)
        L.record_kv_flags(corrected, due)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * hd)
    return L._proj(o, p["wo"], None, wt), new_lc


def paged_gqa_prefill(p, x, cfg: ArchConfig, lc, *, positions,
                      wt=L.Identity, policy: KVProtectionPolicy,
                      chunk: int = 2048):
    """Prefill counterpart: project/rope the whole sequence, encode it into
    pages, then attend over the **decoded** pages (chunked causal) — the
    logits reflect exactly the state later decode steps will read, and the
    at-rest -> at-use round trip is exercised from token 0. x: (B, S, D).
    Returns (out, new_lc)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L._proj(x, p["wq"], p.get("bq"), wt).reshape(b, s, h, hd)
    k = L._proj(x, p["wk"], p.get("bk"), wt).reshape(b, s, kv, hd)
    v = L._proj(x, p["wv"], p.get("bv"), wt).reshape(b, s, kv, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)

    ps = lc["k_pages"].shape[1]
    pad = (-s) % ps
    if pad:  # zero-pad to whole pages; padded tokens are masked below
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    table = lc["kv_table"]
    ke, kch, ksc = _encode_kv(k, policy)                     # (B, S', kv, hd)
    ve, vch, vsc = _encode_kv(v, policy)
    kp, kc, ks = _write_pages(lc["k_pages"], lc.get("k_checks"),
                              lc["k_scale"], table, ke, kch, ksc)
    vp, vc, vs = _write_pages(lc["v_pages"], lc.get("v_checks"),
                              lc["v_scale"], table, ve, vch, vsc)
    new_lc = {"k_pages": kp, "v_pages": vp, "k_scale": ks, "v_scale": vs,
              "kv_table": table}
    if kc is not None:
        new_lc["k_checks"], new_lc["v_checks"] = kc, vc

    kq, kcor, kdue = _decode_kv(ke, kch, policy.scheme, policy.backend)
    vq, vcor, vdue = _decode_kv(ve, vch, policy.scheme, policy.backend)
    kf = (kq.astype(jnp.float32) * ksc[..., None, None]).astype(x.dtype)
    vf = (vq.astype(jnp.float32) * vsc[..., None, None]).astype(x.dtype)
    kf, vf = kf[:, :s], vf[:, :s]
    rep = h // kv
    qh = L.constrain_heads(q.transpose(0, 2, 1, 3))
    kh = L.constrain_heads(jnp.repeat(kf, rep, axis=2).transpose(0, 2, 1, 3))
    vh = L.constrain_heads(jnp.repeat(vf, rep, axis=2).transpose(0, 2, 1, 3))
    o = L.chunked_causal_attention(qh, kh, vh, chunk=chunk)
    live = (jnp.arange(ke.shape[1]) < s).astype(jnp.int32)[None, :]
    if policy.per_slot_flags:
        L.record_kv_flags(jnp.sum((kcor + vcor) * live, axis=1),
                          jnp.sum((kdue + vdue) * live, axis=1))
    else:
        L.record_kv_flags(jnp.sum((kcor + vcor) * live),
                          jnp.sum((kdue + vdue) * live))
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return L._proj(o, p["wo"], None, wt), new_lc


# ---------------------------------------------------------------------------
# campaign adapters: pools <-> ProtectedTensor trees
# ---------------------------------------------------------------------------


def as_protected_tree(cache: dict, policy) -> dict:
    """Wrap the k/v pools as same-shape :class:`ProtectedTensor` leaves so
    the generic protection machinery (``inject_tree_device``,
    ``decode_tree_with_flags``, the campaign engine) drives KV fault
    campaigns unchanged. The per-token scale broadcasts over (kv, hd)."""
    policy = get_kv_policy(policy)
    out = {}
    for name in ("k", "v"):
        pages = cache[f"{name}_pages"]
        out[name] = ProtectedTensor(
            enc=pages, checks=cache.get(f"{name}_checks"),
            scale=cache[f"{name}_scale"][..., None, None],
            scheme_id=policy.scheme, orig_shape=tuple(pages.shape))
    return out


def from_protected_tree(cache: dict, tree: dict) -> dict:
    """Write a (possibly fault-injected) ProtectedTensor pair back into a
    paged cache — the campaign's path from injected pools to live serving."""
    new = dict(cache)
    for name in ("k", "v"):
        pt = tree[name]
        new[f"{name}_pages"] = pt.enc
        if pt.checks is not None:
            new[f"{name}_checks"] = pt.checks
    return new


def tree_layer_flags(tree: dict, backend="xla") -> jnp.ndarray:
    """Per-layer (corrected, due) over a KV ProtectedTensor pair ->
    (n_layers, 2) int32 — the campaign-side view of the per-layer rows the
    serve step surfaces. Counts the whole pool (validity-blind: an injected
    fault in a stale slot still counts as detected)."""
    out = None
    for name in ("k", "v"):
        pt = tree[name]
        _, cor, due = _decode_kv(pt.enc, pt.checks, pt.scheme_id, backend)
        axes = tuple(range(1, cor.ndim))
        pair = jnp.stack([jnp.sum(cor, axis=axes),
                          jnp.sum(due, axis=axes)], axis=-1)
        out = pair if out is None else out + pair
    return out


def cache_layer_flags(cache: dict, policy, backend=None) -> jnp.ndarray:
    """:func:`tree_layer_flags` directly on a paged cache dict."""
    policy = get_kv_policy(policy)
    return tree_layer_flags(as_protected_tree(cache, policy),
                            backend or policy.backend)


# ---------------------------------------------------------------------------
# HBM accounting
# ---------------------------------------------------------------------------


def kv_bytes(cache: dict) -> dict:
    """Where the cache's HBM goes: {"stored": encoded page bytes, "checks":
    out-of-place check bytes, "scales": per-token scale bytes, "tables":
    page-table bytes, "total": all of it}. Works on both paged and dense
    caches (a dense cache is all "stored")."""
    out = {"stored": 0, "checks": 0, "scales": 0, "tables": 0}
    for key, a in cache.items():
        nb = int(math.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
        if key.endswith("_checks"):
            out["checks"] += nb
        elif key.endswith("_scale"):
            out["scales"] += nb
        elif key == "kv_table":
            out["tables"] += nb
        else:
            out["stored"] += nb
    out["total"] = sum(out.values())
    return out


def dense_kv_bytes(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> int:
    """Bytes of the dense bf16 cache the paged pool replaces (per model)."""
    from repro.models import lm
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, batch, max_len, dtype))
    return sum(int(math.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
               for a in jax.tree_util.tree_leaves(cache))
