"""Protected serving: weights live in memory as in-place-ECC-encoded int8.

``encode_tree`` quantizes (+throttles, idempotent on WOT-trained weights) and
ECC-encodes every protected tensor; the encoded image has the SAME shape as
the weight (1 byte per int8 element, check bits in place) so it inherits the
weight's sharding. ``serve_step`` decodes on read — every step — which is the
honest cost model for at-rest protection (on TPU the fused
``kernels/ecc_qmatmul`` does this in VMEM on the way to the MXU; at the XLA
level here the decode appears as elementwise ops ahead of each matmul).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ecc, quant, wot
from repro.models import lm
from repro.models.config import ArchConfig


def _protectable(path, leaf) -> bool:
    return (wot.is_protected_weight(path, leaf) and
            leaf.shape[-1] % 8 == 0)


class Protected:
    """Marker wrapper: {"enc": uint8 (same shape), "scale": f32 scalar}."""
    __slots__ = ()


def encode_leaf(w: jnp.ndarray) -> dict:
    scale = quant.compute_scale(w)
    q = jnp.clip(jnp.round(w / scale), -quant.QMAX, quant.QMAX).astype(jnp.int8)
    q = wot.throttle_q(q.reshape(-1)).reshape(w.shape)  # idempotent post-WOT
    blocks = jax.lax.bitcast_convert_type(q, jnp.uint8).reshape(
        *w.shape[:-1], w.shape[-1] // 8, 8)
    enc = ecc.encode64(blocks).reshape(w.shape)
    return {"enc": enc, "scale": scale.astype(jnp.float32)}


def decode_leaf(p: dict, dtype=jnp.bfloat16) -> jnp.ndarray:
    enc = p["enc"]
    blocks = enc.reshape(*enc.shape[:-1], enc.shape[-1] // 8, 8)
    dec, _single, _double = ecc.decode64(blocks)
    q = jax.lax.bitcast_convert_type(dec.reshape(enc.shape), jnp.int8)
    return (q.astype(jnp.float32) * p["scale"]).astype(dtype)


def _is_protected(x) -> bool:
    return isinstance(x, dict) and set(x.keys()) == {"enc", "scale"}


def encode_tree(params) -> Any:
    """fp32 params -> serving tree (protected leaves encoded, rest bf16)."""
    def enc(path, leaf):
        if _protectable(path, leaf):
            return encode_leaf(leaf)
        return leaf
    return jax.tree_util.tree_map_with_path(enc, params)


def decode_tree(enc_params, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda x: decode_leaf(x, dtype) if _is_protected(x) else x,
        enc_params, is_leaf=_is_protected)


def make_serve_step(cfg: ArchConfig, *, decode_per_step: bool = True,
                    dtype=jnp.bfloat16):
    """serve_step(enc_params, cache, tokens, pos) -> (logits, cache).

    decode_per_step=True keeps weights encoded at rest (the paper's model);
    False decodes once outside (baseline for the protection-cost ablation).
    """
    def serve_step(enc_params, cache, tokens, pos):
        params = decode_tree(enc_params, dtype) if decode_per_step else enc_params
        return lm.decode_step(cfg, params, cache, tokens, pos, dtype=dtype)

    return serve_step


def make_prefill(cfg: ArchConfig, *, dtype=jnp.bfloat16, chunk: int = 2048):
    def prefill(enc_params, tokens, extras=None):
        params = decode_tree(enc_params, dtype)
        extras = extras or {}
        return lm.forward(cfg, params, tokens, dtype=dtype, chunk=chunk,
                          **extras)
    return prefill


def spec_tree(enc_params_or_params, param_spec_fn):
    """Sharding specs for a serving tree: encoded image inherits the weight's
    spec; scale replicated."""
    from jax.sharding import PartitionSpec as P

    def spec(path, leaf):
        names = [getattr(p_, "key", None) for p_ in path]
        if names and names[-1] == "scale":
            return P()
        if names and names[-1] == "enc":
            path = path[:-1]
        return param_spec_fn(path, leaf)

    return jax.tree_util.tree_map_with_path(spec, enc_params_or_params)
