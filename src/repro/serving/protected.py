"""Protected serving on top of ``repro.protection``.

Weights live in memory as ``ProtectedTensor`` leaves — ECC-encoded int8
whose image (for the in-place scheme) has the SAME shape as the weight, so
it inherits the weight's sharding. The serve step decodes **at the point of
use**: each projection either routes through the fused Pallas
``kernels/ecc_qmatmul`` (decode in VMEM on the way to the MXU — no decoded
copy of any weight ever lands in HBM) or decodes just its own leaf inline
next to its matmul, per the :class:`~repro.protection.ProtectionPlan`.
The old whole-tree decode per step survives only as the
``decode_at_use=False`` ablation; ``decode_per_step=False`` is the
decode-once-outside baseline.

Per-layer fault accounting rides along: ``with_flags=True`` makes the step
also return the (corrected, DUE) counts each layer's decodes observed — the
double-error detections the fused kernel used to swallow.

This module is the LM-serving adapter; the protection API itself (schemes,
policy, coverage, injection) lives in ``repro.protection``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import protection
from repro.models import layers as L
from repro.models import lm
from repro.models.config import ArchConfig
from repro.protection.fused import ProtectedWeight, is_matmul_weight
from repro.protection.policy import path_str
from repro.protection.tensor import ProtectedTensor, is_protected_tensor

STACKED_KEYS = ("layers", "tail", "enc_layers")


def encode_leaf(w: jnp.ndarray,
                policy: Optional[protection.ProtectionPolicy] = None
                ) -> protection.ProtectedTensor:
    policy = policy or protection.default_policy()
    return policy.encode_leaf(w, policy.default_scheme)


def decode_leaf(p: protection.ProtectedTensor, dtype=jnp.bfloat16,
                *, backend="xla") -> jnp.ndarray:
    return protection.decode_leaf(p, dtype, backend=backend)


def encode_tree(params,
                policy: Optional[protection.ProtectionPolicy] = None) -> Any:
    """fp32 params -> serving tree (protected leaves -> ProtectedTensor)."""
    return protection.encode_tree(params, policy)


def decode_tree(enc_params, dtype=jnp.bfloat16, *, backend="xla"):
    return protection.decode_tree(enc_params, dtype, backend=backend)


def coverage(params, policy: Optional[protection.ProtectionPolicy] = None
             ) -> protection.CoverageReport:
    """Per-tree protection coverage (count + bytes, no silent gaps)."""
    return protection.coverage(params, policy)


def make_plan(params, policy: Optional[protection.ProtectionPolicy] = None,
              *, mesh=None, param_spec_fn=None) -> protection.ProtectionPlan:
    """Materialize the serving :class:`~repro.protection.ProtectionPlan` for
    a (possibly abstract) parameter tree — resolve scheme, layout, backend,
    and sharding spec per leaf ONCE, then hand the plan to
    :func:`make_serve_step` / :func:`make_prefill` / the dry-run cells."""
    return protection.make_plan(policy or protection.default_policy(), params,
                                mesh=mesh, param_spec_fn=param_spec_fn)


# ---------------------------------------------------------------------------
# decode-at-use routing
# ---------------------------------------------------------------------------


class _Router:
    """Per-leaf decode route: (backend, fused tiles, activation-quant mode)
    from the plan (leaf rules > autotune > policy default) or from the
    policy-wide ``backend`` when serving without a plan.

    act_quant: None (float activations) | "dynamic" | "static" (serve-step
    override applied to every capable leaf) | "plan" (follow each leaf's
    ``LeafPlan.act_quant`` decision). calibrate=True runs the float path
    but wires each matmul's activation absmax into the layers act sink.
    """

    def __init__(self, plan, backend, *, act_quant=None, calibrate=False,
                 abft_per_slot=False):
        if act_quant not in (None, "static", "dynamic", "plan"):
            raise ValueError(f"act_quant {act_quant!r}; one of "
                             f"(None, 'static', 'dynamic', 'plan')")
        self.plan = plan
        self.backend = protection.get_backend(backend)
        self.autotune = getattr(getattr(plan, "policy", None),
                                "autotune", None)
        self.act_quant = act_quant
        self.calibrate = calibrate
        self.abft_per_slot = abft_per_slot

    @property
    def any_abft(self) -> bool:
        """True when any planned leaf carries an ABFT or clamp decision —
        the serve step installs the ABFT sink only then, so guarded and
        unguarded plans trace to different (but each fixed) programs."""
        if self.plan is None:
            return False
        return any(lp.abft or lp.clamp is not None for lp in self.plan)

    def abft_for(self, path: str) -> tuple:
        """-> (abft enabled, clamp bound | None) for one leaf."""
        lp = self.plan.leaves.get(path) if self.plan is not None else None
        if lp is None:
            return False, None
        return bool(lp.abft), lp.clamp

    def backend_for(self, path: str):
        """Resolved backend for a leaf by its FULL plan path (the scoped
        layer transforms prefix their subtree key, so 'rg0/...' leaves in
        the hybrid decoder and its tail resolve independently)."""
        if self.plan is None:
            return self.backend
        lp = self.plan.leaves.get(path)
        if lp is not None and lp.protected:
            return lp.backend_obj or protection.get_backend(lp.backend)
        return self.backend

    def tiles_for(self, shape, *, key="tiles"):
        lookup = getattr(self.autotune, "lookup_tiles_src", None)
        return lookup(shape, key=key)[0] if lookup is not None else None

    def act_for(self, path: str) -> tuple:
        """-> (act_quant mode | None, a_scale | None) for one leaf."""
        lp = self.plan.leaves.get(path) if self.plan is not None else None
        if self.act_quant is None:
            return None, None
        if self.act_quant == "dynamic":
            return "dynamic", None
        if self.act_quant == "static":
            # the calibrated set defines what serves int8; uncalibrated
            # leaves keep float activations rather than guessing a scale
            if lp is not None and lp.a_scale is not None:
                return "static", lp.a_scale
            return None, None
        # "plan": follow the per-leaf decision
        if lp is not None:
            return lp.act_quant, lp.a_scale
        return None, None

    def wrap(self, path: str, pt: ProtectedTensor, dtype):
        """Decode-at-use view for a matmul-consumed leaf; leaves that are
        indexed elementwise (conv kernels) decode inline right here — still
        this leaf only, still at its point of use inside the layer."""
        be = self.backend_for(path)
        if not is_matmul_weight(path):
            w, corrected, due = protection.decode_leaf_with_flags(
                pt, dtype, backend=be)
            L.record_flags(corrected, due)
            return w
        lp = self.plan.leaves.get(path) if self.plan is not None else None
        shape = tuple(pt.orig_shape)
        tiles = (lp.tiles if lp is not None and lp.tiles is not None
                 else self.tiles_for(shape))
        int8_tiles = (lp.int8_tiles
                      if lp is not None and lp.int8_tiles is not None
                      else self.tiles_for(shape, key="int8_tiles"))
        aq, a_scale = self.act_for(path)
        abft, clamp = self.abft_for(path)
        return ProtectedWeight(
            pt, be, tiles=tiles, int8_tiles=int8_tiles,
            record=L.record_flags, act_quant=aq, a_scale=a_scale,
            abft=abft, clamp=clamp, record_abft=L.record_abft,
            abft_per_slot=self.abft_per_slot,
            observe=(functools.partial(L.record_act, path)
                     if self.calibrate else None))


def _scan_ready(subtree, prefix: str, router: _Router, dtype):
    """Make a stacked encoded subtree scannable: same-shape images keep
    their codec (scale broadcast over the layer dim so ``lax.scan`` can
    slice the ProtectedTensor); flat-padded images — whose 1-D byte image
    flattens *across* layers and cannot be sliced — decode here, per step
    but still per leaf (their flags land in the "top" row, not a layer
    row: the decode happens before the scan runs)."""

    def prep(path, leaf):
        if not is_protected_tensor(leaf):
            return leaf
        n_stack = int(leaf.orig_shape[0])
        if leaf.is_flat:
            w, corrected, due = protection.decode_leaf_with_flags(
                leaf, dtype, backend=router.backend_for(
                    f"{prefix}/{path_str(path)}"))
            L.record_flags(corrected, due)
            return w
        return dataclasses.replace(
            leaf, scale=jnp.broadcast_to(leaf.scale, (n_stack,)))

    return jax.tree_util.tree_map_with_path(prep, subtree,
                                            is_leaf=is_protected_tensor)


def _layer_transform(router: _Router, dtype):
    """Per-subtree ``{"layers"|"tail"|"enc_layers": fn}`` transforms for
    ``lm``'s scans: each fn fixes the sliced ProtectedTensor metadata (drop
    the stacked leading dim) and wraps each protected leaf in its
    decode-at-use view, resolving the route by the leaf's FULL plan path."""

    def scoped(prefix):
        def lt(lp):
            def wrap(path, leaf):
                if not is_protected_tensor(leaf):
                    return leaf
                pt = dataclasses.replace(leaf,
                                         orig_shape=leaf.orig_shape[1:])
                return router.wrap(f"{prefix}/{path_str(path)}", pt, dtype)
            return jax.tree_util.tree_map_with_path(
                wrap, lp, is_leaf=is_protected_tensor)
        return lt

    return {k: scoped(k) for k in STACKED_KEYS}


def _use_tree(enc_params, router: _Router, dtype):
    """enc tree -> params tree lm can run with decode at use: stacked
    subtrees stay encoded (scan-ready), top-level protected leaves become
    decode-at-use views (``embed`` decodes to a real array — it is indexed
    and transposed, not matmul'd)."""
    out = {}
    for key, sub in enc_params.items():
        if key in STACKED_KEYS:
            out[key] = _scan_ready(sub, key, router, dtype)
        elif is_protected_tensor(sub):
            if key == "embed":
                w, corrected, due = protection.decode_leaf_with_flags(
                    sub, dtype, backend=router.backend_for(key))
                L.record_flags(corrected, due)
                out[key] = w
            else:
                out[key] = router.wrap(key, sub, dtype)
        else:
            out[key] = sub
    return out


def _decoder(plan, dtype, backend):
    if plan is not None:
        return lambda enc_params: plan.decode_tree(enc_params, dtype)
    be = protection.get_backend(backend)
    return lambda enc_params: protection.decode_tree(enc_params, dtype,
                                                     backend=be)


def make_serve_step(cfg: ArchConfig, *, plan=None,
                    decode_per_step: bool = True,
                    decode_at_use: Optional[bool] = None,
                    dtype=jnp.bfloat16, backend="xla",
                    with_flags: bool = False,
                    act_quant: Optional[str] = None,
                    kv_policy=None, attention_impl: Optional[str] = None):
    """serve_step(enc_params, cache, tokens, pos) -> (logits, cache)
    (``+ flags`` with ``with_flags=True``).

    decode_at_use=True (the default) decodes each weight at its point of
    use — fused decode+matmul for Pallas-routed in-place leaves, per-leaf
    inline decode otherwise — so no decoded copy of the tree is ever
    resident. ``decode_at_use=False`` is the whole-tree decode-per-step
    ablation; ``decode_per_step=False`` the decode-once-outside baseline.
    ``plan`` (a :class:`~repro.protection.ProtectionPlan`) routes each leaf,
    so one model mixes schemes AND backends; without a plan, ``backend`` is
    the policy-wide route. ``with_flags=True`` (decode-at-use only) adds a
    flags dict: per-layer (corrected, DUE) int32 counts plus the "top" row
    for embed/head.

    ``act_quant`` switches projections onto the int8 MXU path (activations
    quantized at the point of use, served through the fused kernel's
    requantize epilogue on the Pallas route): "dynamic" (per-token absmax),
    "static" (calibrated per-leaf scales — see :func:`calibrate_act_scales`
    and ``plan.with_act_quant``), or "plan" (follow each leaf's plan
    decision). Decode-at-use only.

    When the plan marks leaves for ABFT / activation clamps
    (``plan.with_abft`` / ``with_act_quant(..., clamp=True)``) and
    ``with_flags=True``, the flags dict additionally carries the
    (checksum mismatches, clamp hits) channel: "layers_abft" /
    "tail_abft" / "top_abft" rows, shaped like the (corrected, DUE)
    rows — per-slot vectors instead of scalars when the KV policy has
    ``per_slot_flags`` so the front-end can attribute compute faults to
    requests.

    ``kv_policy`` (a :class:`~repro.serving.kvcache.KVProtectionPolicy` or
    preset name) serves against a paged protected KV cache from
    :func:`~repro.serving.kvcache.init_paged_cache`; with ``with_flags`` the
    flags dict then also carries the per-layer "layers_kv" KV rows. Works in
    every decode mode — KV protection is orthogonal to how the weights
    decode. When ``kv_policy`` is not given it defaults from
    ``plan.kv_policy`` (set via ``ProtectionPlan.with_kv_policy``), so one
    plan object can carry both the weight and the serving-state decisions.
    ``attention_impl`` overrides the resolved policy's attention routing
    ("strip" | "chunked") without rebuilding the policy — the switch onto
    the page-chunked online-softmax kernel for long contexts.
    """
    from . import kvcache
    if kv_policy is None and plan is not None:
        kv_policy = getattr(plan, "kv_policy", None)
    kvp = kvcache.get_kv_policy(kv_policy)
    if attention_impl is not None:
        if kvp is None:
            raise ValueError("attention_impl override needs a kv_policy")
        kvp = dataclasses.replace(kvp, attention_impl=attention_impl)
    if decode_at_use is None:
        decode_at_use = decode_per_step
    if act_quant is not None and not (decode_at_use and decode_per_step):
        raise ValueError("act_quant needs the decode-at-use serve step (the "
                         "whole-tree decode paths serve float weights)")
    if decode_at_use and decode_per_step:
        per_slot = bool(kvp is not None and kvp.per_slot_flags)
        router = _Router(plan, backend, act_quant=act_quant,
                         abft_per_slot=per_slot)
        lt = _layer_transform(router, dtype)
        track_abft = with_flags and router.any_abft

        def serve_step(enc_params, cache, tokens, pos):
            sink: list = []
            L.set_flags_sink(sink if with_flags else None)
            L.set_abft_sink([] if track_abft else None)
            try:
                params = _use_tree(enc_params, router, dtype)
                top_flags = L.drain_flags() if with_flags else None
                out = lm.decode_step(cfg, params, cache, tokens, pos,
                                     dtype=dtype, layer_transform=lt,
                                     collect_flags=with_flags,
                                     kv_policy=kvp)
                if with_flags:  # the output head decodes after the scans
                    top_flags = top_flags + L.drain_flags()
                # no matmul runs before the model call, so one post-step
                # drain captures every top-level ABFT record (pre-draining
                # zeros (2,) would not broadcast against per-slot (2, B))
                top_abft = L.drain_abft() if track_abft else None
            finally:
                L.set_flags_sink(None)
                L.set_abft_sink(None)
            if not with_flags:
                return out
            logits, new_cache, flags = out
            extra = {"top": top_flags, **flags}
            if track_abft:
                extra["top_abft"] = top_abft
            return logits, new_cache, extra

        return serve_step

    if with_flags:
        raise ValueError("with_flags needs the decode-at-use serve step "
                         "(the whole-tree decode paths discard flags)")
    decode = _decoder(plan, dtype, backend)

    def serve_step(enc_params, cache, tokens, pos):
        params = decode(enc_params) if decode_per_step else enc_params
        return lm.decode_step(cfg, params, cache, tokens, pos, dtype=dtype,
                              kv_policy=kvp)

    return serve_step


def make_prefill(cfg: ArchConfig, *, plan=None, dtype=jnp.bfloat16,
                 chunk: int = 2048, backend="xla",
                 decode_at_use: bool = True, with_flags: bool = False,
                 act_quant: Optional[str] = None, kv_policy=None,
                 attention_impl: Optional[str] = None):
    """prefill(enc_params, tokens, extras) -> logits (``+ flags`` with
    ``with_flags=True``). Decode-at-use by default, same routing as
    :func:`make_serve_step` (including the ``act_quant`` int8 path);
    ``decode_at_use=False`` keeps the whole-tree decode ablation.

    With ``kv_policy`` the returned callable is instead
    ``prefill(enc_params, cache, tokens, extras=None) -> (logits, cache)``
    (``+ flags``): it fills the paged protected KV cache through
    ``lm.prefill_with_cache`` so decode steps can continue from it, and the
    flags dict gains the per-layer "layers_kv" rows. ``attention_impl``
    overrides the resolved policy's attention routing, as in
    :func:`make_serve_step`."""
    from . import kvcache
    if kv_policy is None and plan is not None:
        kv_policy = getattr(plan, "kv_policy", None)
    kvp = kvcache.get_kv_policy(kv_policy)
    if attention_impl is not None:
        if kvp is None:
            raise ValueError("attention_impl override needs a kv_policy")
        kvp = dataclasses.replace(kvp, attention_impl=attention_impl)
    if act_quant is not None and not decode_at_use:
        raise ValueError("act_quant needs the decode-at-use prefill")

    def parse_args(args, extras):
        """(tokens[, extras]) without kv_policy; (cache, tokens[, extras])
        with — extras stays positional-compatible either way."""
        want = 2 if kvp is not None else 1
        if len(args) not in (want, want + 1):
            raise TypeError(f"prefill takes {want} positional args after "
                            f"enc_params (+ optional extras); got {len(args)}")
        if len(args) == want + 1:
            extras = args[-1]
        cache = args[0] if kvp is not None else None
        tokens = args[want - 1]
        return cache, tokens, extras or {}

    if decode_at_use:
        router = _Router(plan, backend, act_quant=act_quant)
        lt = _layer_transform(router, dtype)
        track_abft = with_flags and router.any_abft

        def prefill(enc_params, *args, extras=None):
            cache, tokens, extras = parse_args(args, extras)
            sink: list = []
            L.set_flags_sink(sink if with_flags else None)
            L.set_abft_sink([] if track_abft else None)
            try:
                params = _use_tree(enc_params, router, dtype)
                top_flags = L.drain_flags() if with_flags else None
                if kvp is not None:
                    out = lm.prefill_with_cache(
                        cfg, params, cache, tokens, dtype=dtype, chunk=chunk,
                        layer_transform=lt, collect_flags=with_flags,
                        kv_policy=kvp)
                else:
                    out = lm.forward(cfg, params, tokens, dtype=dtype,
                                     chunk=chunk, layer_transform=lt,
                                     collect_flags=with_flags, **extras)
                if with_flags:  # the output head decodes after the scans
                    top_flags = top_flags + L.drain_flags()
                top_abft = L.drain_abft() if track_abft else None
            finally:
                L.set_flags_sink(None)
                L.set_abft_sink(None)
            if not with_flags:
                return out
            extra_top = ({"top": top_flags, "top_abft": top_abft}
                         if track_abft else {"top": top_flags})
            if kvp is not None:
                logits, new_cache, flags = out
                return logits, new_cache, {**extra_top, **flags}
            logits, flags = out
            return logits, {**extra_top, **flags}

        return prefill

    if with_flags:
        raise ValueError("with_flags needs the decode-at-use prefill")
    decode = _decoder(plan, dtype, backend)

    def prefill(enc_params, *args, extras=None):
        cache, tokens, extras = parse_args(args, extras)
        params = decode(enc_params)
        if kvp is not None:
            return lm.prefill_with_cache(cfg, params, cache, tokens,
                                         dtype=dtype, chunk=chunk,
                                         kv_policy=kvp)
        return lm.forward(cfg, params, tokens, dtype=dtype, chunk=chunk,
                          **extras)
    return prefill


def calibrate_act_scales(cfg: ArchConfig, enc_params, tokens, *, plan=None,
                         backend="xla", dtype=jnp.bfloat16, chunk: int = 2048,
                         extras=None) -> dict:
    """Calibrate static activation scales from a small batch.

    Runs the float decode-at-use prefill over ``tokens`` (B, S) with every
    projection's activation absmax recorded at its point of use (the same
    per-leaf routing as serving, so exactly the leaves that will consume the
    scales observe them — scanned layers report through the scan, so each
    stacked leaf gets the max over its layers). Returns ``{leaf path:
    a_scale}`` with ``a_scale = absmax / 127`` — feed it to
    ``plan.with_act_quant("static", scales)`` and serve with
    ``make_serve_step(..., act_quant="static"`` or ``"plan")``.
    """
    router = _Router(plan, backend, calibrate=True)
    lt = _layer_transform(router, dtype)
    L.set_act_sink({})
    try:
        params = _use_tree(enc_params, router, dtype)
        extras = extras or {}
        _, acts = lm.forward(cfg, params, tokens, dtype=dtype, chunk=chunk,
                             layer_transform=lt, collect_acts=True, **extras)
        top = L.drain_acts()  # embed/head record outside the scans
    finally:
        L.set_act_sink(None)
    # same floor as quant.compute_scale: a projection whose calibration
    # activations were all zero must not bake a_scale=0 (divide-by-zero at
    # serve time)
    def scale(absmax):
        return max(float(absmax), 1e-12) / 127.0

    scales: dict = {}
    for sub in acts.values():          # {"layers": {path: (n_layers,)}, ...}
        for path, per_layer in (sub or {}).items():
            scales[path] = scale(jnp.max(per_layer))
    for path, absmax in top.items():
        scales[path] = scale(absmax)
    return scales


def spec_tree(enc_params_or_params, param_spec_fn, *, mesh=None):
    """Sharding specs for a serving tree: encoded image inherits the weight's
    spec; scales and check bytes replicated (flat images sharded when
    ``mesh`` is given — prefer ``make_plan(...).spec_tree()``)."""
    return protection.spec_tree(enc_params_or_params, param_spec_fn,
                                mesh=mesh)
