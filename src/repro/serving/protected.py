"""Protected serving on top of ``repro.protection``.

Weights live in memory as ``ProtectedTensor`` leaves — in-place-ECC-encoded
int8 whose image has the SAME shape as the weight (1 byte per element, check
bits in place), so it inherits the weight's sharding. ``serve_step`` decodes
on read — every step — which is the honest cost model for at-rest protection
(on TPU the fused ``kernels/ecc_qmatmul`` does this in VMEM on the way to the
MXU via ``backend="pallas"``; the XLA backend lowers the decode to
elementwise ops ahead of each matmul).

This module is the LM-serving adapter; the protection API itself (schemes,
policy, coverage, injection) lives in ``repro.protection``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax.numpy as jnp

from repro import protection
from repro.models import lm
from repro.models.config import ArchConfig


def encode_leaf(w: jnp.ndarray,
                policy: Optional[protection.ProtectionPolicy] = None
                ) -> protection.ProtectedTensor:
    policy = policy or protection.default_policy()
    return policy.encode_leaf(w, policy.default_scheme)


def decode_leaf(p: protection.ProtectedTensor, dtype=jnp.bfloat16,
                *, backend="xla") -> jnp.ndarray:
    return protection.decode_leaf(p, dtype, backend=backend)


def encode_tree(params,
                policy: Optional[protection.ProtectionPolicy] = None) -> Any:
    """fp32 params -> serving tree (protected leaves -> ProtectedTensor)."""
    return protection.encode_tree(params, policy)


def decode_tree(enc_params, dtype=jnp.bfloat16, *, backend="xla"):
    return protection.decode_tree(enc_params, dtype, backend=backend)


def coverage(params, policy: Optional[protection.ProtectionPolicy] = None
             ) -> protection.CoverageReport:
    """Per-tree protection coverage (count + bytes, no silent gaps)."""
    return protection.coverage(params, policy)


def make_plan(params, policy: Optional[protection.ProtectionPolicy] = None,
              *, mesh=None, param_spec_fn=None) -> protection.ProtectionPlan:
    """Materialize the serving :class:`~repro.protection.ProtectionPlan` for
    a (possibly abstract) parameter tree — resolve scheme, layout, backend,
    and sharding spec per leaf ONCE, then hand the plan to
    :func:`make_serve_step` / :func:`make_prefill` / the dry-run cells."""
    return protection.make_plan(policy or protection.default_policy(), params,
                                mesh=mesh, param_spec_fn=param_spec_fn)


def _decoder(plan, dtype, backend):
    if plan is not None:
        return lambda enc_params: plan.decode_tree(enc_params, dtype)
    be = protection.get_backend(backend)
    return lambda enc_params: protection.decode_tree(enc_params, dtype,
                                                     backend=be)


def make_serve_step(cfg: ArchConfig, *, plan=None,
                    decode_per_step: bool = True,
                    dtype=jnp.bfloat16, backend="xla"):
    """serve_step(enc_params, cache, tokens, pos) -> (logits, cache).

    decode_per_step=True keeps weights encoded at rest (the paper's model);
    False decodes once outside (baseline for the protection-cost ablation).
    ``plan`` (a :class:`~repro.protection.ProtectionPlan`) routes the
    per-step decode per leaf, so one model mixes schemes AND backends;
    without a plan, ``backend`` is the policy-wide route.
    """
    decode = _decoder(plan, dtype, backend)

    def serve_step(enc_params, cache, tokens, pos):
        params = decode(enc_params) if decode_per_step else enc_params
        return lm.decode_step(cfg, params, cache, tokens, pos, dtype=dtype)

    return serve_step


def make_prefill(cfg: ArchConfig, *, plan=None, dtype=jnp.bfloat16,
                 chunk: int = 2048, backend="xla"):
    decode = _decoder(plan, dtype, backend)

    def prefill(enc_params, tokens, extras=None):
        params = decode(enc_params)
        extras = extras or {}
        return lm.forward(cfg, params, tokens, dtype=dtype, chunk=chunk,
                          **extras)
    return prefill


def spec_tree(enc_params_or_params, param_spec_fn, *, mesh=None):
    """Sharding specs for a serving tree: encoded image inherits the weight's
    spec; scales and check bytes replicated (flat images sharded when
    ``mesh`` is given — prefer ``make_plan(...).spec_tree()``)."""
    return protection.spec_tree(enc_params_or_params, param_spec_fn,
                                mesh=mesh)
