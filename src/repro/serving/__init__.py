from . import frontend  # noqa: F401
from . import kvcache  # noqa: F401
from . import protected  # noqa: F401
from . import telemetry  # noqa: F401
