from . import protected  # noqa: F401
