from . import kvcache  # noqa: F401
from . import protected  # noqa: F401
