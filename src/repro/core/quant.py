"""Symmetric range-based linear 8-bit quantization (paper §3, Eq. 1).

``X^q = round(X * (2^(n-1) - 1) / max|X|)`` with n = 8 -> q in [-127, 127].
Biases are quantized to int32 (paper: 32-bit accumulation / biases).
Fake-quant with straight-through estimator (STE) drives QAT (paper §4.1 QATT).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

QMAX = 127  # 2**(8-1) - 1


def compute_scale(x: jnp.ndarray, axis=None, eps: float = 1e-12) -> jnp.ndarray:
    """scale s.t. q = round(x / scale). Per-tensor (axis=None) or per-channel."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, eps) / QMAX


def quantize(x: jnp.ndarray, scale: jnp.ndarray | None = None, axis=None):
    """-> (q int8 in [-127,127], scale)."""
    if scale is None:
        scale = compute_scale(x, axis=axis)
    q = jnp.clip(jnp.round(x / scale), -QMAX, QMAX).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(scale.dtype if hasattr(scale, "dtype") else jnp.float32) * scale


def fake_quant(x: jnp.ndarray, axis=None) -> jnp.ndarray:
    """Quantize-dequantize with STE: gradients flow as identity."""
    scale = jax.lax.stop_gradient(compute_scale(x, axis=axis))
    q = jnp.clip(jnp.round(x / scale), -QMAX, QMAX)
    fq = q * scale
    return x + jax.lax.stop_gradient(fq - x)


def quantize_bias(b: jnp.ndarray, scale: jnp.ndarray):
    """Biases -> int32 at the accumulator scale (paper §3)."""
    q = jnp.round(b / scale).astype(jnp.int32)
    return q, scale


def int8_acc(a_q: jnp.ndarray, w_q: jnp.ndarray,
             preferred=jnp.int32) -> jnp.ndarray:
    """The exact integer accumulator of :func:`int8_matmul` — split out so
    ABFT checksum verification can inspect it before the rescale."""
    return jax.lax.dot_general(
        a_q.astype(jnp.int8), w_q.astype(jnp.int8),
        dimension_numbers=(((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=preferred)


def int8_matmul(a_q: jnp.ndarray, w_q: jnp.ndarray, a_scale, w_scale,
                preferred=jnp.int32) -> jnp.ndarray:
    """Quantized matmul with int32 accumulation -> float output."""
    acc = int8_acc(a_q, w_q, preferred)
    return acc.astype(jnp.float32) * (a_scale * w_scale)
