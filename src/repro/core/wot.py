"""WOT — Weight-distribution-Oriented Training (paper §4.1).

Constraint set S_l: in every 64-bit (8-byte) block of the flattened quantized
weight vector, the first seven values must lie in [-64, 63]; only the eighth
may be large. The QATT realisation: after each QAT/SGD update, *throttle* the
quantized weights (clamp offending values to 63 / -64) and push the change
back into the fp32 master weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import quant

WOT_LO = -64
WOT_HI = 63
BLOCK = 8


def _block_view(flat: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    """Pad a flat vector to a block multiple -> ((nblk, 8), pad)."""
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, BLOCK), pad


def throttle_q(q_flat: jnp.ndarray) -> jnp.ndarray:
    """Clamp positions 0..6 of each 8-value block to [-64, 63] (int domain)."""
    blocks, pad = _block_view(q_flat)
    pos = jnp.arange(BLOCK)
    clamped = jnp.clip(blocks, WOT_LO, WOT_HI)
    blocks = jnp.where(pos == BLOCK - 1, blocks, clamped)
    out = blocks.reshape(-1)
    return out[: q_flat.shape[0]] if pad else out


def throttle_tensor(w: jnp.ndarray, scale=None) -> jnp.ndarray:
    """QATT throttling step on an fp32 weight tensor.

    Quantize -> clamp first-7-of-8 -> dequantize back into fp32 masters
    ("The float32 versions are updated accordingly", paper §4.1).
    """
    if scale is None:
        scale = quant.compute_scale(w)
    q = jnp.clip(jnp.round(w / scale), -quant.QMAX, quant.QMAX)
    qt = throttle_q(q.reshape(-1)).reshape(w.shape)
    # only touch weights the throttle actually moved; keep fp32 precision elsewhere
    return jnp.where(q == qt, w, qt * scale)


_EXCLUDED_NAMES = {"b", "bq", "bk", "bv", "dt_bias", "A_log", "D", "a_param",
                   "scale", "bias", "mean", "var"}
_EXCLUDED_PATH_PARTS = ("ln", "norm", "bn")


def is_protected_weight(path, leaf) -> bool:
    """The paper protects *weights* (matmul/conv/embedding tensors), not
    norm scales or biases (biases are 32-bit, §3). Layer-stacked norm params
    are 2-D, so name/path rules are needed on top of ndim."""
    if not (hasattr(leaf, "ndim") and leaf.ndim >= 2 and
            jnp.issubdtype(leaf.dtype, jnp.floating)):
        return False
    names = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
    if not names:
        return True
    last = names[-1]
    if last in _EXCLUDED_NAMES or last.startswith("b_"):
        return False
    return not any(part in comp for comp in names
                   for part in _EXCLUDED_PATH_PARTS)


def throttle_tree(params, predicate=None):
    """Apply throttle_tensor to every protected weight tensor in a pytree.

    predicate(path, leaf) -> bool selects tensors to constrain (default:
    ``is_protected_weight``)."""
    pred = predicate or is_protected_weight
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = [throttle_tensor(leaf) if pred(path, leaf) else leaf
           for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------- census / diagnostics ---------------------------


def count_large_in_protected(q_flat: jnp.ndarray) -> jnp.ndarray:
    """# of values outside [-64,63] in positions 0..6 (paper Fig. 3 metric)."""
    blocks, _ = _block_view(q_flat)
    large = jnp.logical_or(blocks > WOT_HI, blocks < WOT_LO)
    return jnp.sum(large[:, : BLOCK - 1])


def large_position_histogram(q_flat: jnp.ndarray) -> jnp.ndarray:
    """Per-byte-position histogram of large values (paper Fig. 1)."""
    blocks, _ = _block_view(q_flat)
    large = jnp.logical_or(blocks > WOT_HI, blocks < WOT_LO)
    return jnp.sum(large, axis=0)


def range_percentages(q_flat: np.ndarray) -> dict[str, float]:
    """% of |q| in [0,32), [32,64), [64,128] (paper Table 1 rows)."""
    a = np.abs(np.asarray(q_flat).astype(np.int32))
    n = max(a.size, 1)
    return {
        "[0,32)": float((a < 32).sum()) / n * 100,
        "[32,64)": float(((a >= 32) & (a < 64)).sum()) / n * 100,
        "[64,128]": float((a >= 64).sum()) / n * 100,
    }


def satisfies_constraint(q_flat: jnp.ndarray) -> bool:
    return int(count_large_in_protected(q_flat)) == 0
