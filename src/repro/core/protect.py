"""Protection-strategy API (paper §5.1 counterparts + the contribution).

A scheme turns a flat int8 weight vector into a *stored byte image* (what
lives in fault-prone memory) and back. Faults are injected into the full
stored image — including out-of-place check bytes, exactly as DRAM faults
would hit ECC bits too.

  none      : raw bytes, no protection                       (paper "faulty")
  parity8   : byte parity, detected-faulty weight -> 0       (paper "zero")
  secded72  : standard SEC-DED (72,64,1), 12.5% overhead     (paper "ecc")
  inplace   : in-place zero-space SEC-DED (64,57,1), 0%      (paper "in-place")
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from . import ecc, faults


@dataclasses.dataclass
class Stored:
    """Byte image of one protected flat weight vector."""
    data: np.ndarray                      # (n,) uint8 — weight bytes
    checks: np.ndarray | None             # out-of-place check bytes or None
    n_weights: int                        # original length (pre-padding)

    @property
    def total_bytes(self) -> int:
        return self.data.size + (self.checks.size if self.checks is not None else 0)


class Scheme:
    name: str = "none"
    needs_ecc_hw: bool = False

    def encode(self, q_flat: np.ndarray) -> Stored:
        q = np.asarray(q_flat, dtype=np.int8).reshape(-1)
        data, _ = ecc.pad_to_block_multiple(q.view(np.uint8))
        return Stored(data=data.copy(), checks=None, n_weights=q.size)

    def decode(self, s: Stored) -> np.ndarray:
        return s.data[: s.n_weights].view(np.int8).copy()

    def space_overhead(self, s: Stored) -> float:
        return (s.total_bytes - s.n_weights) / s.n_weights

    def inject(self, s: Stored, rate: float, seed: int) -> Stored:
        """Flip bits across the whole stored image (data + check bytes)."""
        if s.checks is None:
            return Stored(faults.inject(s.data, rate, seed), None, s.n_weights)
        image = np.concatenate([s.data, s.checks])
        flipped = faults.inject(image, rate, seed)
        return Stored(flipped[: s.data.size], flipped[s.data.size:], s.n_weights)


class Parity8(Scheme):
    name = "zero"

    def encode(self, q_flat: np.ndarray) -> Stored:
        s = super().encode(q_flat)
        checks = np.asarray(ecc.encode_parity8(jnp.asarray(s.data)))
        return Stored(s.data, checks, s.n_weights)

    def decode(self, s: Stored) -> np.ndarray:
        data, _bad = ecc.decode_parity8(jnp.asarray(s.data), jnp.asarray(s.checks))
        return np.asarray(data)[: s.n_weights].view(np.int8).copy()


class Secded72(Scheme):
    name = "ecc"
    needs_ecc_hw = True

    def encode(self, q_flat: np.ndarray) -> Stored:
        s = super().encode(q_flat)
        checks = np.asarray(ecc.encode72(jnp.asarray(ecc.to_blocks(jnp.asarray(s.data)))))
        return Stored(s.data, checks, s.n_weights)

    def decode(self, s: Stored) -> np.ndarray:
        blocks = ecc.to_blocks(jnp.asarray(s.data))
        data, _single, _double = ecc.decode72(blocks, jnp.asarray(s.checks))
        return np.asarray(data).reshape(-1)[: s.n_weights].view(np.int8).copy()


class InPlace(Scheme):
    """The paper's contribution. Requires WOT-compliant weights."""
    name = "in-place"
    needs_ecc_hw = True

    def encode(self, q_flat: np.ndarray) -> Stored:
        q = np.asarray(q_flat, dtype=np.int8).reshape(-1)
        data, _ = ecc.pad_to_block_multiple(q.view(np.uint8))
        blocks = jnp.asarray(data.reshape(-1, ecc.BLOCK_BYTES))
        enc = np.asarray(ecc.encode64(blocks)).reshape(-1)
        return Stored(enc, None, q.size)

    def decode(self, s: Stored) -> np.ndarray:
        blocks = jnp.asarray(s.data.reshape(-1, ecc.BLOCK_BYTES))
        dec, _single, _double = ecc.decode64(blocks)
        return np.asarray(dec).reshape(-1)[: s.n_weights].view(np.int8).copy()


SCHEMES: dict[str, Callable[[], Scheme]] = {
    "faulty": Scheme,
    "zero": Parity8,
    "ecc": Secded72,
    "in-place": InPlace,
}


def get_scheme(name: str) -> Scheme:
    return SCHEMES[name]()


def run_fault_trial(scheme: Scheme, q_flat: np.ndarray, rate: float, seed: int) -> np.ndarray:
    """encode -> inject faults -> decode: the per-trial pipeline of Table 2."""
    stored = scheme.encode(q_flat)
    return scheme.decode(scheme.inject(stored, rate, seed))
