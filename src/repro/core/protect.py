"""DEPRECATED — use :mod:`repro.protection`.

This module is a compatibility shim over the unified protection API and will
be removed after one release. The old host-side classes map onto
``repro.protection.host``:

  protect.Stored            -> protection.Stored      (same fields)
  protect.get_scheme(name)  -> protection.get_host_scheme(name)
  protect.run_fault_trial   -> protection.run_fault_trial
  protect.Scheme()/Parity8()/Secded72()/InPlace()
                            -> protection.get_host_scheme(
                                   "faulty"/"parity-zero"/"secded72"/"in-place")
"""
from __future__ import annotations

import warnings

from repro.protection.host import (HostScheme, Stored,  # noqa: F401
                                   get_host_scheme, run_fault_trial)

warnings.warn(
    "repro.core.protect is deprecated; use repro.protection "
    "(ProtectionPolicy / get_scheme / get_host_scheme) instead.",
    DeprecationWarning, stacklevel=2)


class Scheme(HostScheme):
    name = "none"  # the historical label; new code sees "faulty"

    def __init__(self):
        super().__init__("faulty")


class Parity8(HostScheme):
    name = "zero"

    def __init__(self):
        super().__init__("parity-zero")


class Secded72(HostScheme):
    name = "ecc"

    def __init__(self):
        super().__init__("secded72")


class InPlace(HostScheme):
    name = "in-place"

    def __init__(self):
        super().__init__("in-place")


SCHEMES = {
    "faulty": Scheme,
    "zero": Parity8,
    "ecc": Secded72,
    "in-place": InPlace,
}


def get_scheme(name: str) -> HostScheme:
    return SCHEMES[name]() if name in SCHEMES else get_host_scheme(name)
