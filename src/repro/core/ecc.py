"""SEC-DED codecs for in-place zero-space memory protection (paper §4).

Implements three codecs:

* ``inplace (64,57,1)`` — the paper's contribution. A Hsiao-style SEC-DED code
  whose 7 check bits are stored *in place*, in the non-informative bit (bit 6)
  of the first seven bytes of every 8-byte block. Works on WOT-regularized
  int8 weights where bytes 0..6 of each block are in [-64, 63] (so bit 6 ==
  bit 7 and carries no information).
* ``secded72 (72,64,1)`` — the industry-standard baseline: 8 check bits per
  64-bit block, stored out-of-place (12.5% overhead).
* ``parity8`` — one parity bit per byte (the paper's "Parity Zero" baseline).

Code construction (64,57,1): GF(2)^7 has exactly 64 odd-weight vectors. Use
them all as parity-check columns — one per bit of the 64-bit code word. The
seven weight-1 columns sit at the in-place check positions (bit 6 of bytes
0..6). Properties: all columns distinct & nonzero -> single-error correction;
all columns odd weight -> any double-error syndrome is even weight, hence
never equal to a column -> detected, never miscorrected.

Everything is vectorised over a leading block axis: arrays of shape
``(..., nblk, 8)`` uint8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# (64, 57, 1) in-place code tables
# ---------------------------------------------------------------------------

BLOCK_BYTES = 8
CHECK_BIT = 6  # bit index inside a byte that holds a check bit (bytes 0..6)


def _odd_weight_values(width: int) -> list[int]:
    return [v for v in range(1, 1 << width) if bin(v).count("1") % 2 == 1]


def _build_cols64() -> np.ndarray:
    """COLS[g] = 7-bit parity-check column of global bit g (g = byte*8 + bit)."""
    cols = np.zeros(64, dtype=np.uint8)
    check_positions = [i * 8 + CHECK_BIT for i in range(7)]
    for i, g in enumerate(check_positions):
        cols[g] = 1 << i  # weight-1 column => check bit i
    rest = [v for v in _odd_weight_values(7) if bin(v).count("1") >= 3]
    assert len(rest) == 57
    data_positions = [g for g in range(64) if g not in check_positions]
    for g, v in zip(data_positions, rest):
        cols[g] = v
    return cols


COLS64 = _build_cols64()  # (64,) uint8, values in [1, 127], all odd weight

# ROWMASK64[k, i]: byte mask for byte i of row k — bit b set iff COLS64[i*8+b]
# has bit k set. Row-k parity of (word & ROWMASK64[k]) == syndrome bit k.
ROWMASK64 = np.zeros((7, 8), dtype=np.uint8)
for k in range(7):
    for g in range(64):
        if (COLS64[g] >> k) & 1:
            ROWMASK64[k, g // 8] |= np.uint8(1 << (g % 8))

# COLS64 reshaped per byte for flip-mask computation: (8 bytes, 8 bits)
COLS64_BYBYTE = COLS64.reshape(8, 8)

_SIGN_KEEP = np.uint8(0xFF ^ (1 << CHECK_BIT))  # 0xBF


def _syndrome64(blocks: jnp.ndarray) -> jnp.ndarray:
    """Syndrome of each 8-byte block. blocks: (..., 8) uint8 -> (...,) uint8."""
    rowmask = jnp.asarray(ROWMASK64)  # (7, 8)
    masked = blocks[..., None, :] & rowmask  # (..., 7, 8)
    pc = jax.lax.population_count(masked).astype(jnp.uint32)
    parity = (jnp.sum(pc, axis=-1) & 1).astype(jnp.uint8)  # (..., 7)
    weights = jnp.asarray([1 << k for k in range(7)], dtype=jnp.uint8)
    return jnp.sum(parity * weights, axis=-1).astype(jnp.uint8)


def restore_sign_bits(blocks: jnp.ndarray) -> jnp.ndarray:
    """Copy bit7 -> bit6 for bytes 0..6 of each block (paper Fig. 2 wiring)."""
    sign6 = (blocks >> 1) & np.uint8(1 << CHECK_BIT)
    restored = (blocks & _SIGN_KEEP) | sign6
    keep_last = jnp.arange(8, dtype=jnp.int32) == 7
    return jnp.where(keep_last, blocks, restored)


def encode64(blocks: jnp.ndarray) -> jnp.ndarray:
    """Encode WOT-compliant blocks: overwrite bit6 of bytes 0..6 with check bits.

    blocks: (..., 8) uint8 (int8 weights viewed as bytes). Bytes 0..6 must be
    WOT-small ([-64,63]); their bit 6 is overwritten in place.
    """
    blocks = blocks.astype(jnp.uint8)
    keep_last = jnp.arange(8, dtype=jnp.int32) == 7
    zeroed = jnp.where(keep_last, blocks, blocks & _SIGN_KEEP)
    syn = _syndrome64(zeroed)  # (...,) — equals required check bits
    # scatter syndrome bit i into bit6 of byte i
    i = jnp.arange(8, dtype=jnp.uint8)
    checks = ((syn[..., None] >> i) & 1).astype(jnp.uint8) << CHECK_BIT
    checks = jnp.where(keep_last, jnp.uint8(0), checks)
    return zeroed | checks


def decode64(blocks: jnp.ndarray):
    """Decode in-place SEC-DED blocks.

    Returns (weights_bytes, single_corrected, double_detected):
      weights_bytes: (..., 8) uint8 — corrected, sign bits restored.
      single_corrected / double_detected: (...,) bool per block.
    """
    blocks = blocks.astype(jnp.uint8)
    syn = _syndrome64(blocks)  # (...,)
    syn_pc = jax.lax.population_count(syn)
    single = (syn_pc & 1) == 1  # odd-weight syndrome -> single-bit error
    double = jnp.logical_and(syn != 0, jnp.logical_not(single))
    cols = jnp.asarray(COLS64_BYBYTE)  # (8, 8)
    match = (syn[..., None, None] == cols).astype(jnp.uint8)  # (..., 8, 8)
    bitval = jnp.asarray([1 << b for b in range(8)], dtype=jnp.uint8)
    flip = jnp.sum(match * bitval, axis=-1).astype(jnp.uint8)  # (..., 8)
    corrected = jnp.where(single[..., None], blocks ^ flip, blocks)
    return restore_sign_bits(corrected), single, double


# ---------------------------------------------------------------------------
# (72, 64, 1) standard SEC-DED baseline
# ---------------------------------------------------------------------------


def _build_cols72() -> np.ndarray:
    """COLS72[g] = 8-bit column for data bit g (g in [0,64)). Check columns
    are implicitly the 8 weight-1 vectors (stored in a separate check byte)."""
    vals = [v for v in _odd_weight_values(8) if bin(v).count("1") >= 3]
    assert len(vals) >= 64
    return np.asarray(vals[:64], dtype=np.uint8)


COLS72 = _build_cols72()
ROWMASK72 = np.zeros((8, 8), dtype=np.uint8)
for k in range(8):
    for g in range(64):
        if (COLS72[g] >> k) & 1:
            ROWMASK72[k, g // 8] |= np.uint8(1 << (g % 8))
COLS72_BYBYTE = COLS72.reshape(8, 8)


def _syndrome72(blocks: jnp.ndarray) -> jnp.ndarray:
    rowmask = jnp.asarray(ROWMASK72)
    masked = blocks[..., None, :] & rowmask  # (..., 8, 8)
    pc = jax.lax.population_count(masked).astype(jnp.uint32)
    parity = (jnp.sum(pc, axis=-1) & 1).astype(jnp.uint8)
    weights = jnp.asarray([1 << k for k in range(8)], dtype=jnp.uint8)
    return jnp.sum(parity * weights, axis=-1).astype(jnp.uint8)


def encode72(blocks: jnp.ndarray) -> jnp.ndarray:
    """Returns the check byte for each 8-byte data block: (..., 8) -> (...,)."""
    return _syndrome72(blocks.astype(jnp.uint8))


def decode72(blocks: jnp.ndarray, checks: jnp.ndarray):
    """Standard SEC-DED decode. Returns (data, single, double)."""
    blocks = blocks.astype(jnp.uint8)
    syn = _syndrome72(blocks) ^ checks.astype(jnp.uint8)
    syn_pc = jax.lax.population_count(syn)
    single = (syn_pc & 1) == 1
    double = jnp.logical_and(syn != 0, jnp.logical_not(single))
    cols = jnp.asarray(COLS72_BYBYTE)
    match = (syn[..., None, None] == cols).astype(jnp.uint8)
    bitval = jnp.asarray([1 << b for b in range(8)], dtype=jnp.uint8)
    flip = jnp.sum(match * bitval, axis=-1).astype(jnp.uint8)
    corrected = jnp.where(single[..., None], blocks ^ flip, blocks)
    return corrected, single, double


# ---------------------------------------------------------------------------
# parity-per-byte ("Parity Zero") baseline
# ---------------------------------------------------------------------------


def encode_parity8(data: jnp.ndarray) -> jnp.ndarray:
    """One parity bit per byte, packed 8 bytes' parities -> 1 check byte.

    data: (..., n) uint8 with n % 8 == 0 -> (..., n // 8) uint8.
    """
    data = data.astype(jnp.uint8)
    parity = (jax.lax.population_count(data) & 1).astype(jnp.uint8)
    grouped = parity.reshape(*parity.shape[:-1], -1, 8)
    weights = jnp.asarray([1 << k for k in range(8)], dtype=jnp.uint8)
    return jnp.sum(grouped * weights, axis=-1).astype(jnp.uint8)


def decode_parity8(data: jnp.ndarray, checks: jnp.ndarray):
    """Detect parity mismatches; zero out mismatching bytes (paper's 'zero').

    Returns (corrected_data, error_mask) with error_mask (..., n) bool.
    """
    data = data.astype(jnp.uint8)
    expected = encode_parity8(data)
    diff = expected ^ checks.astype(jnp.uint8)  # (..., n//8)
    i = jnp.arange(8, dtype=jnp.uint8)
    bad = ((diff[..., None] >> i) & 1).astype(bool)  # (..., n//8, 8)
    bad = bad.reshape(*data.shape)
    return jnp.where(bad, jnp.uint8(0), data), bad


# ---------------------------------------------------------------------------
# helpers: int8 tensor <-> padded block view
# ---------------------------------------------------------------------------


def to_blocks(flat_bytes: jnp.ndarray) -> jnp.ndarray:
    """(n,) uint8 (n % 8 == 0) -> (n // 8, 8) uint8."""
    return flat_bytes.reshape(-1, BLOCK_BYTES)


def pad_to_block_multiple(flat: np.ndarray) -> tuple[np.ndarray, int]:
    n = flat.shape[0]
    pad = (-n) % BLOCK_BYTES
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, flat.dtype)])
    return flat, pad


@functools.partial(jax.jit, static_argnames=())
def inplace_roundtrip(blocks: jnp.ndarray) -> jnp.ndarray:
    """encode -> decode with no faults (identity on WOT weights); for tests."""
    dec, _, _ = decode64(encode64(blocks))
    return dec
