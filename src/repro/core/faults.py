"""Memory fault injection (paper §5.3).

Fault model: random bit flips in the *stored byte image* of the weights.
``#faulty bits = round(#weight bits * fault_rate)``; each experiment draws
distinct bit positions uniformly. Host-side numpy (experiment harness) plus a
jax scatter-XOR path for on-device injection inside jitted eval loops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def n_faults(n_bits: int, rate: float) -> int:
    return int(round(n_bits * rate))


def sample_positions(n_bits: int, rate: float, seed: int) -> np.ndarray:
    """Distinct uniform bit positions. Resample-until-unique (n << n_bits)."""
    n = n_faults(n_bits, rate)
    rng = np.random.default_rng(seed)
    if n == 0:
        return np.zeros((0,), dtype=np.int64)
    pos = np.unique(rng.integers(0, n_bits, size=n))
    while pos.size < n:
        extra = rng.integers(0, n_bits, size=n - pos.size)
        pos = np.unique(np.concatenate([pos, extra]))
    return pos[:n]


def flip_bits_np(stored: np.ndarray, positions: np.ndarray) -> np.ndarray:
    """XOR-flip the given global bit positions of a uint8 byte image."""
    out = np.array(stored, dtype=np.uint8, copy=True).reshape(-1)
    byte_idx = positions // 8
    bit = (np.uint8(1) << (positions % 8).astype(np.uint8))
    np.bitwise_xor.at(out, byte_idx, bit)
    return out.reshape(stored.shape)


def inject(stored: np.ndarray, rate: float, seed: int) -> np.ndarray:
    """Inject random bit flips at `rate` into a uint8 byte image."""
    flat = np.asarray(stored, dtype=np.uint8).reshape(-1)
    pos = sample_positions(flat.size * 8, rate, seed)
    return flip_bits_np(flat, pos).reshape(stored.shape)


def inject_jax(stored: jnp.ndarray, rate: float, key) -> jnp.ndarray:
    """On-device injection (jit-safe). Sampling is with replacement; repeated
    hits cancel in XOR parity, matching physical double-flips. Builds a
    per-bit parity vector, so intended for test/eval-scale tensors."""
    flat = stored.reshape(-1).astype(jnp.uint8)
    n_bits = flat.size * 8
    n = n_faults(n_bits, rate)
    if n == 0:
        return stored
    pos = jax.random.randint(key, (n,), 0, n_bits)
    parity = jnp.zeros((n_bits,), jnp.uint8).at[pos].add(1) & 1
    bitval = jnp.asarray([1 << b for b in range(8)], dtype=jnp.uint8)
    mask = jnp.sum(parity.reshape(-1, 8) * bitval, axis=-1).astype(jnp.uint8)
    return (flat ^ mask).reshape(stored.shape)
