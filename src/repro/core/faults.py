"""Memory fault injection (paper §5.3).

Fault model: random bit flips in the *stored byte image* of the weights.
``#faulty bits = round(#weight bits * fault_rate)``; bit positions are drawn
uniformly **with replacement** by one sampler shared by the host (NumPy) and
jit (JAX) paths, and applied as an XOR mask so a position drawn twice cancels
— exactly what two physical upsets of the same DRAM cell do.

Collision-probability argument (why with-replacement is the right fix for the
old host-side resample-until-unique loop, which was a data-dependent loop no
device path can run): with ``n = round(n_bits * rate)`` draws over ``n_bits``
positions, the expected number of colliding pairs is the birthday bound
``n * (n - 1) / (2 * n_bits) ~= n_bits * rate**2 / 2``.  Relative to ``n``
that is a bias of ``~rate / 2`` on the effective flip count — at the paper's
largest rate (3e-3) fewer than 0.15% of the requested flips cancel, two
orders of magnitude below the trial-to-trial accuracy std of Table 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_BITVALS = tuple(1 << b for b in range(8))


def n_faults(n_bits: int, rate: float) -> int:
    return int(round(n_bits * rate))


def _draw(n_bits: int, n: int, seed):
    """The one position sampler both paths share: ``n`` uniform draws with
    replacement.  ``seed`` may be an int (host path, NumPy ``default_rng``)
    or a JAX PRNG key (device path, trace-safe)."""
    if isinstance(seed, (int, np.integer)):
        rng = np.random.default_rng(seed)
        return rng.integers(0, n_bits, size=n, dtype=np.int64)
    return jax.random.randint(seed, (n,), 0, n_bits)


def sample_positions(n_bits: int, rate: float, seed) -> np.ndarray:
    """Uniform bit positions, one fixed-size draw with replacement.

    ``seed`` may be an int (host/NumPy) or a JAX PRNG key (device/jit); both
    have identical semantics: repeated positions cancel under the XOR
    application (see module docstring for the collision-probability
    argument).
    """
    return _draw(n_bits, n_faults(n_bits, rate), seed)


def flip_bits_np(stored: np.ndarray, positions: np.ndarray) -> np.ndarray:
    """XOR-flip the given global bit positions of a uint8 byte image.

    ``np.bitwise_xor.at`` applies repeats unbuffered, so duplicate positions
    cancel pairwise — the same semantics as the device parity mask.
    """
    out = np.array(stored, dtype=np.uint8, copy=True).reshape(-1)
    byte_idx = positions // 8
    bit = (np.uint8(1) << (positions % 8).astype(np.uint8))
    np.bitwise_xor.at(out, byte_idx, bit)
    return out.reshape(stored.shape)


def inject(stored: np.ndarray, rate: float, seed: int) -> np.ndarray:
    """Inject random bit flips at `rate` into a uint8 byte image (host)."""
    flat = np.asarray(stored, dtype=np.uint8).reshape(-1)
    pos = sample_positions(flat.size * 8, rate, seed)
    return flip_bits_np(flat, pos).reshape(stored.shape)


def flip_mask_jax(n_bits: int, n, key, n_max: int) -> jnp.ndarray:
    """Per-byte XOR mask with ``n`` of ``n_max`` sampled flips active.

    ``n_max`` is the static sample budget (fixes array shapes for jit);
    ``n`` may be a traced int32 scalar ``<= n_max`` — only the first ``n``
    sampled positions contribute, which is what lets one compiled program
    sweep fault rates.  Builds a per-bit parity vector, so intended for
    eval-scale tensors.
    """
    pos = _draw(n_bits, n_max, key)
    live = (jnp.arange(n_max) < n).astype(jnp.uint8)
    parity = jnp.zeros((n_bits,), jnp.uint8).at[pos].add(live) & 1
    bitval = jnp.asarray(_BITVALS, dtype=jnp.uint8)
    return jnp.sum(parity.reshape(-1, 8) * bitval, axis=-1).astype(jnp.uint8)


def inject_jax(stored: jnp.ndarray, rate: float, key) -> jnp.ndarray:
    """On-device injection (jit-safe) at a static Python-float rate."""
    flat = stored.reshape(-1).astype(jnp.uint8)
    n_bits = flat.size * 8
    n = n_faults(n_bits, rate)
    if n == 0:
        return stored
    return (flat ^ flip_mask_jax(n_bits, n, key, n)).reshape(stored.shape)


def inject_jax_rate(stored: jnp.ndarray, rate, key,
                    max_rate: float) -> jnp.ndarray:
    """On-device injection with a *traced* rate (compiled fault campaigns).

    The sample budget is fixed at ``n_faults(n_bits, max_rate)`` so the
    program shape is rate-independent; ``round(n_bits * rate)`` of the
    sampled positions are live.  ``rate`` may be a traced f32 scalar in
    ``[0, max_rate]`` — e.g. one lane of a ``vmap`` over the rate grid.
    """
    flat = stored.reshape(-1).astype(jnp.uint8)
    n_bits = flat.size * 8
    n_max = n_faults(n_bits, max_rate)
    if n_max == 0:
        return stored
    n = jnp.round(n_bits * jnp.asarray(rate, jnp.float32)).astype(jnp.int32)
    return (flat ^ flip_mask_jax(n_bits, n, key, n_max)).reshape(stored.shape)
