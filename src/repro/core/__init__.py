"""Core: in-place zero-space ECC, WOT training co-design, fault injection."""
from . import ecc, faults, protect, quant, wot  # noqa: F401
