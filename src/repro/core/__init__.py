"""Core: in-place zero-space ECC, WOT training co-design, fault injection.

``repro.core.protect`` is a deprecated shim over :mod:`repro.protection`;
it is imported lazily so only code that still uses it sees the warning.
"""
from . import ecc, faults, quant, wot  # noqa: F401


def __getattr__(name):
    if name == "protect":
        import importlib
        return importlib.import_module(".protect", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
