"""Core: in-place zero-space ECC, WOT training co-design, fault injection.

The old ``repro.core.protect`` shim has been removed — all protection goes
through :mod:`repro.protection` (see the README migration table).
"""
from . import ecc, faults, quant, wot  # noqa: F401
