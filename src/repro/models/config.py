"""Architecture configuration dataclass covering all assigned families."""
from __future__ import annotations

import dataclasses
from typing import Optional


def pad_vocab(v: int, multiple: int = 128) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int                       # raw vocab (padded via vocab_padded)
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rms"                # rms | layer
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # routed expert hidden dim
    capacity_factor: float = 1.25

    # --- MLA (deepseek v2/v3) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- hybrid (recurrentgemma) ---
    rglru_block: int = 0             # layers per super-block that are RG-LRU
    attn_window: int = 0             # local attention window (0 = global)
    lru_width: int = 0

    # --- enc-dec (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 1500              # precomputed frame embeddings (stub)

    # --- vlm (paligemma) ---
    n_patches: int = 0               # precomputed patch embeddings (stub)

    # --- training ---
    microbatch: int = 8              # grad-accumulation microbatches per step
    remat: bool = True
    param_dtype: str = "float32"     # master-weight dtype (bf16 for the MoE
                                     # giants so params+momentum fit HBM)

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab)

    @property
    def d_inner(self) -> int:        # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / windowed-attention)."""
        return self.family in ("ssm", "hybrid")

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
