"""Full model assembly for every assigned architecture family.

Params are plain pytrees; per-layer params are stacked along a leading L axis
and consumed with ``jax.lax.scan`` (compact HLO — essential for 512-device
AOT compiles). ``wt`` hooks QAT fake-quant / protected-decode into every
matmul weight.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from . import layers as L
from .config import ArchConfig

Identity = L.Identity

# --------------------------------------------------------------------------
# optional sharding context (set by the launcher / dry-run; None = no
# constraints, e.g. CPU smoke tests without a mesh)
# --------------------------------------------------------------------------

# {"dp": ("pod","data")| "data", "model": "model", "sp": bool} — the state
# itself lives in layers.py so layer internals (MoE dispatch) see it too.


def set_sharding_ctx(ctx: dict | None):
    L.set_sharding_ctx(ctx)


def _constrain_residual(x):
    """Sequence-parallel residual stream: (B, S, D) -> P(dp, model, None)."""
    ctx = L.SHARDING_CTX
    if ctx is None:
        return x
    dp, mdl = ctx["dp"], ctx["model"]
    if ctx.get("sp") and x.shape[1] % ctx.get("model_size", 1) == 0:
        return L.constrain(x, dp, mdl, None)
    return L.constrain(x, dp, None, None)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_dict(key, shapes: dict, n_layers: int | None, dtype) -> dict:
    """Init a dict of arrays; if n_layers, prepend the stacked layer dim."""
    out = {}
    ks = jax.random.split(key, len(shapes))
    for k_, (name, shp) in zip(ks, sorted(shapes.items())):
        full = (n_layers, *shp) if n_layers else shp
        if name == "A_log":
            v = jnp.log(jnp.broadcast_to(jnp.linspace(1.0, 16.0, shp[-1]), full))
        elif name == "dt_bias":
            v = jnp.full(full, 0.5)
        elif name == "a_param":
            v = jnp.full(full, 1.3)
        elif name.startswith("b_") or name == "b":
            v = jnp.zeros(full)
        elif name == "w" or name == "D":
            v = jnp.ones(full)
        elif name.startswith("conv"):
            v = jax.random.normal(k_, full) * 0.1
        else:
            fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
            v = jax.random.normal(k_, full) * (0.02 if len(shp) < 2
                                               else 1.0 / np.sqrt(fan_in))
        out[name] = v.astype(dtype)
    return out


def _norm_shape(cfg):
    return {"w": (cfg.d_model,)} if cfg.norm == "rms" else \
        {"w": (cfg.d_model,), "b": (cfg.d_model,)}


def _layer_shapes(cfg: ArchConfig) -> dict:
    """Per-layer (pre-stacking) param shapes for the scanned decoder block."""
    f = cfg.family
    if f in ("dense", "vlm"):
        return {"attn": L.gqa_params_shape(cfg), "mlp": L.swiglu_params_shape(cfg),
                "ln1": _norm_shape(cfg), "ln2": _norm_shape(cfg)}
    if f == "moe":
        return {"attn": L.mla_params_shape(cfg) if cfg.use_mla
                else L.gqa_params_shape(cfg),
                "moe": L.moe_params_shape(cfg),
                "ln1": _norm_shape(cfg), "ln2": _norm_shape(cfg)}
    if f == "ssm":
        return {"mixer": L.mamba2_params_shape(cfg), "ln1": _norm_shape(cfg)}
    if f == "hybrid":
        # super-block of 3 layers: [rglru, rglru, local-attn], each + MLP
        blk = {}
        for i in range(2):
            blk[f"rg{i}"] = L.rglru_params_shape(cfg)
            blk[f"rg{i}_mlp"] = L.swiglu_params_shape(cfg)
            blk[f"rg{i}_ln1"] = _norm_shape(cfg)
            blk[f"rg{i}_ln2"] = _norm_shape(cfg)
        blk["attn"] = L.gqa_params_shape(cfg)
        blk["attn_mlp"] = L.swiglu_params_shape(cfg)
        blk["attn_ln1"] = _norm_shape(cfg)
        blk["attn_ln2"] = _norm_shape(cfg)
        return blk
    if f == "encdec":
        return {"attn": L.gqa_params_shape(cfg), "cross": L.cross_params_shape(cfg),
                "mlp": L.gelu_mlp_params_shape(cfg),
                "ln1": _norm_shape(cfg), "ln2": _norm_shape(cfg),
                "ln3": _norm_shape(cfg)}
    raise ValueError(f)


def _enc_layer_shapes(cfg):
    return {"attn": L.gqa_params_shape(cfg), "mlp": L.gelu_mlp_params_shape(cfg),
            "ln1": _norm_shape(cfg), "ln2": _norm_shape(cfg)}


def n_scan_layers(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // 3          # super-blocks
    return cfg.n_layers


def hybrid_tail_layers(cfg: ArchConfig) -> int:
    return cfg.n_layers - 3 * (cfg.n_layers // 3) if cfg.family == "hybrid" else 0


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, 8)
    v, d = cfg.vocab_padded, cfg.d_model
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (v, d)) * 0.02).astype(dtype),
        "final_norm": _init_dict(keys[1], _norm_shape(cfg), None, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(keys[2], (d, v)) *
                          (1.0 / np.sqrt(d))).astype(dtype)
    nl = n_scan_layers(cfg)
    shapes = _layer_shapes(cfg)
    params["layers"] = {name: _init_dict(k_, shp, nl, dtype)
                        for (name, shp), k_ in
                        zip(sorted(shapes.items()),
                            jax.random.split(keys[3], len(shapes)))}
    if cfg.family == "hybrid" and hybrid_tail_layers(cfg):
        tail_shapes = {"rg0": L.rglru_params_shape(cfg),
                       "rg0_mlp": L.swiglu_params_shape(cfg),
                       "rg0_ln1": _norm_shape(cfg), "rg0_ln2": _norm_shape(cfg)}
        params["tail"] = {name: _init_dict(k_, shp, hybrid_tail_layers(cfg), dtype)
                          for (name, shp), k_ in
                          zip(sorted(tail_shapes.items()),
                              jax.random.split(keys[4], len(tail_shapes)))}
    if cfg.family == "encdec":
        eshapes = _enc_layer_shapes(cfg)
        params["enc_layers"] = {name: _init_dict(k_, shp, cfg.enc_layers, dtype)
                                for (name, shp), k_ in
                                zip(sorted(eshapes.items()),
                                    jax.random.split(keys[5], len(eshapes)))}
        params["enc_final_norm"] = _init_dict(keys[6], _norm_shape(cfg), None, dtype)
    return params


def param_specs(cfg: ArchConfig, dtype=jnp.float32):
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype))


# --------------------------------------------------------------------------
# forward (train / prefill): full-sequence
# --------------------------------------------------------------------------


def _take(i, tree):
    return jax.tree.map(lambda a: a[i], tree)


def _scoped_lt(layer_transform, scope: str):
    """``layer_transform`` may be one callable (applied to every scanned
    subtree) or a ``{"layers"|"tail"|"enc_layers": fn}`` dict so callers can
    route each stacked subtree differently (paths like ``rg0/...`` exist in
    both the hybrid decoder and its tail)."""
    if layer_transform is None:
        return None
    if isinstance(layer_transform, dict):
        return layer_transform.get(scope)
    return layer_transform


def _block_full(cfg: ArchConfig, lp, x, positions, wt, chunk):
    f, nk = cfg.family, cfg.norm
    if f in ("dense", "vlm"):
        x = x + gqa_or_mla(cfg, lp["attn"], L.apply_norm(x, lp["ln1"], nk),
                           positions, wt, chunk)
        x = x + L.swiglu(lp["mlp"], L.apply_norm(x, lp["ln2"], nk), wt)
    elif f == "moe":
        x = x + gqa_or_mla(cfg, lp["attn"], L.apply_norm(x, lp["ln1"], nk),
                           positions, wt, chunk)
        x = x + L.moe(lp["moe"], L.apply_norm(x, lp["ln2"], nk), cfg, wt)
    elif f == "ssm":
        x = x + L.mamba2_block(lp["mixer"], L.apply_norm(x, lp["ln1"], nk), cfg, wt)
    elif f == "hybrid":
        for i in range(2):
            x = x + L.rglru_block(lp[f"rg{i}"],
                                  L.apply_norm(x, lp[f"rg{i}_ln1"], nk), cfg, wt)
            x = x + L.swiglu(lp[f"rg{i}_mlp"],
                             L.apply_norm(x, lp[f"rg{i}_ln2"], nk), wt)
        x = x + L.gqa_attention(lp["attn"], L.apply_norm(x, lp["attn_ln1"], nk),
                                cfg, positions=positions, wt=wt,
                                window=cfg.attn_window,
                                chunk=min(chunk, cfg.attn_window or chunk))
        x = x + L.swiglu(lp["attn_mlp"], L.apply_norm(x, lp["attn_ln2"], nk), wt)
    else:
        raise ValueError(f)
    return x


def gqa_or_mla(cfg, p, x, positions, wt, chunk):
    if cfg.use_mla:
        return L.mla_attention(p, x, cfg, positions=positions, wt=wt, chunk=chunk)
    return L.gqa_attention(p, x, cfg, positions=positions, wt=wt, chunk=chunk)


def forward(cfg: ArchConfig, params, tokens, *, prefix_embeds=None,
            enc_embeds=None, wt=Identity, dtype=jnp.bfloat16,
            chunk: int = 2048, layer_transform=None, collect_flags=False,
            collect_acts=False):
    """tokens: (B, S) int32 -> logits (B, S', V). For vlm, prefix_embeds
    (B, P, D) is prepended; for encdec, enc_embeds (B, Se, D) feeds the
    encoder (frontends are stubs per the assignment). layer_transform maps
    each layer's param slice inside the scan (e.g. lazy ECC decode).

    collect_flags=True drains the layers-module fault-flags sink once per
    scanned layer and returns ``(logits, flags)`` where flags maps each
    scanned subtree ("layers", "tail", "enc_layers") to a (n, 2) int32
    array of per-layer (corrected, due) counts.

    collect_acts=True drains the activation-stats sink the same way and
    returns ``(logits, acts)`` (or ``(logits, flags, acts)`` with both)
    where acts maps each scanned subtree to a {leaf path: (n,) f32 absmax}
    dict — the int8 calibration pass reduces these to static a_scale
    values."""
    flags: dict = {}
    acts: dict = {}
    collect_abft = collect_flags and L.abft_sink() is not None
    x = L.embed(tokens, params["embed"], dtype)
    if cfg.family == "vlm" and prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    if cfg.family in ("vlm", "hybrid"):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)  # gemma convention
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def drain():
        return (L.drain_flags() if collect_flags else None,
                L.drain_acts() if collect_acts else None,
                L.drain_abft() if collect_abft else None)

    enc_out = None
    if cfg.family == "encdec":
        enc_out, enc_flags, enc_acts, enc_abft = _encode(
            cfg, params, enc_embeds, wt=wt, dtype=dtype,
            layer_transform=layer_transform, collect_flags=collect_flags,
            collect_acts=collect_acts, collect_abft=collect_abft)
        if collect_flags:
            flags["enc_layers"] = enc_flags
            if collect_abft:
                flags["enc_layers_abft"] = enc_abft
        if collect_acts:
            acts["enc_layers"] = enc_acts

    lt_layers = _scoped_lt(layer_transform, "layers")
    lt_tail = _scoped_lt(layer_transform, "tail")

    def blk(carry, lp):
        x = carry
        if lt_layers is not None:
            lp = lt_layers(lp)
        x = _constrain_residual(x)
        if cfg.family == "encdec":
            x = _decoder_block(cfg, lp, x, positions, enc_out, wt, chunk)
        else:
            x = _block_full(cfg, lp, x, positions, wt, chunk)
        return x, drain()

    blk_fn = jax.checkpoint(blk) if cfg.remat else blk
    x, (layer_flags, layer_acts, layer_abft) = jax.lax.scan(
        blk_fn, x, params["layers"])
    if collect_flags:
        flags["layers"] = layer_flags
        if collect_abft:
            flags["layers_abft"] = layer_abft
    if collect_acts:
        acts["layers"] = layer_acts

    if cfg.family == "hybrid" and "tail" in params:
        def tail_blk(carry, lp):
            x = carry
            if lt_tail is not None:
                lp = lt_tail(lp)
            x = x + L.rglru_block(lp["rg0"], L.apply_norm(x, lp["rg0_ln1"],
                                                          cfg.norm), cfg, wt)
            x = x + L.swiglu(lp["rg0_mlp"], L.apply_norm(x, lp["rg0_ln2"],
                                                         cfg.norm), wt)
            return x, drain()
        x, (tail_flags, tail_acts, tail_abft) = jax.lax.scan(
            jax.checkpoint(tail_blk) if cfg.remat else tail_blk,
            x, params["tail"])
        if collect_flags:
            flags["tail"] = tail_flags
            if collect_abft:
                flags["tail_abft"] = tail_abft
        if collect_acts:
            acts["tail"] = tail_acts

    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    out = L.logits(x, head, wt)
    if collect_flags and collect_acts:
        return out, flags, acts
    if collect_flags:
        return out, flags
    if collect_acts:
        return out, acts
    return out


def _decoder_block(cfg, lp, x, positions, enc_out, wt, chunk):
    nk = cfg.norm
    x = x + L.gqa_attention(lp["attn"], L.apply_norm(x, lp["ln1"], nk), cfg,
                            positions=positions, wt=wt, chunk=chunk)
    kv = L.cross_kv(lp["cross"], enc_out, cfg, wt)
    x = x + L.cross_attention(lp["cross"], L.apply_norm(x, lp["ln2"], nk),
                              kv, cfg, wt)
    x = x + L.gelu_mlp(lp["mlp"], L.apply_norm(x, lp["ln3"], nk), wt)
    return x


def _encode(cfg, params, enc_embeds, *, wt, dtype, layer_transform=None,
            collect_flags=False, collect_acts=False, collect_abft=False):
    x = enc_embeds.astype(dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    lt_enc = _scoped_lt(layer_transform, "enc_layers")

    def blk(carry, lp):
        x = carry
        if lt_enc is not None:
            lp = lt_enc(lp)
        x = x + L.gqa_attention(lp["attn"], L.apply_norm(x, lp["ln1"], cfg.norm),
                                cfg, positions=positions, wt=wt, causal=False)
        x = x + L.gelu_mlp(lp["mlp"], L.apply_norm(x, lp["ln2"], cfg.norm), wt)
        return x, (L.drain_flags() if collect_flags else None,
                   L.drain_acts() if collect_acts else None,
                   L.drain_abft() if collect_abft else None)

    blk_fn = jax.checkpoint(blk) if cfg.remat else blk
    x, (enc_flags, enc_acts, enc_abft) = jax.lax.scan(blk_fn, x,
                                                      params["enc_layers"])
    return (L.apply_norm(x, params["enc_final_norm"], cfg.norm), enc_flags,
            enc_acts, enc_abft)


def loss_fn(cfg: ArchConfig, params, batch, *, wt=Identity,
            dtype=jnp.bfloat16, chunk: int = 2048):
    """Causal-LM cross entropy. batch: {"tokens", "targets", [extras]}."""
    logits = forward(cfg, params, batch["tokens"],
                     prefix_embeds=batch.get("prefix_embeds"),
                     enc_embeds=batch.get("enc_embeds"),
                     wt=wt, dtype=dtype, chunk=chunk)
    targets = batch["targets"]
    if cfg.family == "vlm":  # loss only over the text positions
        logits = logits[:, -targets.shape[1]:]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    tgt_logit = jnp.take_along_axis(
        logits.astype(jnp.float32), targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - tgt_logit)


# --------------------------------------------------------------------------
# decode (serving): KV caches per family
# --------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    nl = n_scan_layers(cfg)
    f = cfg.family

    def z(*shp, dt=dtype):
        return jnp.zeros(shp, dt)

    if f in ("dense", "vlm"):
        return {"k": z(nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                "v": z(nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim)}
    if f == "moe":
        if cfg.use_mla:
            return {"latent": z(nl, batch, max_len, cfg.kv_lora_rank),
                    "k_rope": z(nl, batch, max_len, cfg.qk_rope_dim)}
        return {"k": z(nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                "v": z(nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim)}
    if f == "ssm":
        return {"state": z(nl, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                           cfg.ssm_state),
                "conv": z(nl, batch, cfg.ssm_conv_width - 1,
                          cfg.d_inner + 2 * cfg.ssm_state)}
    if f == "hybrid":
        w = cfg.lru_width or cfg.d_model
        win = cfg.attn_window
        cache = {}
        for i in range(2):
            cache[f"rg{i}_h"] = z(nl, batch, w)
            cache[f"rg{i}_conv"] = z(nl, batch, (cfg.ssm_conv_width or 4) - 1, w)
        cache["k"] = z(nl, batch, win, cfg.n_kv_heads, cfg.head_dim)
        cache["v"] = z(nl, batch, win, cfg.n_kv_heads, cfg.head_dim)
        if hybrid_tail_layers(cfg):
            t = hybrid_tail_layers(cfg)
            cache["tail_h"] = z(t, batch, w)
            cache["tail_conv"] = z(t, batch, (cfg.ssm_conv_width or 4) - 1, w)
        return cache
    if f == "encdec":
        return {"k": z(nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                "v": z(nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                "cross_k": z(nl, batch, cfg.enc_seq, cfg.n_heads, cfg.head_dim),
                "cross_v": z(nl, batch, cfg.enc_seq, cfg.n_heads, cfg.head_dim)}
    raise ValueError(f)


def _paged_attn_decode(lp, h, cfg, lc, pos, wt, kv_policy):
    from repro.serving import kvcache  # deferred: serving builds on lm
    if kv_policy is None:
        raise ValueError("cache is paged (k_pages present) but no kv_policy "
                         "was passed to decode_step")
    return kvcache.paged_gqa_decode(lp["attn"], h, cfg, lc, pos=pos, wt=wt,
                                    policy=kvcache.get_kv_policy(kv_policy))


def decode_step(cfg: ArchConfig, params, cache, tokens, pos, *,
                wt=Identity, dtype=jnp.bfloat16, layer_transform=None,
                collect_flags=False, kv_policy=None):
    """One decode step. tokens: (B,1) int32; pos: (B,) int32.
    Returns (logits (B,1,V), new_cache); with collect_flags=True,
    (logits, new_cache, flags) where flags maps "layers" (and "tail") to
    (n, 2) int32 per-layer (corrected, due) fault counts drained from the
    layers-module flags sink.

    When ``cache`` is a paged protected KV cache
    (``serving.kvcache.init_paged_cache``; marked by its "k_pages" pools),
    attention routes through the decode-at-use paged path under
    ``kv_policy`` and collect_flags additionally returns a "layers_kv" row
    of per-layer KV (corrected, due) counts.

    When an ABFT sink is installed (``layers.set_abft_sink`` — the serve
    step does this for ABFT/clamp-enabled plans), collect_flags also
    returns a "layers_abft" row of per-layer (checksum mismatches,
    clamp hits) counts, drained per scanned layer exactly like the
    memory-fault channels."""
    flags: dict = {}
    x = L.embed(tokens, params["embed"], dtype)
    if cfg.family in ("vlm", "hybrid"):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    f = cfg.family
    kv_paged = "k_pages" in cache

    lt_layers = _scoped_lt(layer_transform, "layers")
    lt_tail = _scoped_lt(layer_transform, "tail")

    def blk(x, lp_cache):
        lp, lc = lp_cache
        if lt_layers is not None:
            lp = lt_layers(lp)
        if f in ("dense", "vlm", "encdec"):
            h = L.apply_norm(x, lp["ln1"], cfg.norm)
            if kv_paged:
                o, newkv = _paged_attn_decode(lp, h, cfg, lc, pos, wt,
                                              kv_policy)
            else:
                o, newkv = L.gqa_decode(lp["attn"], h, cfg,
                                        {"k": lc["k"], "v": lc["v"]},
                                        pos=pos, wt=wt)
            x = x + o
            nc = dict(newkv)
            if f == "encdec":
                h = L.apply_norm(x, lp["ln2"], cfg.norm)
                x = x + L.cross_attention(lp["cross"], h,
                                          (lc["cross_k"], lc["cross_v"]), cfg, wt)
                x = x + L.gelu_mlp(lp["mlp"],
                                   L.apply_norm(x, lp["ln3"], cfg.norm), wt)
                nc.update({"cross_k": lc["cross_k"], "cross_v": lc["cross_v"]})
            else:
                x = x + L.swiglu(lp["mlp"], L.apply_norm(x, lp["ln2"], cfg.norm),
                                 wt)
            return x, nc
        if f == "moe":
            h = L.apply_norm(x, lp["ln1"], cfg.norm)
            if cfg.use_mla:
                o, newkv = L.mla_decode(lp["attn"], h, cfg,
                                        {"latent": lc["latent"],
                                         "k_rope": lc["k_rope"]}, pos=pos, wt=wt)
            elif kv_paged:
                o, newkv = _paged_attn_decode(lp, h, cfg, lc, pos, wt,
                                              kv_policy)
            else:
                o, newkv = L.gqa_decode(lp["attn"], h, cfg,
                                        {"k": lc["k"], "v": lc["v"]},
                                        pos=pos, wt=wt)
            x = x + o
            x = x + L.moe(lp["moe"], L.apply_norm(x, lp["ln2"], cfg.norm), cfg, wt)
            return x, newkv
        if f == "ssm":
            h = L.apply_norm(x, lp["ln1"], cfg.norm)
            o, nc = L.mamba2_decode(lp["mixer"], h, cfg,
                                    {"state": lc["state"], "conv": lc["conv"]}, wt)
            return x + o, nc
        if f == "hybrid":
            nc = {}
            for i in range(2):
                h = L.apply_norm(x, lp[f"rg{i}_ln1"], cfg.norm)
                o, c2 = L.rglru_decode(lp[f"rg{i}"], h, cfg,
                                       {"h": lc[f"rg{i}_h"],
                                        "conv": lc[f"rg{i}_conv"]}, wt)
                x = x + o
                nc[f"rg{i}_h"], nc[f"rg{i}_conv"] = c2["h"], c2["conv"]
                x = x + L.swiglu(lp[f"rg{i}_mlp"],
                                 L.apply_norm(x, lp[f"rg{i}_ln2"], cfg.norm), wt)
            h = L.apply_norm(x, lp["attn_ln1"], cfg.norm)
            o, kv = L.gqa_decode(lp["attn"], h, cfg, {"k": lc["k"], "v": lc["v"]},
                                 pos=pos, wt=wt, window=cfg.attn_window)
            x = x + o
            nc.update(kv)
            x = x + L.swiglu(lp["attn_mlp"],
                             L.apply_norm(x, lp["attn_ln2"], cfg.norm), wt)
            return x, nc
        raise ValueError(f)

    layer_cache = {k_: v for k_, v in cache.items() if not k_.startswith("tail")}
    collect_kv = collect_flags and kv_paged
    collect_abft = collect_flags and L.abft_sink() is not None

    def scan_blk(x, lp_lc):
        x, nc = blk(x, lp_lc)
        return x, (nc, L.drain_flags() if collect_flags else None,
                   L.drain_kv_flags() if collect_kv else None,
                   L.drain_abft() if collect_abft else None)

    prev_kv_sink = L.kv_flags_sink()
    if collect_kv:
        L.set_kv_flags_sink([])
    try:
        x, (new_cache, layer_flags, layer_kv_flags, layer_abft) = jax.lax.scan(
            scan_blk, x, (params["layers"], layer_cache))
    finally:
        if collect_kv:
            L.set_kv_flags_sink(prev_kv_sink)
    if collect_flags:
        flags["layers"] = layer_flags
        if collect_kv:
            flags["layers_kv"] = layer_kv_flags
        if collect_abft:
            flags["layers_abft"] = layer_abft

    out_cache = dict(new_cache)
    if f == "hybrid" and "tail" in params:
        def tail_blk(x, lp_lc):
            lp, lc = lp_lc
            if lt_tail is not None:
                lp = lt_tail(lp)
            h = L.apply_norm(x, lp["rg0_ln1"], cfg.norm)
            o, c2 = L.rglru_decode(lp["rg0"], h, cfg,
                                   {"h": lc["tail_h"], "conv": lc["tail_conv"]},
                                   wt)
            x = x + o
            x = x + L.swiglu(lp["rg0_mlp"],
                             L.apply_norm(x, lp["rg0_ln2"], cfg.norm), wt)
            return x, ({"tail_h": c2["h"], "tail_conv": c2["conv"]},
                       L.drain_flags() if collect_flags else None)
        tc = {"tail_h": cache["tail_h"], "tail_conv": cache["tail_conv"]}
        x, (new_tail, tail_flags) = jax.lax.scan(tail_blk, x,
                                                 (params["tail"], tc))
        out_cache.update(new_tail)
        if collect_flags:
            flags["tail"] = tail_flags

    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = L.logits(x, head, wt)
    return (logits, out_cache, flags) if collect_flags else (logits, out_cache)


def prefill_with_cache(cfg: ArchConfig, params, cache, tokens, *, wt=Identity,
                       dtype=jnp.bfloat16, chunk: int = 2048,
                       layer_transform=None, collect_flags=False,
                       kv_policy=None):
    """Full-sequence prefill that also fills a paged protected KV cache.

    tokens: (B, S) int32; ``cache`` from ``serving.kvcache.init_paged_cache``
    (S <= page capacity). Unlike ``forward``, every layer's K/V stream is
    encoded into its pages and the attention runs over the decoded-at-use
    pages, so the logits reflect exactly the state subsequent
    ``decode_step`` calls will read. Returns (logits (B, S, V), new_cache);
    with collect_flags=True additionally a flags dict with "layers" (weight)
    and "layers_kv" (KV) per-layer (corrected, due) rows."""
    from repro.serving import kvcache  # deferred: serving builds on lm
    if "k_pages" not in cache:
        raise ValueError("prefill_with_cache expects a paged cache "
                         "(serving.kvcache.init_paged_cache)")
    policy = kvcache.get_kv_policy(kv_policy)
    if policy is None:
        raise ValueError("kv_policy is required for a paged cache")
    if not kvcache.supports_paged(cfg):
        raise ValueError(f"paged prefill unsupported for family "
                         f"{cfg.family!r}")
    f = cfg.family
    flags: dict = {}
    x = L.embed(tokens, params["embed"], dtype)
    if f in ("vlm", "hybrid"):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    lt_layers = _scoped_lt(layer_transform, "layers")

    def blk(x, lp_lc):
        lp, lc = lp_lc
        if lt_layers is not None:
            lp = lt_layers(lp)
        x = _constrain_residual(x)
        h = L.apply_norm(x, lp["ln1"], cfg.norm)
        o, newkv = kvcache.paged_gqa_prefill(lp["attn"], h, cfg, lc,
                                             positions=positions, wt=wt,
                                             policy=policy, chunk=chunk)
        x = x + o
        h2 = L.apply_norm(x, lp["ln2"], cfg.norm)
        if f == "moe":
            x = x + L.moe(lp["moe"], h2, cfg, wt)
        else:
            x = x + L.swiglu(lp["mlp"], h2, wt)
        return x, (newkv, L.drain_flags() if collect_flags else None,
                   L.drain_kv_flags() if collect_flags else None,
                   L.drain_abft() if collect_abft else None)

    collect_abft = collect_flags and L.abft_sink() is not None
    prev_kv_sink = L.kv_flags_sink()
    if collect_flags:
        L.set_kv_flags_sink([])
    try:
        x, (new_cache, layer_flags, layer_kv_flags, layer_abft) = jax.lax.scan(
            blk, x, (params["layers"], cache))
    finally:
        if collect_flags:
            L.set_kv_flags_sink(prev_kv_sink)
    if collect_flags:
        flags["layers"] = layer_flags
        flags["layers_kv"] = layer_kv_flags
        if collect_abft:
            flags["layers_abft"] = layer_abft

    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = L.logits(x, head, wt)
    return (logits, new_cache, flags) if collect_flags else (logits, new_cache)
