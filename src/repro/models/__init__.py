from . import cnn, config, layers, lm  # noqa: F401
