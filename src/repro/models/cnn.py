"""CNNs from the paper's evaluation (VGG16, ResNet18, SqueezeNet) in JAX.

Full ImageNet-scale definitions plus a ``scale``/``img_size`` reduction knob
so the WOT + fault-injection experiments run at CPU scale (the paper's claims
we validate — weight-distribution statistics, WOT convergence to the
constraint, protection ordering — are mechanism-level, not dataset-level).

Params are dicts; convs use NHWC / HWIO layouts. ``wt`` hooks QAT fake-quant.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Identity = lambda w: w


def conv(p, x, *, stride=1, padding="SAME", wt=Identity):
    y = jax.lax.conv_general_dilated(
        x, wt(p["w"]).astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"].astype(x.dtype) if "b" in p else y


def _conv_init(key, kh, kw, cin, cout, bias=True):
    w = jax.random.normal(key, (kh, kw, cin, cout)) * np.sqrt(2.0 / (kh * kw * cin))
    p = {"w": w.astype(jnp.float32)}
    if bias:
        p["b"] = jnp.zeros((cout,), jnp.float32)
    return p


def maxpool(x, k=2, s=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, s, s, 1), "VALID")


def avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))


# ---------------------------------------------------------------- VGG16 ----

_VGG16_PLAN = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
               512, 512, 512, "M", 512, 512, 512, "M"]


def init_vgg16(key, *, n_classes=1000, scale=1.0, img_size=224):
    keys = iter(jax.random.split(key, 32))
    params, cin = {"convs": []}, 3
    for item in _VGG16_PLAN:
        if item == "M":
            continue
        cout = max(8, int(item * scale))
        params["convs"].append(_conv_init(next(keys), 3, 3, cin, cout))
        cin = cout
    spatial = img_size // 32
    fc1 = max(32, int(4096 * scale))
    params["fc1"] = {"w": jax.random.normal(next(keys), (cin * spatial * spatial,
                                                         fc1)) * 0.01,
                     "b": jnp.zeros((fc1,))}
    params["fc2"] = {"w": jax.random.normal(next(keys), (fc1, fc1)) * 0.01,
                     "b": jnp.zeros((fc1,))}
    params["fc3"] = {"w": jax.random.normal(next(keys), (fc1, n_classes)) * 0.01,
                     "b": jnp.zeros((n_classes,))}
    return params


def vgg16(params, x, wt=Identity):
    ci = 0
    for item in _VGG16_PLAN:
        if item == "M":
            x = maxpool(x)
        else:
            x = jax.nn.relu(conv(params["convs"][ci], x, wt=wt))
            ci += 1
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ wt(params["fc1"]["w"]).astype(x.dtype) + params["fc1"]["b"])
    x = jax.nn.relu(x @ wt(params["fc2"]["w"]).astype(x.dtype) + params["fc2"]["b"])
    return x @ wt(params["fc3"]["w"]).astype(x.dtype) + params["fc3"]["b"]


# -------------------------------------------------------------- ResNet18 ---


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def batchnorm(p, x, training=False, eps=1e-5):
    if training:
        mu = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
    else:
        mu, var = p["mean"], p["var"]
    inv = jax.lax.rsqrt(var + eps) * p["scale"]
    return (x - mu) * inv + p["bias"]


def init_resnet18(key, *, n_classes=1000, scale=1.0, img_size=224):
    widths = [max(8, int(w * scale)) for w in (64, 128, 256, 512)]
    keys = iter(jax.random.split(key, 64))
    p = {"stem": _conv_init(next(keys), 7, 7, 3, widths[0], bias=False),
         "stem_bn": _bn_init(widths[0]), "stages": []}
    cin = widths[0]
    for si, w in enumerate(widths):
        stage = []
        for bi in range(2):
            stride = 2 if (si > 0 and bi == 0) else 1
            blk = {"c1": _conv_init(next(keys), 3, 3, cin, w, bias=False),
                   "bn1": _bn_init(w),
                   "c2": _conv_init(next(keys), 3, 3, w, w, bias=False),
                   "bn2": _bn_init(w)}
            if stride != 1 or cin != w:
                blk["proj"] = _conv_init(next(keys), 1, 1, cin, w, bias=False)
                blk["proj_bn"] = _bn_init(w)
            stage.append(blk)
            cin = w
        p["stages"].append(stage)
    p["fc"] = {"w": jax.random.normal(next(keys), (cin, n_classes)) * 0.01,
               "b": jnp.zeros((n_classes,))}
    return p


def resnet18(p, x, wt=Identity, training=False):
    x = jax.nn.relu(batchnorm(p["stem_bn"], conv(p["stem"], x, stride=2, wt=wt),
                              training))
    x = maxpool(x, 3, 2)
    for si, stage in enumerate(p["stages"]):
        for bi, blk in enumerate(stage):
            stride = 2 if (si > 0 and bi == 0) else 1
            idn = x
            y = jax.nn.relu(batchnorm(blk["bn1"],
                                      conv(blk["c1"], x, stride=stride,
                                           wt=wt), training))
            y = batchnorm(blk["bn2"], conv(blk["c2"], y, wt=wt), training)
            if "proj" in blk:
                idn = batchnorm(blk["proj_bn"],
                                conv(blk["proj"], x, stride=stride, wt=wt),
                                training)
            x = jax.nn.relu(y + idn)
    x = avgpool_global(x)
    return x @ wt(p["fc"]["w"]).astype(x.dtype) + p["fc"]["b"]


# ------------------------------------------------------------ SqueezeNet ---


def _fire_init(key, cin, squeeze, expand):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"squeeze": _conv_init(k1, 1, 1, cin, squeeze),
            "e1": _conv_init(k2, 1, 1, squeeze, expand),
            "e3": _conv_init(k3, 3, 3, squeeze, expand)}


def fire(p, x, wt=Identity):
    s = jax.nn.relu(conv(p["squeeze"], x, wt=wt))
    return jnp.concatenate([jax.nn.relu(conv(p["e1"], s, wt=wt)),
                            jax.nn.relu(conv(p["e3"], s, wt=wt))], axis=-1)


_FIRE_PLAN = [(16, 64), (16, 64), (32, 128), "M", (32, 128), (48, 192),
              (48, 192), (64, 256), "M", (64, 256)]


def init_squeezenet(key, *, n_classes=1000, scale=1.0, img_size=224):
    keys = iter(jax.random.split(key, 16))
    sc = lambda c: max(4, int(c * scale))
    p = {"stem": _conv_init(next(keys), 3, 3, 3, sc(64)), "fires": []}
    cin = sc(64)
    for item in _FIRE_PLAN:
        if item == "M":
            continue
        sq, ex = item
        p["fires"].append(_fire_init(next(keys), cin, sc(sq), sc(ex)))
        cin = 2 * sc(ex)
    p["head"] = _conv_init(next(keys), 1, 1, cin, n_classes)
    return p


def squeezenet(p, x, wt=Identity):
    x = jax.nn.relu(conv(p["stem"], x, stride=2, wt=wt))
    x = maxpool(x, 3, 2)
    fi = 0
    for item in _FIRE_PLAN:
        if item == "M":
            x = maxpool(x, 3, 2)
        else:
            x = fire(p["fires"][fi], x, wt=wt)
            fi += 1
    x = conv(p["head"], x, wt=wt)
    return avgpool_global(x)


CNNS: dict[str, tuple[Callable, Callable]] = {
    "vgg16": (init_vgg16, vgg16),
    "resnet18": (init_resnet18, resnet18),
    "squeezenet": (init_squeezenet, squeezenet),
}
