"""Model building blocks (pure functions over param dicts).

Conventions:
* activations bf16 (configurable), params fp32 masters during training.
* ``wt`` is a weight-transform hook: QAT fake-quant during training
  (``core.quant.fake_quant``), identity for plain eval, or the int8
  decode+dequant path for protected serving.
* all attention is chunked (online-softmax over KV chunks) so 32k prefill
  fits HBM; decode paths take explicit KV caches.
* every block is shape-polymorphic over batch; layers carry no state.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

Identity = lambda w: w

# --------------------------------------------------------------------------
# sharding context: set by the launcher/dry-run; None => no constraints
# (plain CPU smoke tests). Layers use it to pin internals XLA would
# otherwise replicate (MoE dispatch buffers, residual stream).
# --------------------------------------------------------------------------

SHARDING_CTX: dict | None = None


def set_sharding_ctx(ctx: dict | None):
    global SHARDING_CTX
    SHARDING_CTX = ctx


def constrain(x, *spec):
    if SHARDING_CTX is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def ctx_dp():
    return SHARDING_CTX.get("dp") if SHARDING_CTX else None


# --------------------------------------------------------------------------
# fault-flags sink: decode-at-use serving sets a sink (a plain list collected
# at trace time); every protected-weight decode/fused-matmul records its
# (corrected, due) counts, and lm.forward/decode_step drain per layer so the
# scan emits per-layer fault accounting. None => recording is a no-op.
# --------------------------------------------------------------------------

_FLAGS_SINK: list | None = None


def set_flags_sink(sink: list | None):
    global _FLAGS_SINK
    _FLAGS_SINK = sink


def record_flags(corrected, due):
    if _FLAGS_SINK is not None:
        _FLAGS_SINK.append((corrected, due))


def drain_flags():
    """Sum and clear the recorded (corrected, due) pairs -> (2,) int32."""
    total = jnp.zeros((2,), jnp.int32)
    if _FLAGS_SINK:
        total = sum((jnp.stack([jnp.asarray(c, jnp.int32).reshape(()),
                                jnp.asarray(d, jnp.int32).reshape(())])
                     for c, d in _FLAGS_SINK), total)
        _FLAGS_SINK.clear()
    return total


# --------------------------------------------------------------------------
# KV fault-flags sink: the paged protected KV cache records the (corrected,
# due) counts each layer's decode-at-use attention observed over its valid
# cached tokens — kept separate from the weight sink so per-layer rows
# report weight and state faults side by side. Same trace-time contract.
# --------------------------------------------------------------------------

_KV_FLAGS_SINK: list | None = None


def set_kv_flags_sink(sink: list | None):
    global _KV_FLAGS_SINK
    _KV_FLAGS_SINK = sink


def kv_flags_sink() -> list | None:
    return _KV_FLAGS_SINK


def record_kv_flags(corrected, due):
    if _KV_FLAGS_SINK is not None:
        _KV_FLAGS_SINK.append((corrected, due))


def drain_kv_flags():
    """Sum and clear the recorded KV (corrected, due) pairs.

    Entries are scalars by default -> (2,) int32. When the KV policy asks
    for per-slot attribution (``KVProtectionPolicy.per_slot_flags``) each
    entry is a (B,) row instead and the result is (2, B) int32 — the shape
    flows through the layer scan unchanged, so ``flags["layers_kv"]``
    becomes (n_layers, 2, B).
    """
    if _KV_FLAGS_SINK:
        pairs = [jnp.stack([jnp.asarray(c, jnp.int32),
                            jnp.asarray(d, jnp.int32)])
                 for c, d in _KV_FLAGS_SINK]
        _KV_FLAGS_SINK.clear()
        return sum(pairs[1:], pairs[0])
    return jnp.zeros((2,), jnp.int32)


# --------------------------------------------------------------------------
# ABFT sink: when a plan turns on compute-fault detection
# (``plan.with_abft``) every guarded matmul records its (checksum
# mismatches, activation-clamp hits) pair here — kept separate from the
# (corrected, due) memory-fault sinks because it witnesses a different
# fault domain (MXU/SDC compute faults and out-of-range activations, not
# stored bytes). Same trace-time contract as the KV sink, including the
# per-slot variant: entries are scalars -> (2,), or (B,) rows -> (2, B).
# --------------------------------------------------------------------------

_ABFT_SINK: list | None = None


def set_abft_sink(sink: list | None):
    global _ABFT_SINK
    _ABFT_SINK = sink


def abft_sink() -> list | None:
    return _ABFT_SINK


def record_abft(mismatches, clamp_hits):
    if _ABFT_SINK is not None:
        _ABFT_SINK.append((mismatches, clamp_hits))


def drain_abft():
    """Sum and clear the recorded (mismatches, clamp-hits) pairs.

    Scalar entries -> (2,) int32; per-slot (B,) rows -> (2, B) int32 (the
    shape flows through the layer scan, so ``flags["layers_abft"]`` becomes
    (n_layers, 2, B) under per-slot attribution).
    """
    if _ABFT_SINK:
        pairs = [jnp.stack([jnp.asarray(m, jnp.int32),
                            jnp.asarray(h, jnp.int32)])
                 for m, h in _ABFT_SINK]
        _ABFT_SINK.clear()
        return sum(pairs[1:], pairs[0])
    return jnp.zeros((2,), jnp.int32)


# --------------------------------------------------------------------------
# activation-stats sink: the int8 calibration pass sets a dict sink; every
# decode-at-use matmul records its float activation absmax keyed by the
# leaf's plan path, and lm.forward drains per scanned layer so the scan
# emits per-layer maxima (reduced to per-leaf static a_scale values by
# serving.protected.calibrate_act_scales). None => recording is a no-op.
# --------------------------------------------------------------------------

_ACT_SINK: dict | None = None


def set_act_sink(sink: dict | None):
    global _ACT_SINK
    _ACT_SINK = sink


def record_act(key: str, absmax):
    if _ACT_SINK is not None:
        prev = _ACT_SINK.get(key)
        _ACT_SINK[key] = absmax if prev is None else jnp.maximum(prev, absmax)


def drain_acts() -> dict:
    """Clear and return the recorded {leaf path: absmax f32} map."""
    out = dict(_ACT_SINK) if _ACT_SINK else {}
    if _ACT_SINK:
        _ACT_SINK.clear()
    return out


def constrain_heads(t):
    """(B, H, S, D) attention tensor -> shard heads over 'model' when the
    head count divides the axis. Keeps softmax/scores fully local per shard
    instead of replicating O(S^2) score buffers. DISABLED when sequence
    parallelism is active: S already owns the 'model' axis there, and the
    conflicting constraints force XLA into full rematerialization
    (measured: v3 train collective 48TB -> 160TB with both on)."""
    if SHARDING_CTX is None or SHARDING_CTX.get("sp"):
        return t
    msize = SHARDING_CTX.get("model_size", 1)
    if t.shape[1] % msize == 0:
        return constrain(t, ctx_dp(), "model", None, None)
    return t


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w.astype(x.dtype)


def layer_norm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w + b).astype(x.dtype)


def apply_norm(x, p, kind):
    return rms_norm(x, p["w"]) if kind == "rms" else layer_norm(x, p["w"], p["b"])


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), dtype=jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :].astype(x.dtype)  # broadcast over heads
    sin = sin[..., None, :].astype(x.dtype)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# --------------------------------------------------------------------------
# chunked (flash-style) attention
# --------------------------------------------------------------------------


def _attend_chunk(q, k, v, mask, scale):
    """q (B,H,Sq,D) k/v (B,H,Sk,D[v]) mask (Sq,Sk) or None -> (o, m, l)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1)                       # (B,H,Sq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                       # (B,H,Sq)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
    return o, m, l


def chunked_causal_attention(q, k, v, *, chunk: int = 2048,
                             window: int = 0) -> jnp.ndarray:
    """Online-softmax causal attention.

    q,k,v: (B, H, S, D) (k/v already GQA-broadcast). window > 0 restricts to a
    sliding local window (must equal `chunk` for the fast path used here).
    Returns (B, H, S, Dv).
    """
    b, h, s, d = q.shape
    dv = v.shape[-1]
    scale = 1.0 / np.sqrt(d)
    if window:
        if window >= s:
            window = 0      # window covers everything -> plain causal
        else:
            chunk = window  # fast path: one previous chunk == the window
    chunk = min(chunk, s)
    if s % chunk:  # zero-pad tail; padded keys are causally invisible to real
        pad = chunk - s % chunk  # queries, padded query rows are sliced off
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        out = chunked_causal_attention(qp, kp, vp, chunk=chunk, window=window)
        return out[:, :, :s]
    nq = s // chunk
    if window:
        assert window == chunk, "fast path assumes window == chunk"

    qc = q.reshape(b, h, nq, chunk, d)
    kc = k.reshape(b, h, nq, chunk, d)
    vc = v.reshape(b, h, nq, chunk, dv)
    idx = jnp.arange(chunk)
    # mask within the diagonal chunk / against the previous chunk
    diag_mask = idx[:, None] >= idx[None, :]
    prev_mask = (idx[:, None] + chunk) >= (idx[None, :] + 1) if not window else \
        (idx[:, None] < idx[None, :])  # window: only strictly-newer prev keys

    def q_block(i, qi):
        """attend query chunk i over kv chunks 0..i (or i-1..i if windowed)."""
        oi, mi, li = _attend_chunk(qi, kc[:, :, i], vc[:, :, i], diag_mask, scale)

        def merge(carry, j):
            o, m, l = carry
            kj = jax.lax.dynamic_index_in_dim(kc, j, axis=2, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vc, j, axis=2, keepdims=False)
            valid = j >= 0
            if window:
                mask = prev_mask
            else:
                mask = None
            o2, m2, l2 = _attend_chunk(qi, kj, vj, mask, scale)
            m2 = jnp.where(valid, m2, -jnp.inf)
            mnew = jnp.maximum(m, m2)
            a1 = jnp.exp(m - mnew)
            a2 = jnp.where(valid, jnp.exp(m2 - mnew), 0.0)
            o = o * a1[..., None].astype(o.dtype) + \
                jnp.where(valid, o2 * a2[..., None].astype(o.dtype), 0)
            l = l * a1 + l2 * a2
            return (o, mnew, l), None

        if window:
            (oi, mi, li), _ = merge((oi, mi, li), i - 1)
        else:
            js = jnp.arange(nq)  # j < i valid; others masked by `valid`
            (oi, mi, li), _ = jax.lax.scan(
                lambda c, j: merge(c, jnp.where(j < i, j, -1)), (oi, mi, li), js)
        return oi / jnp.maximum(li, 1e-30)[..., None].astype(oi.dtype)

    sp_active = SHARDING_CTX is not None and SHARDING_CTX.get("sp")
    if nq == 1:
        out = q_block(0, qc[:, :, 0])[:, :, None]
    elif not window and nq <= 64 and not sp_active:
        # TRIANGLE-UNROLLED path: q chunk i touches only kv chunks 0..i, so
        # the masked upper half of the S^2 score matrix is never computed
        # (~47% attention flops+bytes saved vs the scan-all-chunks path).
        # Disabled under sequence parallelism: per-chunk S slices would land
        # on single shards and force replication (measured 10x regression).
        def merge_nomask(carry, j):
            # qi travels in the carry: jax.lax.scan caches traced bodies by
            # (function id, avals), so a closure over the loop's qi would
            # bake iteration 0's query chunk into every later scan. KV chunks
            # are dynamically indexed from the full buffers — slicing a
            # per-i prefix copy would materialize O(nq^2) chunk copies.
            o, m, l, qi = carry
            kj = jax.lax.dynamic_index_in_dim(kc, j, axis=2, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vc, j, axis=2, keepdims=False)
            o2, m2, l2 = _attend_chunk(qi, kj, vj, None, scale)
            mnew = jnp.maximum(m, m2)
            a1, a2 = jnp.exp(m - mnew), jnp.exp(m2 - mnew)
            o = o * a1[..., None].astype(o.dtype) + \
                o2 * a2[..., None].astype(o.dtype)
            return (o, mnew, l * a1 + l2 * a2, qi), None

        outs = []
        for i in range(nq):
            qi = qc[:, :, i]
            oi, mi, li = _attend_chunk(qi, kc[:, :, i], vc[:, :, i],
                                       diag_mask, scale)
            if i > 0:  # static trip count i: only the causal triangle runs
                (oi, mi, li, _), _ = jax.lax.scan(
                    merge_nomask, (oi, mi, li, qi), jnp.arange(i))
            outs.append(oi / jnp.maximum(li, 1e-30)[..., None].astype(oi.dtype))
        out = jnp.stack(outs, axis=2)
    else:
        out = jax.vmap(q_block, in_axes=(0, 2), out_axes=2)(jnp.arange(nq), qc)
    return out.reshape(b, h, s, dv)


def decode_attention(q, k_cache, v_cache, length_mask=None):
    """q: (B,H,1,D); caches: (B,H,Skv,D). Full-cache single-token attention."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k_cache).astype(jnp.float32) * scale
    if length_mask is not None:
        s = jnp.where(length_mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v_cache)


# --------------------------------------------------------------------------
# GQA attention block
# --------------------------------------------------------------------------


def gqa_params_shape(cfg):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": (d, h * hd), "wk": (d, kv * hd), "wv": (d, kv * hd),
        "wo": (h * hd, d),
    }
    if cfg.qkv_bias:
        p.update({"bq": (h * hd,), "bk": (kv * hd,), "bv": (kv * hd,)})
    return p


def _proj(x, w, b=None, wt=Identity):
    w = wt(w)
    if getattr(w, "decode_at_use", False):
        y = w.matmul(x)  # decode-at-use view: fused or per-leaf inline decode
    else:
        y = x @ w.astype(x.dtype)
    if b is not None:
        # y's dtype, not x's: int8-quantized activations produce float y
        y = y + b.astype(y.dtype)
    return y


def gqa_attention(p, x, cfg, *, positions, wt=Identity, causal=True,
                  window=0, chunk=2048):
    """Training/prefill attention over a full sequence. x: (B,S,D)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _proj(x, p["wq"], p.get("bq"), wt).reshape(b, s, h, hd)
    k = _proj(x, p["wk"], p.get("bk"), wt).reshape(b, s, kv, hd)
    v = _proj(x, p["wv"], p.get("bv"), wt).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # GQA broadcast kv -> h
    rep = h // kv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    q, k, v = (constrain_heads(t.transpose(0, 2, 1, 3)) for t in (q, k, v))
    if causal:
        o = chunked_causal_attention(q, k, v, chunk=chunk, window=window)
    else:  # bidirectional (whisper encoder)
        o, m, l = _attend_chunk(q, k, v, None, 1.0 / np.sqrt(hd))
        o = o / jnp.maximum(l, 1e-30)[..., None].astype(o.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return _proj(o, p["wo"], None, wt)


def gqa_decode(p, x, cfg, cache, *, pos, wt=Identity, window=0):
    """Single-token decode. x: (B,1,D); cache: {"k","v": (B, Smax, kv, hd)}.

    pos: (B,) current position. Returns (out, new_cache).
    """
    b, _, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _proj(x, p["wq"], p.get("bq"), wt).reshape(b, 1, h, hd)
    k = _proj(x, p["wk"], p.get("bk"), wt).reshape(b, 1, kv, hd)
    v = _proj(x, p["wv"], p.get("bv"), wt).reshape(b, 1, kv, hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    smax = cache["k"].shape[1]
    slot = (pos % smax) if window else pos  # ring buffer for windowed caches
    kc = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice_in_dim(c, kk, i, 0)
                  )(cache["k"], k, slot)
    vc = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice_in_dim(c, vv, i, 0)
                  )(cache["v"], v, slot)
    rep = h // kv
    kh = jnp.repeat(kc, rep, axis=2).transpose(0, 2, 1, 3)  # (B,H,Smax,hd)
    vh = jnp.repeat(vc, rep, axis=2).transpose(0, 2, 1, 3)
    if window:
        # ring buffer: slot j holds the newest token t <= pos with
        # t % smax == j, whose age is (pos - j) % smax. A slot is valid iff
        # that age is inside the window AND the slot was ever written
        # (age <= pos). The old "all slots valid once pos >= smax" mask
        # silently widened the window to smax whenever the cache was
        # allocated larger than the window, admitting stale tokens.
        age = (pos[:, None] - jnp.arange(smax)[None, :]) % smax
        valid = jnp.logical_and(age < min(window, smax),
                                age <= pos[:, None])
    else:
        valid = jnp.arange(smax)[None, :] <= pos[:, None]
    o = decode_attention(q.transpose(0, 2, 1, 3), kh, vh, valid)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * hd)
    return _proj(o, p["wo"], None, wt), {"k": kc, "v": vc}


# --------------------------------------------------------------------------
# cross-attention (whisper decoder)
# --------------------------------------------------------------------------


def cross_params_shape(cfg):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {"wq": (d, h * hd), "wk": (d, h * hd), "wv": (d, h * hd),
            "wo": (h * hd, d)}


def cross_kv(p, enc_out, cfg, wt=Identity):
    """Precompute cross-attention K/V from encoder output: (B,Se,H,hd) x2."""
    b, se, _ = enc_out.shape
    h, hd = cfg.n_heads, cfg.head_dim
    k = _proj(enc_out, p["wk"], None, wt).reshape(b, se, h, hd)
    v = _proj(enc_out, p["wv"], None, wt).reshape(b, se, h, hd)
    return k, v


def cross_attention(p, x, kv, cfg, wt=Identity):
    """x: (B,Sd,D); kv: (k, v) each (B,Se,H,hd). Bidirectional over encoder."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = _proj(x, p["wq"], None, wt).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k, v = (t.transpose(0, 2, 1, 3) for t in kv)
    o, _m, l = _attend_chunk(q, k, v, None, 1.0 / np.sqrt(hd))
    o = o / jnp.maximum(l, 1e-30)[..., None].astype(o.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return _proj(o, p["wo"], None, wt)


# --------------------------------------------------------------------------
# MLA attention (deepseek v2/v3) — compressed KV cache
# --------------------------------------------------------------------------


def mla_params_shape(cfg):
    d, h = cfg.d_model, cfg.n_heads
    r, qn, qr, vd = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    p = {
        "w_dkv": (d, r + qr),            # compress: kv latent + shared rope key
        "w_uk": (r, h * qn),             # latent -> per-head nope keys
        "w_uv": (r, h * vd),             # latent -> per-head values
        "wo": (h * vd, d),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = (d, cfg.q_lora_rank)
        p["w_uq"] = (cfg.q_lora_rank, h * (qn + qr))
    else:
        p["wq"] = (d, h * (qn + qr))
    return p


def _mla_q(p, x, cfg, wt):
    b, s, _ = x.shape
    h, qn, qr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        q = _proj(_proj(x, p["w_dq"], None, wt), p["w_uq"], None, wt)
    else:
        q = _proj(x, p["wq"], None, wt)
    q = q.reshape(b, s, h, qn + qr)
    return q[..., :qn], q[..., qn:]


def mla_attention(p, x, cfg, *, positions, wt=Identity, chunk=2048):
    b, s, _ = x.shape
    h, qn, qr, vd, r = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    q_nope, q_rope = _mla_q(p, x, cfg, wt)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    dkv = _proj(x, p["w_dkv"], None, wt)           # (B,S,r+qr)
    latent, k_rope = dkv[..., :r], dkv[..., r:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    k_nope = _proj(latent, p["w_uk"], None, wt).reshape(b, s, h, qn)
    v = _proj(latent, p["w_uv"], None, wt).reshape(b, s, h, vd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, qr))], axis=-1)
    q, k, v = (constrain_heads(t.transpose(0, 2, 1, 3)) for t in (q, k, v))
    o = chunked_causal_attention(q, k, v, chunk=chunk)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * vd)
    return _proj(o, p["wo"], None, wt)


def mla_decode(p, x, cfg, cache, *, pos, wt=Identity):
    """MLA decode with the *compressed* cache: {"latent": (B,Smax,r),
    "k_rope": (B,Smax,qr)} — the memory win MLA exists for."""
    b = x.shape[0]
    h, qn, qr, vd, r = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    q_nope, q_rope = _mla_q(p, x, cfg, wt)
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)
    dkv = _proj(x, p["w_dkv"], None, wt)
    latent, k_rope = dkv[..., :r], dkv[..., r:]
    k_rope = apply_rope(k_rope[:, :, None, :], pos[:, None], cfg.rope_theta)[:, :, 0]
    lat_c = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(c, u, i, 0)
                     )(cache["latent"], latent, pos)
    kr_c = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(c, u, i, 0)
                    )(cache["k_rope"], k_rope, pos)
    smax = lat_c.shape[1]
    # absorb: score = q_nope . W_uk(latent) + q_rope . k_rope
    k_nope = _proj(lat_c, p["w_uk"], None, wt).reshape(b, smax, h, qn)
    v = _proj(lat_c, p["w_uv"], None, wt).reshape(b, smax, h, vd)
    s1 = jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
    s2 = jnp.einsum("bqhd,bkd->bhqk", q_rope, kr_c)
    s = (s1 + s2).astype(jnp.float32) / np.sqrt(qn + qr)
    valid = jnp.arange(smax)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", pr, v).reshape(b, 1, h * vd)
    return _proj(o, p["wo"], None, wt), {"latent": lat_c, "k_rope": kr_c}


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def swiglu_params_shape(cfg, d_ff=None):
    f = d_ff or cfg.d_ff
    d = cfg.d_model
    return {"w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)}


def swiglu(p, x, wt=Identity):
    g = jax.nn.silu(_proj(x, p["w_gate"], None, wt))
    return _proj(g * _proj(x, p["w_up"], None, wt), p["w_down"], None, wt)


def gelu_mlp_params_shape(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {"w_up": (d, f), "b_up": (f,), "w_down": (f, d), "b_down": (d,)}


def gelu_mlp(p, x, wt=Identity):
    h = jax.nn.gelu(_proj(x, p["w_up"], p["b_up"], wt))
    return _proj(h, p["w_down"], p["b_down"], wt)


# --------------------------------------------------------------------------
# MoE (capacity-based gather/scatter dispatch; experts shard over 'model')
# --------------------------------------------------------------------------


def moe_params_shape(cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    p = {
        "router": (d, e),
        "we_gate": (e, d, f), "we_up": (e, d, f), "we_down": (e, f, d),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        p.update({"ws_gate": (d, fs), "ws_up": (d, fs), "ws_down": (fs, d)})
    return p


def moe_capacity(cfg, n_tokens: int) -> int:
    c = int(np.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, (c + 7) // 8 * 8)


def moe(p, x, cfg, wt=Identity):
    """x: (B,S,D) -> (B,S,D). GShard-style GROUPED dispatch: each batch row
    is a routing group that stays local to its data shard — position
    computation is a per-group sort (O(S k log Sk) scalar work, no (n,E)
    cumsum), dispatch/combine are group-local scatters, and only the
    (group, expert) buffer crosses shards (the EP all-to-all). Per-expert
    capacity is per group; overflow rides the residual path."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = moe_capacity(cfg, s)                                  # per group
    nk = s * k

    logits = jnp.einsum("gsd,de->gse", x,
                        wt(p["router"]).astype(x.dtype)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                     # (g, s, e)
    topw, topi = jax.lax.top_k(gates, k)                        # (g, s, k)
    topw = (topw / jnp.sum(topw, -1, keepdims=True)).astype(x.dtype)

    # per-group positions within each expert queue, via stable sort.
    # NOTE: dispatch and combine are GATHER-only — scatters with batched
    # indices make XLA SPMD replicate (g, nk, d)-sized buffers (measured:
    # +100 TB wire on deepseek-v3), gathers partition cleanly.
    eid = topi.reshape(b, nk)
    order = jnp.argsort(eid, axis=1, stable=True)               # (g, nk)
    sorted_eid = jnp.take_along_axis(eid, order, 1)
    starts = jax.vmap(lambda se: jnp.searchsorted(
        se, jnp.arange(e), side="left"))(sorted_eid)            # (g, e)
    onehot_cnt = jnp.diff(jnp.concatenate(
        [starts, jnp.full((b, 1), nk, starts.dtype)], axis=1), axis=1)
    pos_sorted = jnp.arange(nk)[None, :] - \
        jnp.take_along_axis(starts, sorted_eid, 1)              # (g, nk)
    keep_sorted = pos_sorted < cap

    # capacity grid: slot (e, c) <- sorted index starts[e] + c
    c_idx = jnp.arange(cap)
    grid_j = starts[:, :, None] + c_idx[None, None, :]          # (g, e, cap)
    grid_valid = c_idx[None, None, :] < onehot_cnt[:, :, None]
    grid_j = jnp.clip(grid_j, 0, nk - 1).reshape(b, e * cap)
    src_tok = jnp.take_along_axis(
        jnp.take_along_axis(jnp.arange(nk)[None, :] // k * jnp.ones(
            (b, 1), jnp.int32), order, 1),                      # token of sorted j
        grid_j, 1)                                              # (g, e*cap)
    xe = jnp.take_along_axis(x, src_tok[..., None], axis=1)     # gather
    xe = jnp.where(grid_valid.reshape(b, e * cap)[..., None], xe, 0)
    xe = xe.reshape(b, e, cap, d)
    xe = constrain(xe, ctx_dp(), "model", None, None)  # EP all-to-all here

    # per-(token,k) slot for the combine gather
    pos = jnp.zeros((b, nk), jnp.int32).at[
        jnp.arange(b)[:, None], order].set(pos_sorted)
    keep = pos < cap
    slot = jnp.where(keep, eid * cap + pos, 0)                  # (g, nk)

    # expert FFN over all groups (e shards over 'model')
    g_ = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                                wt(p["we_gate"]).astype(xe.dtype)))
    u = jnp.einsum("gecd,edf->gecf", xe, wt(p["we_up"]).astype(xe.dtype))
    ye = jnp.einsum("gecf,efd->gecd", g_ * u,
                    wt(p["we_down"]).astype(xe.dtype))
    ye = constrain(ye, ctx_dp(), "model", None, None)

    # group-local combine: gather slots back, weight, sum over k
    yflat = ye.reshape(b, e * cap, d)
    safe = jnp.where(keep, slot, 0)
    token_y = jnp.where(keep[..., None],
                        jnp.take_along_axis(yflat, safe[..., None], 1), 0)
    y = jnp.sum(token_y.reshape(b, s, k, d) *
                topw[..., None].astype(x.dtype), axis=2)

    if cfg.n_shared_experts:
        y = y + swiglu({"w_gate": p["ws_gate"], "w_up": p["ws_up"],
                        "w_down": p["ws_down"]}, x, wt)
    return y


# --------------------------------------------------------------------------
# Mamba2 (SSD) block
# --------------------------------------------------------------------------


def mamba2_params_shape(cfg):
    d, di, n, hd = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h = di // hd
    return {
        "w_in": (d, 2 * di + 2 * n + h),   # [x, z, B, C, dt]
        "conv_w": (cfg.ssm_conv_width, di + 2 * n),
        "A_log": (h,), "D": (h,), "dt_bias": (h,),
        "w_out": (di, d),
    }


def _ssd_chunked(x, dt, A, B, C, chunk):
    """SSD chunked scan. x: (b,l,h,p); dt: (b,l,h); A: (h,); B,C: (b,l,n).
    Returns y (b,l,h,p) and final state (b,h,p,n)."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    nc = l // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    da = dtc * A  # (b,nc,q,h) negative
    cum = jnp.cumsum(da, axis=2)                     # within-chunk cumsum
    # intra-chunk: y_intra[t] = sum_{s<=t} C_t . B_s * exp(cum_t - cum_s) dt_s x_s
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,q,q,h)
    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    # mask BEFORE exp: non-causal entries have seg > 0 and overflow exp,
    # poisoning gradients through the where (the where-grad trap)
    seg = jnp.where(causal, seg, -jnp.inf)
    decay = jnp.exp(seg).astype(x.dtype)
    cb = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)       # (b,nc,q,q)
    y_intra = jnp.einsum("bcqs,bcqsh,bcsh,bcshp->bcqhp",
                         cb.astype(x.dtype), decay, dtc.astype(x.dtype), xc)

    # chunk states: S_c = sum_s exp(cum_last - cum_s) dt_s B_s x_s^T
    last = cum[:, :, -1:, :]                          # (b,nc,1,h)
    dec_s = jnp.exp(last - cum).astype(x.dtype)       # (b,nc,q,h)
    S = jnp.einsum("bcsh,bcsh,bcsn,bcshp->bchpn",
                   dec_s, dtc.astype(x.dtype), Bc, xc)  # per-chunk state contrib
    chunk_decay = jnp.exp(last[:, :, 0, :])           # (b,nc,h)

    def step(carry, inp):
        s_prev = carry                                 # (b,h,p,n)
        s_c, dk = inp                                  # (b,h,p,n), (b,h)
        s_new = s_prev * dk[:, :, None, None].astype(s_prev.dtype) + s_c
        return s_new, s_prev

    s0 = jnp.zeros((b, h, p, n), x.dtype)
    s_fin, s_prevs = jax.lax.scan(
        step, s0, (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)         # (b,nc,h,p,n)

    # inter-chunk: y_inter[t] = C_t . exp(cum_t) S_prev
    dec_q = jnp.exp(cum).astype(x.dtype)               # (b,nc,q,h)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, dec_q, s_prevs)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y, s_fin


def _causal_conv(x, w):
    """depthwise causal conv. x: (b,l,c); w: (k,c)."""
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi * w[i].astype(x.dtype)
    return out


def mamba2_block(p, x, cfg, wt=Identity):
    """Training/prefill SSD. x: (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    di, n, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h = di // hd
    zxbcdt = _proj(x, p["w_in"], None, wt)
    xi, z, B, C, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], -1)
    conv_in = jnp.concatenate([xi, B, C], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"]))
    xi, B, C = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = _ssd_chunked(xi.reshape(b, s, h, hd), dt, A, B, C,
                        min(cfg.ssm_chunk, s))
    y = y + xi.reshape(b, s, h, hd) * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, di) * jax.nn.silu(z)
    return _proj(y, p["w_out"], None, wt)


def mamba2_decode(p, x, cfg, cache, wt=Identity):
    """Single-step SSD recurrence. cache: {"state": (B,h,hd,n),
    "conv": (B, k-1, di+2n)}. x: (B,1,D)."""
    b = x.shape[0]
    di, n, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h = di // hd
    zxbcdt = _proj(x[:, 0], p["w_in"], None, wt)       # (B, ...)
    xi, z, B, C, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], -1)
    conv_in = jnp.concatenate([xi, B, C], axis=-1)      # (B, di+2n)
    hist = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)  # (B,k,c)
    w = p["conv_w"]
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w.astype(hist.dtype)))
    xi, B, C = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,h)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * A)                                 # (B,h)
    xh = xi.reshape(b, h, hd)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt.astype(x.dtype), xh, B)
    state = cache["state"] * da[:, :, None, None].astype(x.dtype) + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C)
    y = y + xh * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(b, di) * jax.nn.silu(z)
    out = _proj(y, p["w_out"], None, wt)[:, None]
    return out, {"state": state, "conv": hist[:, 1:]}


# --------------------------------------------------------------------------
# RG-LRU (recurrentgemma) block
# --------------------------------------------------------------------------


def rglru_params_shape(cfg):
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    return {
        "w_x": (d, w), "w_y_gate": (d, w),            # linear in / output gate
        "conv_w": (cfg.ssm_conv_width or 4, w),
        "w_input_gate": (w, w), "w_a_gate": (w, w), "a_param": (w,),
        "w_out": (w, d),
    }


_C_RGLRU = 8.0


def _rglru_scan(x_in, i_gate, a_gate, a_param):
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t); associative scan over L."""
    log_a = -_C_RGLRU * jax.nn.softplus(a_param) * jax.nn.sigmoid(a_gate)
    a = jnp.exp(log_a.astype(jnp.float32))
    gated = (i_gate * x_in).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * gated

    def comb(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h.astype(x_in.dtype)


def rglru_block(p, x, cfg, wt=Identity):
    """Recurrent block (train/prefill). x: (B,S,D)."""
    xw = _proj(x, p["w_x"], None, wt)
    xw = jax.nn.silu(_causal_conv(xw, p["conv_w"]))
    i_gate = jax.nn.sigmoid(xw @ wt(p["w_input_gate"]).astype(xw.dtype))
    a_gate = xw @ wt(p["w_a_gate"]).astype(xw.dtype)
    h = _rglru_scan(xw, i_gate, a_gate, p["a_param"])
    y_gate = jax.nn.gelu(_proj(x, p["w_y_gate"], None, wt))
    return _proj(h * y_gate, p["w_out"], None, wt)


def rglru_decode(p, x, cfg, cache, wt=Identity):
    """Single-step recurrence. cache: {"h": (B,w), "conv": (B,k-1,w)}."""
    xw = _proj(x[:, 0], p["w_x"], None, wt)            # (B,w)
    hist = jnp.concatenate([cache["conv"], xw[:, None]], axis=1)
    xw = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, p["conv_w"].astype(hist.dtype)))
    i_gate = jax.nn.sigmoid(xw @ wt(p["w_input_gate"]).astype(xw.dtype))
    a_gate = xw @ wt(p["w_a_gate"]).astype(xw.dtype)
    log_a = -_C_RGLRU * jax.nn.softplus(p["a_param"]) * jax.nn.sigmoid(a_gate)
    a = jnp.exp(log_a.astype(jnp.float32))
    b = jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (i_gate * xw).astype(jnp.float32)
    h = (cache["h"].astype(jnp.float32) * a + b).astype(x.dtype)
    y_gate = jax.nn.gelu(_proj(x[:, 0], p["w_y_gate"], None, wt))
    out = _proj(h * y_gate, p["w_out"], None, wt)[:, None]
    return out, {"h": h, "conv": hist[:, 1:]}


# --------------------------------------------------------------------------
# embedding / logits
# --------------------------------------------------------------------------


def embed(tokens, emb, dtype=jnp.bfloat16):
    return emb.astype(dtype)[tokens]


def logits(x, head, wt=Identity):
    return _proj(x, wt(head), None)  # (B,S,V)
