#!/usr/bin/env python
"""Docs link-check: fail on dead relative links and anchors in Markdown.

Scans every tracked ``*.md`` under the repo root for ``[text](target)``
links, resolves relative targets against the file's directory, and exits
non-zero listing any that do not exist. ``#fragment`` parts pointing at a
Markdown file (or the same file) are checked against that file's heading
anchors (GitHub slug rules: lowercase, punctuation dropped, spaces to
hyphens). External (``scheme://``) and ``mailto:`` links are skipped — CI
stays hermetic.

  python tools/check_links.py [root]
"""
from __future__ import annotations

import functools
import pathlib
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$", re.M)
SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", "node_modules",
             ".claude"}


def slugify(heading: str) -> str:
    """GitHub-style heading anchor: strip markdown/code markup, lowercase,
    drop punctuation, spaces -> hyphens."""
    h = re.sub(r"[`*_]|\[([^\]]*)\]\([^)]*\)", r"\1", heading).lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.strip().replace(" ", "-")


@functools.lru_cache(maxsize=None)
def anchors(md: pathlib.Path) -> frozenset:
    return frozenset(slugify(m.group(1)) for m in
                     HEADING.finditer(md.read_text(encoding="utf-8")))


def check(root: pathlib.Path) -> list:
    bad = []
    for md in sorted(root.rglob("*.md")):
        if SKIP_DIRS & set(p.name for p in md.parents):
            continue
        for m in LINK.finditer(md.read_text(encoding="utf-8")):
            target, _, frag = m.group(1).partition("#")
            if "://" in target or target.startswith("mailto:"):
                continue
            dest = (md.parent / target) if target else md
            if not dest.exists():
                bad.append(f"{md.relative_to(root)}: dead link -> "
                           f"{m.group(1)}")
                continue
            if frag and dest.suffix == ".md" and \
                    frag.lower() not in anchors(dest.resolve()):
                bad.append(f"{md.relative_to(root)}: dead anchor -> "
                           f"{m.group(1)}")
    return bad


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = pathlib.Path(argv[0]) if argv else \
        pathlib.Path(__file__).resolve().parent.parent
    bad = check(root)
    if bad:
        print("\n".join(bad))
        print(f"link-check: {len(bad)} dead relative link(s)")
        return 1
    print("link-check: all relative Markdown links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
