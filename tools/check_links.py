#!/usr/bin/env python
"""Docs link-check: fail on dead relative links in Markdown files.

Scans every tracked ``*.md`` under the repo root for ``[text](target)``
links, resolves relative targets (with optional ``#fragment``) against the
file's directory, and exits non-zero listing any that do not exist. External
(``scheme://``) and ``mailto:`` links are skipped — CI stays hermetic.

  python tools/check_links.py [root]
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", "node_modules",
             ".claude"}


def check(root: pathlib.Path) -> list:
    bad = []
    for md in sorted(root.rglob("*.md")):
        if SKIP_DIRS & set(p.name for p in md.parents):
            continue
        for m in LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1).split("#", 1)[0]
            if (not target or "://" in target
                    or target.startswith("mailto:")):
                continue
            if not (md.parent / target).exists():
                bad.append(f"{md.relative_to(root)}: dead link -> "
                           f"{m.group(1)}")
    return bad


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = pathlib.Path(argv[0]) if argv else \
        pathlib.Path(__file__).resolve().parent.parent
    bad = check(root)
    if bad:
        print("\n".join(bad))
        print(f"link-check: {len(bad)} dead relative link(s)")
        return 1
    print("link-check: all relative Markdown links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
