"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
full production stack — QAT + WOT, SGD momentum, grad accumulation, async
ECC-protected checkpointing — then verify the deployable int8 weights satisfy
the WOT constraint and serve them under injected faults.

  PYTHONPATH=src python examples/train_lm_wot.py [--steps 200]
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, protection
from repro.core import quant, wot
from repro.data import synthetic
from repro.models import lm
from repro.serving import protected
from repro.training import checkpoint, optim, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M params: qwen-family reduced width
    cfg = configs.get("qwen1.5-4b").with_(
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=1536, vocab=16384, microbatch=2, remat=False)
    n_params = sum(np.prod(l.shape) for l in
                   jax.tree.leaves(lm.param_specs(cfg)))
    print(f"[lm] {cfg.name}-reduced: {n_params / 1e6:.1f}M params")

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.sgd_init(params)
    ckpt = checkpoint.AsyncCheckpointer(args.ckpt, protected=True)
    step_fn = jax.jit(train.make_train_step(cfg, lr=3e-3, chunk=64))

    t0 = time.time()
    B, S = 8, 128
    for step in range(args.steps):
        b = synthetic.token_batch(cfg.vocab_padded, B, S, seed=0, step=step)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step_fn(params, opt, b)
        if step % 20 == 0:
            tok_s = B * S * (step + 1) / (time.time() - t0)
            print(f"  step {step:4d} loss {float(loss):.4f} ({tok_s:.0f} tok/s)")
        if (step + 1) % 100 == 0:
            ckpt.save((params, opt), step + 1)
    ckpt.wait()

    # deployable weights satisfy WOT
    bad = 0
    for leaf in jax.tree.leaves(params):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2:
            q, _ = quant.quantize(leaf)
            bad += int(wot.count_large_in_protected(q.reshape(-1)))
    print(f"[lm] WOT violations in deployable int8 weights: {bad}")

    # protected serving under faults
    print("[lm] " + protection.coverage(params).summary()
          .replace("\n", "\n[lm] "))
    enc = protected.encode_tree(params)
    enc_faulty = protection.inject_tree(enc, 1e-4, seed=1)
    serve = jax.jit(protected.make_serve_step(cfg))
    cache = lm.init_cache(cfg, 2, 64)
    toks = jnp.zeros((2, 1), jnp.int32)
    for t in range(8):
        logits, cache = serve(enc_faulty, cache, toks,
                              jnp.full((2,), t, jnp.int32))
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
    print(f"[lm] served 8 tokens from fault-injected encoded weights: "
          f"{np.isfinite(np.asarray(logits, np.float32)).all()}")


if __name__ == "__main__":
    main()
