"""Quickstart: the paper's full pipeline in a few minutes on CPU.

1. Pretrain a small CNN (stands in for the paper's ImageNet models).
2. WOT fine-tune: QAT + throttling (paper §4.1 QATT) with SGD momentum.
3. Quantize to int8; the WOT constraint holds -> in-place ECC is applicable.
4. Encode (zero space overhead!), inject memory faults, decode, evaluate —
   protection matches standard SEC-DED ECC at 0% space cost.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.training.cnn_experiments import (accuracy, eval_with_scheme,
                                            large_count, pretrain,
                                            wot_finetune)


def main():
    print("=== In-Place Zero-Space Memory Protection: quickstart ===")
    print("[1] fp32 pretraining (stands in for ImageNet weights) ...")
    params, fwd, tmpl = pretrain("resnet18", steps=100)
    print(f"    fp32 accuracy: {accuracy(params, fwd, tmpl):.3f}, "
          f"int8: {accuracy(params, fwd, tmpl, quantized=True):.3f}, "
          f"WOT-violating large values: {large_count(params)}")

    print("[2] WOT fine-tune (QAT + throttling, SGD momentum) ...")
    params, tmpl, _ = wot_finetune(params, fwd, tmpl, steps=40)
    print(f"    int8+WOT accuracy: "
          f"{accuracy(params, fwd, tmpl, quantized=True):.3f}, "
          f"large values: {large_count(params)} (constraint satisfied)")

    rate = 1e-3
    print(f"[3] memory faults at rate {rate}: accuracy per scheme")
    for scheme in ("faulty", "zero", "ecc", "in-place"):
        accs = [eval_with_scheme(params, fwd, tmpl, scheme, rate, 100 * s)[0]
                for s in range(3)]
        _, ovh = eval_with_scheme(params, fwd, tmpl, scheme, 0.0, 0)
        print(f"    {scheme:9s}: accuracy {sum(accs) / 3:.3f} "
              f"(space overhead {ovh * 100:4.1f}%)")
    print("in-place zero-space ECC == standard ECC protection at 0% cost")


if __name__ == "__main__":
    main()
