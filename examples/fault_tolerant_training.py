"""Fault-tolerance demo: training survives a simulated node crash, and the
recovered weights survive memory faults.

Trains with async ECC-protected checkpoints, "crashes" mid-run, then resumes
from the latest checkpoint — final params are bitwise-reproducible vs an
uninterrupted run (deterministic per-step data pipeline).  The finale runs a
compiled on-device fault campaign (``repro.protection.fidelity_campaign``)
on the recovered weights: unprotected storage loses weights at every rate,
in-place zero-space ECC decodes ~everything back — the whole rate sweep in
one jitted program (``batch="scan"`` keeps memory flat at LM size).

  PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import pathlib
import shutil
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, protection
from repro.data import synthetic
from repro.models import lm
from repro.training import checkpoint, optim, train

CKPT = "/tmp/repro_ft_demo"


def run(params, opt, step_fn, cfg, start, end, ckpt_mgr=None, every=5):
    for s in range(start, end):
        b = synthetic.token_batch(cfg.vocab_padded, 4, 32, seed=0, step=s)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step_fn(params, opt, b)
        if ckpt_mgr and (s + 1) % every == 0:
            ckpt_mgr.save((params, opt), s + 1)
    if ckpt_mgr:
        ckpt_mgr.wait()
    return params, opt, float(loss)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = configs.get_smoke("deepseek-7b").with_(microbatch=2)
    params0 = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt0 = optim.sgd_init(params0)
    step_fn = jax.jit(train.make_train_step(cfg, lr=1e-3, chunk=16))

    print("[ft] uninterrupted run: 20 steps")
    p_ref, _, loss_ref = run(params0, opt0, step_fn, cfg, 0, 20)

    print("[ft] run with checkpoints, crash at step 13")
    ck = checkpoint.AsyncCheckpointer(CKPT, protected=False)
    p, o, _ = run(params0, opt0, step_fn, cfg, 0, 13, ck, every=5)
    del p, o  # "node failure": in-memory state lost

    last = checkpoint.latest_step(CKPT)
    print(f"[ft] resuming from checkpoint step {last}")
    (p, o), s0 = checkpoint.restore(CKPT, (params0, opt0))
    p_resumed, _, loss_res = run(p, o, step_fn, cfg, s0, 20)

    err = jax.tree.reduce(max, jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p_ref, p_resumed))
    print(f"[ft] resumed-vs-uninterrupted max param diff: {err:.2e}")
    assert err < 1e-6
    print("[ft] crash-resume reproduces the uninterrupted run exactly")

    print("[ft] memory-fault campaign on the recovered weights "
          "(compiled scan sweep, 2 trials/rate)")
    rates = (1e-5, 1e-4, 1e-3)
    fidelity = {}
    for scheme in ("faulty", "in-place"):
        res = protection.fidelity_campaign(
            p_resumed, scheme, rates=rates, trials=2,
            key=jax.random.PRNGKey(42), batch="scan")
        fidelity[scheme] = res.mean()
        cells = "  ".join(f"{r:.0e}:{m * 100:7.3f}%"
                          for r, m in zip(res.rates, res.mean()))
        print(f"[ft] {scheme:9s} decode fidelity {cells} "
              f"(overhead {res.space_overhead * 100:.1f}%, "
              f"sweep {res.wall_clock_s:.2f}s)")
    assert fidelity["in-place"][0] >= fidelity["faulty"][0]
    assert fidelity["in-place"][-1] > 0.999
    print("[ft] in-place zero-space ECC keeps the recovered weights intact "
          "under memory faults")


if __name__ == "__main__":
    main()
