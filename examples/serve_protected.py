"""Protected-serving example: batched decode with ECC-encoded weights under
active memory faults, across architectures (dense / MoE / SSM / hybrid).

  PYTHONPATH=src python examples/serve_protected.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import inject_tree
from repro.models import lm
from repro.serving import protected


def main():
    for arch in ("deepseek-7b", "deepseek-v2-236b", "mamba2-2.7b",
                 "recurrentgemma-2b"):
        cfg = configs.get_smoke(arch)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        enc = protected.encode_tree(params)
        serve = jax.jit(protected.make_serve_step(cfg))
        B = 4
        cache = lm.init_cache(cfg, B, 64)

        # clean pass
        tok = jnp.zeros((B, 1), jnp.int32)
        clean, _ = serve(enc, cache, tok, jnp.zeros((B,), jnp.int32))

        # serve with faults injected into the resident weight images
        faulty_enc = inject_tree(enc, 1e-5, seed=42)
        dirty, _ = serve(faulty_enc, cache, tok, jnp.zeros((B,), jnp.int32))
        err = float(jnp.max(jnp.abs(clean.astype(jnp.float32) -
                                    dirty.astype(jnp.float32))))
        print(f"{arch:20s} batch={B}: fault-injected vs clean logits "
              f"max|diff| = {err:.2e}  (singles corrected in-place)")


if __name__ == "__main__":
    main()
