"""Protected-serving example: batched decode with ECC-encoded weights under
active memory faults, across architectures (dense / MoE / SSM / hybrid),
driven entirely through the ``repro.protection`` policy API.

  PYTHONPATH=src python examples/serve_protected.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro import configs, protection
from repro.models import lm
from repro.serving import protected


def main():
    policy = protection.ProtectionPolicy(default_scheme="in-place")
    for arch in ("deepseek-7b", "deepseek-v2-236b", "mamba2-2.7b",
                 "recurrentgemma-2b"):
        cfg = configs.get_smoke(arch)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        report = policy.coverage(params)
        enc = policy.encode_tree(params)
        serve = jax.jit(protected.make_serve_step(cfg))
        B = 4
        cache = lm.init_cache(cfg, B, 64)

        # clean pass
        tok = jnp.zeros((B, 1), jnp.int32)
        clean, _ = serve(enc, cache, tok, jnp.zeros((B,), jnp.int32))

        # serve with faults injected into the resident weight images
        faulty_enc = protection.inject_tree(enc, 1e-5, seed=42)
        dirty, _ = serve(faulty_enc, cache, tok, jnp.zeros((B,), jnp.int32))
        err = float(jnp.max(jnp.abs(clean.astype(jnp.float32) -
                                    dirty.astype(jnp.float32))))
        print(f"{arch:20s} batch={B}: {report.n_protected} tensors "
              f"protected ({report.protected_bytes / 2**20:.1f} MiB, "
              f"{report.n_unprotected} unprotected), fault-injected vs clean "
              f"logits max|diff| = {err:.2e} (singles corrected in-place)")


if __name__ == "__main__":
    main()
