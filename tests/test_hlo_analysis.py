"""Unit tests for the roofline HLO parser (the §Roofline source of truth)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def test_scan_flops_trip_count_aware():
    def f(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    s = H.compute_stats(c.as_text())
    assert s["flops"] == 10 * 2 * 128 ** 3  # body counted x trip_count
    # cost_analysis counts the body once — the reason this parser exists
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < s["flops"]


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(c, _):
            return jax.lax.scan(lambda c2, w: (c2 @ w, None), c, ws)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    s = H.compute_stats(c.as_text())
    assert s["flops"] == 3 * 5 * 2 * 64 ** 3


def test_shape_bytes():
    assert H._shape_bytes("f32[10,10]") == 400
    assert H._shape_bytes("bf16[8]{0}") == 16
    assert H._shape_bytes("(f32[4], s8[8])") == 24
    assert H._shape_bytes("u8[2,3,4]") == 24


def test_wire_factors():
    # all-reduce: 2(n-1)/n * operand
    assert H._wire("all-reduce", 100, 0, 4) == pytest.approx(150.0)
    assert H._wire("all-gather", 0, 160, 16) == pytest.approx(150.0)
    assert H._wire("reduce-scatter", 160, 0, 16) == pytest.approx(150.0)
    assert H._wire("collective-permute", 100, 0, 2) == 100.0


def test_group_size_parsing():
    assert H._group_size("replica_groups={{0,1,2,3}}") == 4
    assert H._group_size("replica_groups=[16,16]<=[256]") == 16


def test_dot_flops_on_real_sharded_program():
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("m",))
    with mesh:
        f = jax.jit(lambda a, b: a @ b,
                    in_shardings=(NamedSharding(mesh, P()),
                                  NamedSharding(mesh, P())))
        c = f.lower(jax.ShapeDtypeStruct((256, 512), jnp.float32),
                    jax.ShapeDtypeStruct((512, 128), jnp.float32)).compile()
    s = H.compute_stats(c.as_text())
    assert s["flops"] == 2 * 256 * 512 * 128
