"""``repro.serving.kvcache`` — the paged, zero-space-protected KV cache:
page codec fault behaviour, fused-vs-reference bit identity, the paged
serving chain (prefill -> decode), live-pool injection and per-layer KV
flags, KV fault campaigns, byte accounting, the plan-level KV knob, and
the windowed-ring / ragged-length attention regressions it leans on."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, protection
from repro.kernels import paged_attention
from repro.models import layers as L
from repro.models import lm
from repro.serving import kvcache, protected

CFG = configs.get_smoke("deepseek-7b")    # dense smoke: 4 heads / 4 kv
GQA = configs.get_smoke("minitron-4b")    # 4 heads / 2 kv (GQA rep = 2)


def _randn(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------------------
# attention regressions the paged path leans on
# ---------------------------------------------------------------------------


def test_windowed_ring_ignores_cache_overallocation():
    """A windowed decode must attend to exactly the last ``window`` tokens
    no matter how large the ring buffer was allocated. The old slot mask
    treated every slot as valid once pos >= smax, silently widening the
    window to smax when the cache was over-allocated."""
    cfg, b, window, steps = CFG, 2, 4, 11
    rng = np.random.default_rng(0)
    p = {k: _randn(rng, s) * 0.05
         for k, s in L.gqa_params_shape(cfg).items()}
    xs = [_randn(rng, (b, 1, cfg.d_model)) for _ in range(steps)]
    outs = {}
    for smax in (window, 3 * window):   # exact ring vs over-allocated ring
        cache = {"k": jnp.zeros((b, smax, cfg.n_kv_heads, cfg.head_dim)),
                 "v": jnp.zeros((b, smax, cfg.n_kv_heads, cfg.head_dim))}
        outs[smax] = []
        for t, x in enumerate(xs):
            pos = jnp.full((b,), t, jnp.int32)
            o, cache = L.gqa_decode(p, x, cfg, cache, pos=pos, window=window)
            outs[smax].append(np.asarray(o, np.float32))
    # steps > smax wraps the small ring twice and leaves the big ring with
    # never-written slots — both must still see only the last 4 tokens
    for a, c in zip(outs[window], outs[3 * window]):
        np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-6)


def test_decode_attention_ragged_lengths():
    """Per-sequence ``length_mask``: each row of a ragged batch must equal
    single-sequence attention over its own truncated cache, and garbage in
    masked slots must not leak into any row."""
    rng = np.random.default_rng(1)
    b, h, s, d = 3, 2, 9, 8
    q = _randn(rng, (b, h, 1, d))
    k = _randn(rng, (b, h, s, d))
    v = _randn(rng, (b, h, s, d))
    lengths = np.array([2, 5, 9])
    mask = jnp.asarray(np.arange(s)[None, :] < lengths[:, None])
    o = L.decode_attention(q, k, v, mask)
    for i, n in enumerate(lengths):
        ref = L.decode_attention(q[i:i + 1], k[i:i + 1, :, :n],
                                 v[i:i + 1, :, :n])
        np.testing.assert_allclose(np.asarray(o[i:i + 1]), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
    poison = mask[:, None, :, None]
    o2 = L.decode_attention(q, jnp.where(poison, k, 1e4),
                            jnp.where(poison, v, -1e4), mask)
    assert np.array_equal(np.asarray(o), np.asarray(o2))


# ---------------------------------------------------------------------------
# page codec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", kvcache.KV_SCHEMES)
def test_page_codec_fault_behaviour(scheme):
    """One flipped bit in an encoded page: in-place corrects it, parity-zero
    detects and zeroes the byte, the unprotected baseline silently serves
    the corruption. Clean pages decode with zero flags everywhere."""
    pol = kvcache.KVProtectionPolicy(scheme=scheme)
    rng = np.random.default_rng(2)
    kf = _randn(rng, (2, 16, 2, 16))                     # (B, S, kv, hd)
    enc, checks, scale = kvcache._encode_kv(kf, pol)
    assert enc.dtype == jnp.uint8 and scale.shape == (2, 16)
    q0, cor0, due0 = kvcache._decode_kv(enc, checks, pol.scheme, pol.backend)
    assert q0.dtype == jnp.int8
    assert int(jnp.sum(cor0)) == 0 and int(jnp.sum(due0)) == 0

    flat = np.asarray(enc).copy()
    flat.flat[37] ^= 1 << 3                              # one data-bit fault
    q1, cor1, due1 = kvcache._decode_kv(jnp.asarray(flat), checks,
                                        pol.scheme, pol.backend)
    if scheme == "faulty":
        assert not np.array_equal(np.asarray(q0), np.asarray(q1))
        assert int(jnp.sum(cor1)) == 0 and int(jnp.sum(due1)) == 0
    elif scheme == "parity-zero":
        diff = np.asarray(q0) != np.asarray(q1)
        assert diff.sum() == 1 and np.asarray(q1).flat[37] == 0
        assert int(jnp.sum(cor1)) == 1 and int(jnp.sum(due1)) == 0
    else:                                                # in-place corrects
        assert np.array_equal(np.asarray(q0), np.asarray(q1))
        assert int(jnp.sum(cor1)) == 1 and int(jnp.sum(due1)) == 0


def test_page_quantization_error_bound():
    """The unprotected int8 page codec is plain per-token absmax
    quantization: dequantized error stays within half an LSB."""
    pol = kvcache.KVProtectionPolicy(scheme="faulty")
    rng = np.random.default_rng(3)
    kf = _randn(rng, (2, 8, 2, 16))
    enc, checks, scale = kvcache._encode_kv(kf, pol)
    q, _, _ = kvcache._decode_kv(enc, checks, pol.scheme, pol.backend)
    deq = np.asarray(q, np.float32) * np.asarray(scale)[..., None, None]
    err = np.abs(deq - np.asarray(kf))
    lsb = np.asarray(scale)[..., None, None]
    assert (err <= 0.5 * lsb + 1e-6).all()


# ---------------------------------------------------------------------------
# fused kernel == XLA reference, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("scheme", kvcache.KV_SCHEMES)
def test_fused_page_attention_bitexact(scheme, backend):
    """The fused decode-at-use kernel must match decode-then-
    ``decode_attention`` bit for bit — on clean strips AND on a faulted
    strip (ragged positions, GQA rep=2), with identical flag counts. The
    reference is jitted, as in the serving paths: eager op-by-op execution
    materializes an intermediate bf16 rounding of the score dot that XLA
    elides when the reference compiles as one program."""
    rng = np.random.default_rng(4)
    b, s, kv, hd, rep = 2, 32, 2, 16, 2
    pol = kvcache.KVProtectionPolicy(scheme=scheme, backend=backend)
    q = _randn(rng, (b, kv * rep, 1, hd), jnp.bfloat16)
    ke, kch, ksc = kvcache._encode_kv(_randn(rng, (b, s, kv, hd)), pol)
    ve, vch, vsc = kvcache._encode_kv(_randn(rng, (b, s, kv, hd)), pol)
    pos = jnp.asarray([s - 1, s // 2], jnp.int32)        # ragged batch

    flat = np.asarray(ke).copy()
    flat[0, 1, 0, 3] ^= 1 << 2          # fault in a token valid for seq 0
    ke = jnp.asarray(flat)

    o_f, fl_f = paged_attention.fused_page_attention(
        q, ke, kch, ksc, ve, vch, vsc, pos, scheme=scheme)
    reference = jax.jit(lambda *a: kvcache._reference_paged_attention(
        *a, pol))
    o_r, cor, due = reference(q, ke, kch, ksc, ve, vch, vsc, pos)
    assert np.array_equal(np.asarray(o_f), np.asarray(o_r))
    assert (int(fl_f[0]), int(fl_f[1])) == (int(cor), int(due))
    if scheme != "faulty":
        assert int(cor) == 1

    # per-slot rows: same output, flags resolved per batch row with the
    # injected fault attributed to sequence 0 only
    o_p, fl_p = paged_attention.fused_page_attention(
        q, ke, kch, ksc, ve, vch, vsc, pos, scheme=scheme, per_slot=True)
    assert np.array_equal(np.asarray(o_p), np.asarray(o_f))
    assert fl_p.shape == (2, b)
    assert np.array_equal(np.asarray(fl_p).sum(axis=1), np.asarray(fl_f))
    if scheme != "faulty":
        assert int(fl_p[0, 0]) == 1 and int(fl_p[0, 1]) == 0


# ---------------------------------------------------------------------------
# the paged serving chain
# ---------------------------------------------------------------------------


def test_paged_decode_tracks_dense(smoke_params):
    """Paged int8 decode (GQA arch, rep=2) follows the dense bf16 chain:
    same shapes, finite logits, strongly correlated — exact agreement is
    not expected (the pages are int8-quantized)."""
    (_, params), (cfg, b, smax) = smoke_params("minitron-4b"), (GQA, 2, 32)
    dense = kvcache.init_cache(cfg, b, smax)
    paged = kvcache.init_cache(cfg, b, smax, kv_policy="unprotected")
    assert "k_pages" in paged and "k_checks" not in paged
    toks_d = toks_p = jnp.zeros((b, 1), jnp.int32)
    corrs = []
    for t in range(5):
        pos = jnp.full((b,), t, jnp.int32)
        ld, dense = lm.decode_step(cfg, params, dense, toks_d, pos)
        lp, paged = lm.decode_step(cfg, params, paged, toks_p, pos,
                                   kv_policy="unprotected")
        assert ld.shape == lp.shape == (b, 1, cfg.vocab_padded)
        a = np.asarray(ld, np.float32).ravel()
        c = np.asarray(lp, np.float32).ravel()
        assert np.isfinite(c).all()
        corrs.append(np.corrcoef(a, c)[0, 1])
        toks_d = jnp.argmax(ld, axis=-1).astype(jnp.int32)
        toks_p = jnp.argmax(lp, axis=-1).astype(jnp.int32)
    assert np.mean(corrs) > 0.5, corrs


def test_paged_decode_requires_policy(smoke_params):
    cfg, params = smoke_params("deepseek-7b")
    cache = kvcache.init_cache(cfg, 1, 16, kv_policy="in-place")
    with pytest.raises(ValueError, match="kv_policy"):
        lm.decode_step(cfg, params, cache, jnp.zeros((1, 1), jnp.int32),
                       jnp.zeros((1,), jnp.int32))


def test_prefill_then_decode_chain(smoke_params):
    """``prefill_with_cache`` fills the pools so decode steps continue from
    them; clean pools report all-zero per-layer KV flags."""
    (_, params), (cfg, b, n) = smoke_params("deepseek-7b", 1), (CFG, 2, 20)
    cache = kvcache.init_cache(cfg, b, 48, kv_policy="in-place")
    toks = jnp.asarray(
        np.random.default_rng(5).integers(0, cfg.vocab, (b, n)), jnp.int32)
    logits, cache, flags = lm.prefill_with_cache(
        cfg, params, cache, toks, kv_policy="in-place", collect_flags=True)
    assert logits.shape == (b, n, cfg.vocab_padded)
    assert flags["layers_kv"].shape == (cfg.n_layers, 2)
    assert int(jnp.sum(flags["layers_kv"])) == 0
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    l2, cache, f2 = lm.decode_step(cfg, params, cache, nxt,
                                   jnp.full((b,), n, jnp.int32),
                                   kv_policy="in-place", collect_flags=True)
    assert l2.shape == (b, 1, cfg.vocab_padded)
    assert int(jnp.sum(f2["layers_kv"])) == 0


def test_live_pool_injection_flags(smoke_params):
    """Faults injected into the LIVE pools surface as per-layer (corrected,
    DUE) counts — both through ``tree_layer_flags`` and through the next
    decode step's ``layers_kv`` flags."""
    (_, params), (cfg, b) = smoke_params("deepseek-7b", 2), (CFG, 2)
    pol = kvcache.get_kv_policy("in-place")
    cache = kvcache.init_cache(cfg, b, 32, kv_policy=pol)
    toks = jnp.asarray(
        np.random.default_rng(6).integers(0, cfg.vocab, (b, 24)), jnp.int32)
    _, cache = lm.prefill_with_cache(cfg, params, cache, toks, kv_policy=pol)

    tree = kvcache.as_protected_tree(cache, pol)
    clean = np.asarray(kvcache.tree_layer_flags(tree))
    assert clean.shape == (cfg.n_layers, 2) and clean.sum() == 0
    dirty = protection.inject_tree_device(tree, 3e-3,
                                          jax.random.PRNGKey(7))
    rows = np.asarray(kvcache.tree_layer_flags(dirty))
    assert rows[:, 0].sum() > 0

    cache = kvcache.from_protected_tree(cache, dirty)
    _, _, flags = lm.decode_step(cfg, params, cache,
                                 jnp.zeros((b, 1), jnp.int32),
                                 jnp.full((b,), 24, jnp.int32),
                                 kv_policy=pol, collect_flags=True)
    assert int(jnp.sum(flags["layers_kv"][:, 0])) > 0


# ---------------------------------------------------------------------------
# KV fault campaigns
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seq", [16, 48])
def test_due_campaign_kv_target(seq, smoke_params):
    """``due_campaign(target="kv")`` sweeps the serving state at multiple
    context lengths and carries per-layer rows; JSON round-trips losslessly
    and pre-KV artifacts (no target / layer_rows keys) still load."""
    (_, params), (cfg, b) = smoke_params("deepseek-7b", 3), (CFG, 2)
    pol = kvcache.get_kv_policy("in-place")
    cache = kvcache.init_cache(cfg, b, seq, kv_policy=pol)
    toks = jnp.asarray(
        np.random.default_rng(8).integers(0, cfg.vocab, (b, seq)), jnp.int32)
    _, cache = lm.prefill_with_cache(cfg, params, cache, toks, kv_policy=pol)
    tree = kvcache.as_protected_tree(cache, pol)

    res = protection.due_campaign(None, "in-place", rates=(1e-3, 5e-3),
                                  trials=2, key=jax.random.PRNGKey(9),
                                  target="kv", kv_tree=tree)
    assert res.target == "kv"
    assert len(res.layer_rows) == cfg.n_layers
    assert sum(r[0] for r in res.layer_rows) > 0   # corrected singles
    rt = protection.CampaignResult.from_json(res.to_json())
    assert rt == res

    legacy = res.to_dict()
    legacy.pop("target"), legacy.pop("layer_rows")
    old = protection.CampaignResult.from_dict(legacy)
    assert old.target == "weights" and old.layer_rows == ()


def test_due_campaign_both_targets(smoke_params):
    (_, params), (cfg, b) = smoke_params("deepseek-7b", 4), (CFG, 1)
    pol = kvcache.get_kv_policy("in-place")
    cache = kvcache.init_cache(cfg, b, 16, kv_policy=pol)
    toks = jnp.asarray(
        np.random.default_rng(10).integers(0, cfg.vocab, (b, 16)), jnp.int32)
    _, cache = lm.prefill_with_cache(cfg, params, cache, toks, kv_policy=pol)
    tree = kvcache.as_protected_tree(cache, pol)
    policy = protection.ProtectionPolicy(default_scheme="in-place")
    enc = policy.encode_tree(params)
    res = protection.due_campaign(enc, policy, rates=(5e-3,), trials=1,
                                  key=jax.random.PRNGKey(11),
                                  target="both", kv_tree=tree)
    assert res.target == "both" and len(res.layer_rows) == cfg.n_layers
    with pytest.raises(ValueError, match="kv_tree"):
        protection.due_campaign(enc, policy, target="kv")


# ---------------------------------------------------------------------------
# byte accounting: the zero-space claim as bytes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("preset",
                         ["unprotected", "parity-zero", "in-place"])
def test_freed_page_reuse_no_stale_carryover(preset, smoke_params):
    """Page free/reuse hygiene: a freed-then-reassigned page serves a new
    sequence EXACTLY like a fresh pool — no stale-scale, stale-parity, or
    stale-fault carryover from the previous tenant, even after the
    tenant's pages absorbed injected faults while live."""
    cfg, params = smoke_params("deepseek-7b")
    pol = kvcache.get_kv_policy(preset)
    b, max_len, n_pages = 2, 32, 6
    rng = np.random.default_rng(13)
    seq_a = rng.integers(0, cfg.vocab, 8)
    seq_b = rng.integers(0, cfg.vocab, 6)

    def step(cache, toks, pos):
        return lm.decode_step(cfg, params, cache, toks, pos,
                              kv_policy=pol, collect_flags=True)

    # tenant A lives on slot 0, pages (2, 3); slot 1 idles on its parking
    # page (keep-alive token 0 at pos 0, like the serving front-end)
    cache = kvcache.init_paged_cache(cfg, b, max_len, pol,
                                     n_pages=n_pages)
    cache = kvcache.set_slot_pages(cache, 0, (2, 3))
    for t, tok in enumerate(seq_a):
        _, cache, _ = step(cache, jnp.asarray([[int(tok)], [0]], jnp.int32),
                           jnp.asarray([t, 0], jnp.int32))
    # the pool absorbs faults while A is live (scales/parity now reflect
    # A's tenancy plus flipped bits)
    tree = kvcache.as_protected_tree(cache, pol)
    dirty = protection.inject_tree_device(tree, 2e-3,
                                          jax.random.PRNGKey(21))
    cache = kvcache.from_protected_tree(cache, dirty)
    # A finishes: zero its pages, park its slot — the free-side hygiene
    cache = kvcache.zero_pages(cache, (2, 3))
    cache = kvcache.set_slot_pages(cache, 0, ())

    def serve_b(c):
        # tenant B reuses pages (2, 3) from slot 1
        c = kvcache.set_slot_pages(c, 1, (2, 3))
        outs, nflags = [], 0
        for t, tok in enumerate(seq_b):
            lg, c, fl = step(c, jnp.asarray([[0], [int(tok)]], jnp.int32),
                             jnp.asarray([0, t], jnp.int32))
            outs.append(np.asarray(lg, np.float32))
            nflags += int(jnp.sum(fl["layers_kv"]))
        return outs, nflags

    reused, fl_reused = serve_b(cache)
    fresh, fl_fresh = serve_b(kvcache.init_paged_cache(
        cfg, b, max_len, pol, n_pages=n_pages))
    assert fl_reused == 0 and fl_fresh == 0   # nothing stale surfaces
    for got, want in zip(reused, fresh):
        assert np.array_equal(got, want)      # bit-identical serving


def test_page_allocator_and_pool_helpers():
    """Host-side allocator contract: deterministic lowest-id-first order,
    parking pages never handed out, double-free and foreign-free rejected,
    refcounted sharing exact (free releases a page only when its LAST
    reference drops, and reports exactly which pages it released)."""
    a = kvcache.PageAllocator(8, reserved=2)
    assert a.free_count == 6 and a.can(6) and not a.can(7)
    assert a.alloc(3) == (2, 3, 4)
    assert a.free([3]) == (3,)
    assert a.alloc(1) == (3,)                 # lowest id first, reused
    with pytest.raises(ValueError, match="exhausted"):
        a.alloc(5)
    with pytest.raises(ValueError, match="not allocatable"):
        a.free([1])                           # parking page
    with pytest.raises(ValueError, match="not allocatable"):
        a.free([8])                           # out of pool
    assert a.free([2]) == (2,)
    with pytest.raises(ValueError, match="double free"):
        a.free([2])
    # refcounts: a shared page survives all but its last free
    assert a.refcount(3) == 1 and a.refcount(2) == 0
    a.retain([3, 4])
    assert a.refcount(3) == a.refcount(4) == 2
    assert a.free([3, 4]) == ()               # sharers still hold them
    assert a.free([3]) == (3,)
    with pytest.raises(ValueError, match="double free"):
        a.free([3])
    with pytest.raises(ValueError, match="no live reference"):
        a.retain([3])                         # can't revive a dead page
    assert a.free_count + a.live_count == 6   # conservation, always
    assert kvcache.pages_needed(1, 16) == 1
    assert kvcache.pages_needed(16, 16) == 1
    assert kvcache.pages_needed(17, 16) == 2

    pol = kvcache.get_kv_policy("parity-zero")
    cache = kvcache.init_paged_cache(CFG, 2, 32, pol, n_pages=6)
    # parking layout: slot b's whole table row points at page b
    assert (np.asarray(cache["kv_table"][:, 0]) == 0).all()
    assert (np.asarray(cache["kv_table"][:, 1]) == 1).all()
    with pytest.raises(ValueError, match="parking"):
        kvcache.init_paged_cache(CFG, 2, 32, pol, n_pages=2)
    cache = kvcache.set_slot_pages(cache, 1, (4,))
    row = np.asarray(cache["kv_table"][:, 1])
    assert (row[:, 0] == 4).all() and (row[:, 1] == 1).all()  # tail parks
    with pytest.raises(ValueError, match="pages_per_seq"):
        kvcache.set_slot_pages(cache, 0, (2, 3, 4))


def test_kv_bytes_accounting():
    cfg, b, s = CFG, 4, 64
    by = {}
    for scheme in kvcache.KV_SCHEMES:
        pol = kvcache.KVProtectionPolicy(scheme=scheme)
        cache = jax.eval_shape(lambda p=pol: kvcache.init_paged_cache(
            cfg, b, s, p))
        by[scheme] = kvcache.kv_bytes(cache)
    stored = by["in-place"]["stored"]
    assert stored == by["faulty"]["stored"] == by["parity-zero"]["stored"]
    assert by["in-place"]["checks"] == 0          # zero-space: no growth
    assert by["faulty"]["checks"] == 0
    assert by["parity-zero"]["checks"] == stored // 8
    assert kvcache.dense_kv_bytes(cfg, b, s) == 2 * stored  # bf16 vs int8


def test_kv_policy_presets():
    assert set(kvcache.KV_POLICY_PRESETS) == {
        "unprotected", "parity-zero", "in-place",
        "unprotected-fused", "parity-zero-fused", "in-place-fused",
        "unprotected-chunked", "parity-zero-chunked", "in-place-chunked"}
    assert kvcache.get_kv_policy(None) is None
    p = kvcache.get_kv_policy("in-place-fused")
    assert p.scheme == "in-place" and p.fused
    assert p.attention_impl == "strip"
    assert kvcache.get_kv_policy(p) is p
    assert kvcache.get_kv_policy("faulty").scheme == "faulty"  # alias
    c = kvcache.get_kv_policy("in-place-chunked")
    assert c.scheme == "in-place" and c.attention_impl == "chunked"
    with pytest.raises(ValueError, match="unknown KV policy"):
        kvcache.get_kv_policy("triplicate")
    with pytest.raises(ValueError, match="attention_impl"):
        kvcache.KVProtectionPolicy(attention_impl="flash")
    with pytest.raises(ValueError, match="chunk_pages"):
        kvcache.KVProtectionPolicy(chunk_pages=0)


# ---------------------------------------------------------------------------
# plan-level KV knob + serving entry points
# ---------------------------------------------------------------------------


def test_plan_kv_policy_drives_serving(smoke_params):
    """``ProtectionPlan.with_kv_policy`` makes one plan object carry both
    the weight and the serving-state decisions: ``make_serve_step`` /
    ``make_prefill`` default their KV policy from it."""
    (_, params), (cfg, b) = smoke_params("deepseek-7b", 5), (CFG, 2)
    policy = protection.ProtectionPolicy(default_scheme="in-place")
    plan = policy.plan(params).with_kv_policy("in-place")
    assert plan.kv_policy.scheme == "in-place"
    assert plan.summary()["kv_policy"]["scheme"] == "in-place"
    assert plan.summary()["kv_policy"]["attention_impl"] == "strip"
    assert plan.with_act_quant("dynamic").kv_policy is plan.kv_policy

    enc = plan.encode_tree(params)
    cache = kvcache.init_cache(cfg, b, 32, kv_policy=plan.kv_policy)
    step = protected.make_serve_step(cfg, plan=plan, with_flags=True)
    logits, cache, flags = step(enc, cache, jnp.zeros((b, 1), jnp.int32),
                                jnp.zeros((b,), jnp.int32))
    assert logits.shape == (b, 1, cfg.vocab_padded)
    assert flags["layers_kv"].shape == (cfg.n_layers, 2)

    prefill = protected.make_prefill(cfg, plan=plan, with_flags=True)
    cache2 = kvcache.init_cache(cfg, b, 32, kv_policy=plan.kv_policy)
    toks = jnp.zeros((b, 8), jnp.int32)
    logits, cache2, flags = prefill(enc, cache2, toks)
    assert logits.shape == (b, 8, cfg.vocab_padded)
    assert "layers_kv" in flags and "k_pages" in cache2


# ---------------------------------------------------------------------------
# bench artifact: bench_kernels/v6 attention + long-context + ABFT rows
# ---------------------------------------------------------------------------


def test_autotune_attention_rows():
    entry = {"shape": [256, 256], "xla_us": 1.0, "pallas_us": 2.0,
             "best": "xla"}
    row = {"shape": [2, 128, 2, 32], "scheme": "in-place",
           "fused_us": 1.0, "ref_us": 2.0, "bitexact": True}
    long_row = {"shape": [1, 8192, 1, 128], "scheme": "in-place",
                "chunk_tokens": 2048, "chunked_us": 9.0, "strip_us": 8.0,
                "strip_vmem_bytes": 17_000_000, "over_budget": True,
                "oracle_max_abs_err": 1e-3, "tol": 2e-2,
                "within_tol": True}
    xo = {"head_dim": 128, "rep": 2, "vmem_budget_bytes": 16 * 2 ** 20,
          "chunk_tokens": 2048, "tokens_by_scheme": {"in-place": 8113}}
    t = protection.AutotuneTable.from_dict(
        {"schema": "bench_kernels/v5", "platform": "cpu",
         "entries": [entry], "attention": [row],
         "attention_long": [long_row], "crossover": xo})
    assert t.schema == protection.BENCH_KERNELS_SCHEMA_V5 == "bench_kernels/v5"
    assert t.attention == [row]
    assert t.attention_long == [long_row] and t.crossover == xo
    rt = protection.AutotuneTable.from_dict(t.to_dict())
    assert rt.attention == [row] and rt.attention_long == [long_row]
    assert rt.crossover == xo
    # v4 artifacts (attention rows, no long-context section) still load
    v4 = protection.AutotuneTable.from_dict(
        {"schema": protection.BENCH_KERNELS_SCHEMA_V4,
         "entries": [entry], "attention": [row]})
    assert v4.attention == [row] and v4.attention_long == []
    assert v4.crossover is None
    for old in (protection.BENCH_KERNELS_SCHEMA_V1,
                protection.BENCH_KERNELS_SCHEMA_V2,
                protection.BENCH_KERNELS_SCHEMA_V3):
        legacy = protection.AutotuneTable.from_dict(
            {"schema": old, "entries": [entry]})
        assert legacy.attention == [] and legacy.lookup([256, 256]) == "xla"
    with pytest.raises(ValueError, match="unsupported autotune schema"):
        protection.AutotuneTable.from_dict({"schema": "bench_kernels/v9"})

    checked_in = os.path.join(os.path.dirname(__file__), os.pardir,
                              "BENCH_kernels.json")
    shipped = protection.AutotuneTable.from_json(checked_in)
    assert shipped.schema == protection.BENCH_KERNELS_SCHEMA == "bench_kernels/v6"
    assert shipped.attention and all(r["bitexact"] for r in shipped.attention)
    assert shipped.attention_long and shipped.crossover
    assert all(r["within_tol"] for r in shipped.attention_long)
    assert any(r["over_budget"] for r in shipped.attention_long)
    # v6 ABFT twin rows: priced at the winning tiles for reporting, never
    # consulted by the lookups
    assert all(e.get("fused_abft_us") and e.get("fused_int8_abft_us")
               for e in shipped.entries)
