"""ECC codec invariants — unit + hypothesis property tests."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ecc


def wot_blocks(rng, n):
    w = rng.integers(-64, 64, size=(n, 8)).astype(np.int8)
    w[:, 7] = rng.integers(-128, 128, size=n)
    return w


class TestInPlace64:
    def test_code_tables(self):
        # all 64 columns distinct, nonzero, odd weight; check cols = e_i
        cols = ecc.COLS64
        assert len(set(cols.tolist())) == 64
        assert all(bin(int(c)).count("1") % 2 == 1 and c > 0 for c in cols)
        for i in range(7):
            assert cols[i * 8 + ecc.CHECK_BIT] == 1 << i

    def test_roundtrip(self):
        rng = np.random.default_rng(0)
        w = wot_blocks(rng, 2048)
        enc = ecc.encode64(jnp.asarray(w.view(np.uint8)))
        dec, single, double = ecc.decode64(enc)
        assert not bool(single.any()) and not bool(double.any())
        assert (np.asarray(dec).view(np.int8) == w).all()

    def test_every_single_bit_flip_corrected(self):
        rng = np.random.default_rng(1)
        w = wot_blocks(rng, 4)
        enc = np.asarray(ecc.encode64(jnp.asarray(w.view(np.uint8))))
        for g in range(64):
            f = enc.copy()
            f[0, g // 8] ^= np.uint8(1 << (g % 8))
            dec, single, double = ecc.decode64(jnp.asarray(f))
            assert (np.asarray(dec)[0].view(np.int8) == w[0]).all(), g
            assert bool(single[0]) and not bool(double[0])

    def test_every_double_flip_detected_never_miscorrected(self):
        rng = np.random.default_rng(2)
        w = wot_blocks(rng, 1)
        enc = np.asarray(ecc.encode64(jnp.asarray(w.view(np.uint8))))
        pairs = list(itertools.combinations(range(64), 2))
        f = np.repeat(enc, len(pairs), axis=0)
        for i, (g1, g2) in enumerate(pairs):
            f[i, g1 // 8] ^= np.uint8(1 << (g1 % 8))
            f[i, g2 // 8] ^= np.uint8(1 << (g2 % 8))
        dec, single, double = ecc.decode64(jnp.asarray(f))
        assert bool(double.all()) and not bool(single.any())

    def test_sign_restore_matches_wot_semantics(self):
        # any WOT-small byte (in [-64,63]) has bit6 == bit7; encode then
        # decode must reproduce it even though bit6 was overwritten
        vals = np.arange(-64, 64, dtype=np.int8)
        w = np.zeros((len(vals), 8), np.int8)
        w[:, 3] = vals
        enc = ecc.encode64(jnp.asarray(w.view(np.uint8)))
        dec, _, _ = ecc.decode64(enc)
        assert (np.asarray(dec).view(np.int8) == w).all()

    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(0, 63))
    def test_property_single_flip(self, seed, bitpos):
        rng = np.random.default_rng(seed)
        w = wot_blocks(rng, 8)
        enc = np.asarray(ecc.encode64(jnp.asarray(w.view(np.uint8)))).copy()
        enc[3, bitpos // 8] ^= np.uint8(1 << (bitpos % 8))
        dec, single, double = ecc.decode64(jnp.asarray(enc))
        assert (np.asarray(dec).view(np.int8) == w).all()
        assert bool(single[3]) and not bool(double.any())


class TestSecded72:
    def test_roundtrip_and_single_correction(self):
        rng = np.random.default_rng(3)
        data = rng.integers(0, 256, size=(256, 8)).astype(np.uint8)
        chk = ecc.encode72(jnp.asarray(data))
        dec, s, d = ecc.decode72(jnp.asarray(data), chk)
        assert not bool(s.any()) and (np.asarray(dec) == data).all()
        for g in range(0, 64, 7):
            f = data.copy()
            f[0, g // 8] ^= np.uint8(1 << (g % 8))
            dec, s, d = ecc.decode72(jnp.asarray(f), chk)
            assert (np.asarray(dec)[0] == data[0]).all() and bool(s[0])

    def test_check_byte_flip_harmless(self):
        rng = np.random.default_rng(4)
        data = rng.integers(0, 256, size=(16, 8)).astype(np.uint8)
        chk = np.asarray(ecc.encode72(jnp.asarray(data))).copy()
        chk[0] ^= 1  # fault in the stored check byte itself
        dec, s, d = ecc.decode72(jnp.asarray(data), jnp.asarray(chk))
        assert (np.asarray(dec)[0] == data[0]).all()  # data still intact


class TestParity8:
    def test_detect_and_zero(self):
        rng = np.random.default_rng(5)
        data = rng.integers(0, 256, size=(128,)).astype(np.uint8)
        chk = ecc.encode_parity8(jnp.asarray(data))
        f = data.copy()
        f[17] ^= 0x10
        dec, bad = ecc.decode_parity8(jnp.asarray(f), chk)
        assert bool(bad[17]) and int(np.asarray(dec)[17]) == 0
        assert int(np.asarray(bad).sum()) == 1

    def test_double_flip_in_byte_escapes(self):
        # parity limitation (documents why the paper needs SEC-DED)
        rng = np.random.default_rng(6)
        data = rng.integers(0, 256, size=(8,)).astype(np.uint8)
        chk = ecc.encode_parity8(jnp.asarray(data))
        f = data.copy()
        f[2] ^= 0b00000110  # two flips, parity unchanged
        dec, bad = ecc.decode_parity8(jnp.asarray(f), chk)
        assert not bool(bad[2])
