"""Distribution substrate tests: sharding rules, pipeline parallelism,
compressed psum — run in a subprocess with 8 simulated devices."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(__file__))


def _run(body: str, n_dev=8):
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import jax, jax.numpy as jnp, numpy as np
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=dict(os.environ, PYTHONPATH="src"),
                       cwd=ROOT, timeout=600)
    assert r.returncode == 0 and "SUBPROC_OK" in r.stdout, \
        r.stderr[-3000:] + r.stdout[-500:]
    return r.stdout


def test_param_specs_cover_all_archs():
    """Every param leaf of every arch gets a valid PartitionSpec."""
    _run("""
        from repro import configs
        from repro.models import lm
        from repro.distributed import sharding as sh
        for name in configs.ARCH_IDS:
            cfg = configs.get_smoke(name)
            params = lm.param_specs(cfg)
            specs = sh.param_specs(params)
            n = len(jax.tree.leaves(params))
            m = len(jax.tree.leaves(specs, is_leaf=lambda x: x is not None))
            assert jax.tree.structure(params) is not None
    """)


def test_sharded_train_step_runs_on_2x4_mesh():
    """Real (not AOT) sharded execution of the full QATT train step."""
    _run("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models import lm
        from repro.distributed import sharding as sh
        from repro.training import optim, train
        from repro.launch import specs as S
        from repro.models.config import ShapeConfig

        cfg = configs.get_smoke("minitron-4b").with_(microbatch=2)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("t", 32, 8, "train")
        step, args, in_sh, out_sh = S.train_cell(cfg, shape, mesh, chunk=16)
        as_named = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
            t, is_leaf=lambda x: isinstance(x, P))
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = optim.sgd_init(params)
        import numpy as np
        batch = {"tokens": jnp.zeros((32, 8), jnp.int32),
                 "targets": jnp.zeros((32, 8), jnp.int32)}
        with mesh:
            f = jax.jit(step, in_shardings=as_named(in_sh),
                        out_shardings=as_named(out_sh))
            p2, o2, loss = f(params, opt, batch)
        assert np.isfinite(float(loss))
    """)


def test_pipeline_matches_sequential():
    _run("""
        from jax.sharding import Mesh
        from repro.distributed.pipeline import make_pipeline_fn
        n_stages, n_micro, d = 4, 8, 16
        mesh = jax.make_mesh((n_stages,), ("stage",))
        def stage_fn(w, x):
            return jnp.tanh(x @ w)
        ws = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) * 0.5
        xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, 4, d))
        pipe = make_pipeline_fn(stage_fn, n_stages, n_micro, mesh, "stage")
        with mesh:
            out = pipe(ws, xs)
        # sequential reference
        ref = xs
        for s in range(n_stages):
            ref = jax.vmap(lambda x: stage_fn(ws[s], x))(ref)
        import numpy as np
        assert np.max(np.abs(np.asarray(out) - np.asarray(ref))) < 1e-5
    """)


def test_compressed_psum_shard_map():
    _run("""
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.training.compress import compressed_psum
        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
        res = jnp.zeros((8, 128))
        def f(g, r):
            out, nr = compressed_psum(g[0], r[0], "data")
            return out[None], nr[None]
        with mesh:
            out, nr = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                                out_specs=(P("data"), P("data")))(g, res)
        import numpy as np
        mean_ref = np.mean(np.asarray(g), axis=0)
        # all shards got the same (approximate) mean; error feedback holds rest
        got = np.asarray(out)
        for i in range(8):
            assert np.allclose(got[i], mean_ref, atol=np.abs(g).max()/64)
        assert np.allclose(np.asarray(nr).sum(0) + got.sum(0)*0,
                           np.asarray(g - out).sum(0), atol=1e-3)
    """)


@pytest.mark.slow
def test_multipod_mesh_axes():
    _run("""
        import sys
        sys.argv = ["x"]
        from repro.launch.mesh import make_production_mesh
        # 16 devices can't build the real 512 mesh; check axis logic only
        m = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        assert m.axis_names == ("pod", "data", "model")
    """)
