"""Distribution substrate tests: sharding rules, pipeline parallelism,
compressed psum — run in a subprocess with 8 simulated devices."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(__file__))


def _run(body: str, n_dev=8):
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import jax, jax.numpy as jnp, numpy as np
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=dict(os.environ, PYTHONPATH="src"),
                       cwd=ROOT, timeout=600)
    assert r.returncode == 0 and "SUBPROC_OK" in r.stdout, \
        r.stderr[-3000:] + r.stdout[-500:]
    return r.stdout


def test_param_specs_cover_all_archs():
    """Every param leaf of every arch gets a valid PartitionSpec."""
    _run("""
        from repro import configs
        from repro.models import lm
        from repro.distributed import sharding as sh
        for name in configs.ARCH_IDS:
            cfg = configs.get_smoke(name)
            params = lm.param_specs(cfg)
            specs = sh.param_specs(params)
            n = len(jax.tree.leaves(params))
            m = len(jax.tree.leaves(specs, is_leaf=lambda x: x is not None))
            assert jax.tree.structure(params) is not None
    """)


def test_sharded_train_step_runs_on_2x4_mesh():
    """Real (not AOT) sharded execution of the full QATT train step."""
    _run("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models import lm
        from repro.distributed import sharding as sh
        from repro.training import optim, train
        from repro.launch import specs as S
        from repro.models.config import ShapeConfig

        cfg = configs.get_smoke("minitron-4b").with_(microbatch=2)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("t", 32, 8, "train")
        step, args, in_sh, out_sh = S.train_cell(cfg, shape, mesh, chunk=16)
        as_named = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
            t, is_leaf=lambda x: isinstance(x, P))
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = optim.sgd_init(params)
        import numpy as np
        batch = {"tokens": jnp.zeros((32, 8), jnp.int32),
                 "targets": jnp.zeros((32, 8), jnp.int32)}
        with mesh:
            f = jax.jit(step, in_shardings=as_named(in_sh),
                        out_shardings=as_named(out_sh))
            p2, o2, loss = f(params, opt, batch)
        assert np.isfinite(float(loss))
    """)


def test_pipeline_matches_sequential():
    _run("""
        from jax.sharding import Mesh
        from repro.distributed.pipeline import make_pipeline_fn
        n_stages, n_micro, d = 4, 8, 16
        mesh = jax.make_mesh((n_stages,), ("stage",))
        def stage_fn(w, x):
            return jnp.tanh(x @ w)
        ws = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) * 0.5
        xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, 4, d))
        pipe = make_pipeline_fn(stage_fn, n_stages, n_micro, mesh, "stage")
        with mesh:
            out = pipe(ws, xs)
        # sequential reference
        ref = xs
        for s in range(n_stages):
            ref = jax.vmap(lambda x: stage_fn(ws[s], x))(ref)
        import numpy as np
        assert np.max(np.abs(np.asarray(out) - np.asarray(ref))) < 1e-5
    """)


def test_compressed_psum_shard_map():
    _run("""
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.training.compress import compressed_psum
        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
        res = jnp.zeros((8, 128))
        def f(g, r):
            out, nr = compressed_psum(g[0], r[0], "data")
            return out[None], nr[None]
        with mesh:
            out, nr = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                                out_specs=(P("data"), P("data")))(g, res)
        import numpy as np
        mean_ref = np.mean(np.asarray(g), axis=0)
        # all shards got the same (approximate) mean; error feedback holds rest
        got = np.asarray(out)
        for i in range(8):
            assert np.allclose(got[i], mean_ref, atol=np.abs(g).max()/64)
        assert np.allclose(np.asarray(nr).sum(0) + got.sum(0)*0,
                           np.asarray(g - out).sum(0), atol=1e-3)
    """)


def test_plan_spec_tree_flat_padded_sharded_on_2d_mesh():
    """Flat-padded images get a REAL 1-D spec over ('data','model') when
    shards stay block-aligned — and the sharded tree actually decodes
    under jit with those in_shardings (the old path replicated every flat
    image)."""
    _run("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import protection
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        def wotp(shape):
            q = rng.integers(-64, 64, size=int(np.prod(shape))).astype(np.int8)
            q.reshape(-1)[7::8] = rng.integers(-127, 128, size=q.reshape(-1)[7::8].size)
            q.reshape(-1)[7] = 127
            return jnp.asarray(q.reshape(shape).astype(np.float32) * 0.01)
        params = {"wq": wotp((16, 64)),      # same-shape image
                  "odd": wotp((32, 18)),     # flat 576 = 8 blocks/shard x 8 shards
                  "tiny": wotp((3, 5))}      # flat 16: not block-divisible by 8 shards
        policy = protection.ProtectionPolicy(
            predicate=lambda p, l: getattr(l, "ndim", 0) >= 2)
        plan = policy.plan(params, mesh=mesh,
                           param_spec_fn=lambda p, l: P("data", "model"))
        enc = plan.encode_tree(params)
        specs = plan.spec_tree(enc)
        assert specs["wq"].enc == P("data", "model"), specs["wq"].enc
        assert specs["odd"].enc == P(("data", "model")), specs["odd"].enc
        assert specs["tiny"].enc == P(), specs["tiny"].enc
        assert specs["odd"].scale == P()
        assert plan["odd"].flat_sharded and not plan["tiny"].flat_sharded
        assert plan.summary()["n_flat_sharded"] == 1
        # the module-level helper agrees when handed the mesh
        legacy = protection.spec_tree(enc, lambda p, l: P("data", "model"),
                                      mesh=mesh)
        assert legacy["odd"].enc == P(("data", "model"))
        # and the sharded tree really decodes under jit
        as_named = jax.tree.map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
            specs, is_leaf=lambda x: isinstance(x, P))
        with mesh:
            f = jax.jit(lambda e: plan.decode_tree(e, jnp.float32),
                        in_shardings=(as_named,))
            dec = f(enc)
        for k in params:
            assert np.array_equal(np.asarray(dec[k]), np.asarray(params[k])), k
    """)


def test_decode_cell_espec_and_logits_spec_on_small_mesh():
    """decode_cell is plan-driven: espec comes from the materialized plan,
    and the logits out-sharding keys off the REAL mesh data-axis size (the
    old hard-coded `b % 16` broke any non-16 mesh)."""
    _run("""
        from jax.sharding import PartitionSpec as P
        from repro import configs, protection
        from repro.launch import specs as S
        from repro.models.config import ShapeConfig
        from repro.protection import is_protected_tensor

        cfg = configs.get_smoke("qwen1.5-4b")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("d", 64, 8, "decode")   # b=8: 8 % 2 == 0
        policy = protection.get_policy_preset("attn-inplace-mlp-secded")
        step, args, in_sh, out_sh = S.decode_cell(cfg, shape, mesh,
                                                  policy=policy)
        assert out_sh[0] == P("data", None, "model"), out_sh[0]
        enc_specs = [l for l in jax.tree.leaves(
            in_sh[0], is_leaf=is_protected_tensor) if is_protected_tensor(l)]
        assert enc_specs, "espec lost its ProtectedTensor structure"
        sids = {l.scheme_id for l in enc_specs}
        assert sids == {"in-place", "secded72"}, sids

        shape3 = ShapeConfig("d3", 64, 3, "decode")  # b=3: 3 % 2 != 0
        _, _, _, out_sh3 = S.decode_cell(cfg, shape3, mesh, policy=policy)
        assert out_sh3[0] == P(None, None, "model"), out_sh3[0]
    """)


@pytest.mark.slow
def test_multipod_mesh_axes():
    _run("""
        import sys
        sys.argv = ["x"]
        from repro.launch.mesh import make_production_mesh
        # 16 devices can't build the real 512 mesh; check axis logic only
        m = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        assert m.axis_names == ("pod", "data", "model")
    """)
