"""``repro.protection.plan`` — materialized per-leaf protection decisions:
summary-vs-CoverageReport byte agreement, mixed scheme+backend trees,
backend resolution order (rule > autotune > policy), preset policies, and
the plan-driven serving step."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, protection
from repro.models import lm
from repro.serving import protected


def wot_params(rng, shape=(16, 64)):
    """fp32 weights that quantize exactly back to a WOT-compliant q."""
    q = rng.integers(-64, 64, size=int(np.prod(shape))).astype(np.int8)
    q.reshape(-1)[7::8] = rng.integers(-127, 128, size=q[7::8].size)
    q.reshape(-1)[7] = 127
    return jnp.asarray(q.reshape(shape).astype(np.float32) * 0.01)


PRED = lambda p, l: getattr(l, "ndim", 0) >= 2


# ---------------------------------------------------------------------------
# materialization + accounting
# ---------------------------------------------------------------------------


def test_plan_summary_matches_coverage_report_byte_for_byte():
    """The acceptance contract: plan.summary() and CoverageReport agree on
    every byte count, on a real arch tree with mixed schemes."""
    cfg = configs.get_smoke("minitron-4b")
    abstract = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32))
    policy = protection.get_policy_preset("attn-inplace-mlp-secded")
    plan = policy.plan(abstract)
    rep = policy.coverage(abstract)
    s = plan.summary()
    assert s["protected_bytes"] == rep.protected_bytes
    assert s["unprotected_bytes"] == rep.unprotected_bytes
    assert s["pad_bytes"] == rep.pad_bytes
    assert s["n_protected"] == rep.n_protected
    assert s["n_unprotected"] == rep.n_unprotected
    assert {k: v["n_tensors"] for k, v in s["by_scheme"].items()} == \
        rep.by_scheme()
    # the preset actually mixes schemes on an LM tree
    assert set(s["by_scheme"]) == {"in-place", "secded72"}
    # per-scheme stored bytes partition the total
    assert sum(v["stored_bytes"] for v in s["by_scheme"].values()) == \
        s["protected_bytes"]
    # secded72 leaves store 12.5% checks; in-place stores zero extra
    sd = s["by_scheme"]["secded72"]
    ip = s["by_scheme"]["in-place"]
    assert sd["check_bytes"] == (sd["weight_bytes"] + sd["pad_bytes"]) // 8
    assert ip["stored_bytes"] == ip["weight_bytes"] + ip["pad_bytes"]


def test_plan_is_coverage_report_source():
    """CoverageReport is a thin view: plan.coverage() entries equal the
    policy's report exactly (order, reasons, bytes)."""
    rng = np.random.default_rng(0)
    params = {"wq": wot_params(rng), "odd": wot_params(rng, (6, 13)),
              "norm": jnp.ones((64,), jnp.float32)}
    policy = protection.ProtectionPolicy(predicate=PRED)
    assert policy.plan(params).coverage().entries == \
        policy.coverage(params).entries


def test_plan_encode_decode_mixed_schemes_and_backends():
    rng = np.random.default_rng(1)
    params = {"attn": {"wq": wot_params(rng)},
              "mlp": {"w_up": wot_params(rng)},
              "odd": wot_params(rng, (32, 18))}
    policy = protection.ProtectionPolicy(
        rules=[("mlp/", "secded72")],
        backend_rules=[("attn/", "pallas")], predicate=PRED)
    plan = policy.plan(params)
    assert plan["attn/wq"].scheme_id == "in-place"
    assert plan["attn/wq"].backend == "pallas"
    assert plan["attn/wq"].backend_src == "rule"
    assert plan["mlp/w_up"].scheme_id == "secded72"
    assert plan["mlp/w_up"].backend == "xla"
    assert plan["mlp/w_up"].backend_src == "policy"
    assert plan["odd"].layout == "flat-padded"
    assert plan["odd"].enc_shape == (576,)

    enc = plan.encode_tree(params)
    assert enc["attn"]["wq"].scheme_id == "in-place"
    assert enc["mlp"]["w_up"].checks is not None
    dec = plan.decode_tree(enc, jnp.float32)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(dec)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # the plan path is what policy.encode_tree/decode_tree now run
    dec2 = policy.decode_tree(policy.encode_tree(params), jnp.float32)
    for a, b in zip(jax.tree.leaves(dec), jax.tree.leaves(dec2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_plan_rejects_mismatched_tree():
    rng = np.random.default_rng(2)
    plan = protection.ProtectionPolicy(predicate=PRED).plan(
        {"w": wot_params(rng)})
    with pytest.raises(KeyError, match="not in this ProtectionPlan"):
        plan.encode_tree({"other": wot_params(rng)})


# ---------------------------------------------------------------------------
# backend resolution: rule > autotune > policy default
# ---------------------------------------------------------------------------


def _table():
    return protection.AutotuneTable(
        entries=[{"shape": [16, 64], "xla_us": 2.0, "pallas_us": 1.0,
                  "best": "pallas"},
                 {"shape": [512, 512], "xla_us": 1.0, "pallas_us": 9.0,
                  "best": "xla"}])


def test_backend_resolution_order():
    policy = protection.ProtectionPolicy(
        backend_rules=[("special", "xla")], autotune=_table(), predicate=PRED)
    be, src = policy.resolve_backend("special/w", (16, 64))
    assert (be.name, src) == ("xla", "rule")          # rule beats autotune
    be, src = policy.resolve_backend("blk/w", (16, 64))
    assert (be.name, src) == ("pallas", "autotune")   # exact shape hit
    be, src = policy.resolve_backend("blk/w", (4096, 8192))
    assert (be.name, src) == ("xla", "policy")        # too far from any entry


def test_autotune_nearest_nblocks_fallback():
    t = _table()
    assert t.lookup((16, 64)) == "pallas"
    assert t.lookup((8, 128)) == "pallas"    # same 128 blocks, other shape
    assert t.lookup((512, 520)) == "xla"     # near the 32768-block entry
    assert t.lookup((65536, 8192)) is None   # >4x from everything


def test_autotune_table_bench_kernels_roundtrip(tmp_path):
    payload = {"schema": protection.BENCH_KERNELS_SCHEMA, "platform": "cpu",
               "entries": [{"shape": [256, 256], "xla_us": 10.0,
                            "pallas_us": 5.0, "best": "pallas"}]}
    p = tmp_path / "BENCH_kernels.json"
    p.write_text(json.dumps(payload))
    t = protection.AutotuneTable.from_json(p)
    assert t.lookup((256, 256)) == "pallas"
    assert t.to_dict()["schema"] == protection.BENCH_KERNELS_SCHEMA
    # a policy accepts the path directly
    pol = protection.ProtectionPolicy(autotune=str(p), predicate=PRED)
    assert pol.resolve_backend("w", (256, 256))[0].name == "pallas"
    with pytest.raises(ValueError, match="schema"):
        protection.AutotuneTable.from_dict({"schema": "bogus/v9"})
    with pytest.raises(ValueError, match="unknown best backend"):
        protection.AutotuneTable(entries=[{"shape": [8, 8], "best": "tpu"}])


def test_checked_in_bench_kernels_artifact_loads():
    """BENCH_kernels.json in the repo root is valid autotune input."""
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "BENCH_kernels.json")
    t = protection.AutotuneTable.from_json(path)
    assert len(t) >= 3
    for e in t.entries:
        assert e["best"] in ("xla", "pallas")
        assert e["nblocks"] == int(np.prod(e["shape"])) // 8


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------


def test_policy_presets_materialize_on_lm_tree():
    cfg = configs.get_smoke("qwen1.5-4b")
    abstract = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32))
    seen = {}
    for name in protection.POLICY_PRESETS:
        plan = protection.get_policy_preset(name).plan(abstract)
        seen[name] = plan.summary()
    assert set(seen["all-in-place"]["by_scheme"]) == {"in-place"}
    assert set(seen["all-secded72"]["by_scheme"]) == {"secded72"}
    assert set(seen["unprotected"]["by_scheme"]) == {"faulty"}
    assert set(seen["attn-inplace-mlp-secded"]["by_scheme"]) == \
        {"in-place", "secded72"}
    # zero-space story: in-place and faulty store the same bytes,
    # secded72 stores 12.5% more
    ip, un = seen["all-in-place"], seen["unprotected"]
    sd = seen["all-secded72"]
    assert ip["protected_bytes"] == un["protected_bytes"]
    assert sd["protected_bytes"] > ip["protected_bytes"]
    with pytest.raises(ValueError, match="unknown policy preset"):
        protection.get_policy_preset("everything-bagel")


# ---------------------------------------------------------------------------
# plan-driven serving (the acceptance end-to-end)
# ---------------------------------------------------------------------------


def test_serve_step_from_plan_mixed_scheme_mixed_backend():
    """One model tree, two schemes, two backends, one jitted serve step —
    logits match the homogeneous all-xla in-place serve bit-for-bit (all
    schemes round-trip the same throttled int8 weights at rate 0)."""
    cfg = configs.get_smoke("minitron-4b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    policy = protection.get_policy_preset(
        "attn-inplace-mlp-secded",
        backend_rules=[(r"(^|/)(wq|wk|wv)($|/)", "pallas")])
    plan = protected.make_plan(params, policy)
    s = plan.summary()
    assert len(s["by_scheme"]) == 2 and len(s["by_backend"]) == 2
    assert s == protected.make_plan(params, policy).summary()  # deterministic
    # summary vs CoverageReport byte-for-byte (acceptance wording)
    rep = protection.coverage(params, policy)
    assert s["protected_bytes"] == rep.protected_bytes
    assert s["unprotected_bytes"] == rep.unprotected_bytes

    enc = plan.encode_tree(params)
    serve = jax.jit(protected.make_serve_step(cfg, plan=plan))
    cache = lm.init_cache(cfg, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    logits, _ = serve(enc, cache, tok, pos)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    ref_policy = protection.ProtectionPolicy()
    ref_enc = ref_policy.encode_tree(params)
    ref_serve = jax.jit(protected.make_serve_step(cfg))
    ref_logits, _ = ref_serve(ref_enc, cache, tok, pos)
    assert np.array_equal(np.asarray(logits, np.float32),
                          np.asarray(ref_logits, np.float32))


def test_prefill_from_plan():
    cfg = configs.get_smoke("qwen1.5-4b")
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    policy = protection.get_policy_preset("attn-inplace-mlp-secded")
    plan = protected.make_plan(params, policy)
    enc = plan.encode_tree(params)
    prefill = jax.jit(protected.make_prefill(cfg, plan=plan, chunk=16))
    logits = prefill(enc, jnp.zeros((2, 16), jnp.int32), {})
    assert np.isfinite(np.asarray(logits, np.float32)).all()


# ---------------------------------------------------------------------------
# import hygiene (satellite: dryrun must not clobber the environment)
# ---------------------------------------------------------------------------


def test_dryrun_import_is_env_clean():
    prog = ("import os; os.environ.pop('XLA_FLAGS', None); "
            "import repro.launch.dryrun as d; "
            "assert 'XLA_FLAGS' not in os.environ, os.environ['XLA_FLAGS']; "
            "d.setup_host_devices(8); "
            "assert 'device_count=8' in os.environ['XLA_FLAGS']; "
            "print('CLEAN')")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=dict(os.environ, PYTHONPATH="src"),
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0 and "CLEAN" in r.stdout, r.stderr[-2000:]
