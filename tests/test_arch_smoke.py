"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import cnn, lm
from repro.training import optim, train


def _batch(cfg, b=2, s=32):
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_padded)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.zeros((b, cfg.n_patches, cfg.d_model),
                                           jnp.bfloat16)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch).with_(microbatch=2)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, b=2, s=32)

    logits = lm.forward(cfg, params, batch["tokens"],
                        prefix_embeds=batch.get("prefix_embeds"),
                        enc_embeds=batch.get("enc_embeds"), chunk=16)
    s_out = 32 + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_out, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    step = jax.jit(train.make_train_step(cfg, lr=1e-3, chunk=16))
    opt = optim.sgd_init(params)
    p2, opt2, loss = step(params, opt, batch)
    assert np.isfinite(float(loss))
    # params actually changed
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))), params, p2))
    assert moved > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_decode_step(arch):
    cfg = configs.get_smoke(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b = 2
    cache = lm.init_cache(cfg, b, 64)
    logits, cache2 = jax.jit(
        lambda p, c, t, po: lm.decode_step(cfg, p, c, t, po))(
        params, cache, jnp.zeros((b, 1), jnp.int32),
        jnp.full((b,), 3, jnp.int32))
    assert logits.shape == (b, 1, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", configs.CNN_IDS)
def test_cnn_smoke(name):
    init, fwd = cnn.CNNS[name]
    params = init(jax.random.PRNGKey(0), n_classes=10, scale=0.125,
                  img_size=32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits = fwd(params, x)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """Pin the exact assigned hyperparameters of the FULL configs."""
    spec = {
        "paligemma-3b": dict(n_layers=18, d_model=2048, n_heads=8,
                             n_kv_heads=1, d_ff=16384, vocab=257216),
        "minitron-4b": dict(n_layers=32, d_model=3072, n_heads=24,
                            n_kv_heads=8, d_ff=9216, vocab=256000),
        "phi3-medium-14b": dict(n_layers=40, d_model=5120, n_heads=40,
                                n_kv_heads=10, d_ff=17920, vocab=100352),
        "qwen1.5-4b": dict(n_layers=40, d_model=2560, n_heads=20,
                           n_kv_heads=20, d_ff=6912, vocab=151936,
                           qkv_bias=True),
        "deepseek-7b": dict(n_layers=30, d_model=4096, n_heads=32,
                            n_kv_heads=32, d_ff=11008, vocab=102400),
        "mamba2-2.7b": dict(n_layers=64, d_model=2560, vocab=50280,
                            ssm_state=128),
        "whisper-base": dict(n_layers=6, d_model=512, n_heads=8,
                             n_kv_heads=8, d_ff=2048, vocab=51865,
                             enc_layers=6),
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128,
                                 vocab=102400, n_experts=160, top_k=6,
                                 n_shared_experts=2, moe_d_ff=1536,
                                 use_mla=True, kv_lora_rank=512),
        "deepseek-v3-671b": dict(n_layers=61, d_model=7168, n_heads=128,
                                 vocab=129280, n_experts=256, top_k=8,
                                 n_shared_experts=1, moe_d_ff=2048,
                                 use_mla=True, kv_lora_rank=512,
                                 q_lora_rank=1536),
        "recurrentgemma-2b": dict(n_layers=26, d_model=2560, n_heads=10,
                                  n_kv_heads=1, d_ff=7680, vocab=256000,
                                  attn_window=2048),
    }[arch]
    cfg = configs.get(arch)
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
