"""Flash attention / ecc_encode / quantize_throttle Pallas kernels vs refs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ecc, quant, wot
from repro.kernels.ecc_encode import ecc_encode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant_throttle import quantize_throttle


def _naive_causal(q, k, v):
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(q.shape[-1])
    mask = jnp.tril(jnp.ones((q.shape[2],) * 2, bool))
    s = jnp.where(mask, s, -jnp.inf)
    return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)


@pytest.mark.parametrize("b,h,s,d,bq,bk", [
    (1, 2, 128, 32, 64, 64),
    (2, 2, 256, 64, 128, 64),
    (1, 1, 128, 128, 128, 128),
])
def test_flash_attention_sweep(b, h, s, d, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(s + d), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d)) for kk in ks)
    out = flash_attention(q, k, v, bq=bq, bk=bk)
    ref = _naive_causal(q, k, v)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (1, 2, 128, 64), jnp.bfloat16)
               for kk in ks)
    out = flash_attention(q, k, v, bq=64, bk=64)
    ref = _naive_causal(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32))
    assert out.dtype == jnp.bfloat16
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref))) < 0.05


@pytest.mark.parametrize("nblk", [64, 4096, 8192])
def test_ecc_encode_matches_ref(nblk):
    rng = np.random.default_rng(nblk)
    w = rng.integers(-64, 64, size=(nblk, 8)).astype(np.int8)
    w[:, 7] = rng.integers(-128, 128, size=nblk)
    blocks = jnp.asarray(w.view(np.uint8))
    enc_k = ecc_encode(blocks, blk_n=min(nblk, 2048))
    enc_r = ecc.encode64(blocks)
    assert (np.asarray(enc_k) == np.asarray(enc_r)).all()
    # and the kernel-encoded image decodes back to the original weights
    dec, single, double = ecc.decode64(enc_k)
    assert (np.asarray(dec).view(np.int8) == w).all()
    assert not bool(single.any())


@pytest.mark.parametrize("nblk", [512, 4096])
def test_quantize_throttle_matches_deploy_path(nblk):
    rng = np.random.default_rng(nblk)
    w = jnp.asarray(rng.normal(size=(nblk, 8)).astype(np.float32) * 3)
    q_k, scale_k = quantize_throttle(w, blk=min(nblk, 1024))
    q_r, scale_r = quant.quantize(w)
    q_r = wot.throttle_q(q_r.reshape(-1)).reshape(w.shape)
    assert float(jnp.abs(scale_k - scale_r)) < 1e-9
    assert (np.asarray(q_k) == np.asarray(q_r)).all()
    assert wot.satisfies_constraint(jnp.asarray(np.asarray(q_k).reshape(-1)))


@pytest.mark.parametrize("nblk,blk", [(5000, 4096), (100, 64), (4097, 4096),
                                      (65, 64)])
def test_quantize_throttle_non_divisible_edge_block(nblk, blk):
    """Regression: arbitrary leaf sizes quantize without host-side padding —
    the old nblk % blk == 0 assert rejected any leaf that wasn't a tile
    multiple. The cdiv grid's masked edge block must neither corrupt the
    absmax (garbage rows zeroed) nor the quantized tail."""
    rng = np.random.default_rng(nblk)
    w = jnp.asarray(rng.normal(size=(nblk, 8)).astype(np.float32) * 2)
    q_k, scale_k = quantize_throttle(w, blk=blk)
    q_r, scale_r = quant.quantize(w)
    q_r = wot.throttle_q(q_r.reshape(-1)).reshape(w.shape)
    assert float(jnp.abs(scale_k - scale_r)) < 1e-9
    assert (np.asarray(q_k) == np.asarray(q_r)).all()


def test_ops_deploy_pipeline_end_to_end():
    """deploy_quantize -> encode_weights -> decode_weights wrappers chain."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32) * 2)
    q, scale = ops.deploy_quantize(w)
    assert wot.satisfies_constraint(jnp.asarray(np.asarray(q).reshape(-1)))
    enc = ops.encode_weights(q.reshape(-1))
    dec, flags = ops.decode_weights(enc)
    assert (np.asarray(dec) == np.asarray(q).reshape(-1)).all()
    assert not np.asarray(flags).any()


def test_ops_attention_wrapper():
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (jax.random.normal(kk, (1, 2, 128, 32)) for kk in ks)
    out = ops.attention(q, k, v, bq=64, bk=64)
    ref = _naive_causal(q, k, v)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5
