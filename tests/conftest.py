"""Session-scoped model/plan fixtures shared across the serving tests.

``test_decode_at_use.py``, ``test_int8_serving.py``, and
``test_kvcache.py`` each used to rebuild the same smoke models (and in
one case train one) per test; these fixtures build each (arch, seed) /
(arch, backend, seed) combination once per session.

Mutation safety: several tests mutate the returned trees in place
(``enc["layers"]["attn"]["wq"] = ...``), so every fixture hands back a
FRESH container tree (new dicts/lists at every level) over shared
immutable leaves (jax arrays, frozen ``ProtectedTensor`` dataclasses) —
cheap to copy, impossible to cross-contaminate.
"""
import jax
import pytest

from repro import configs, protection
from repro.models import lm
from repro.serving import protected


def _copy_tree(t):
    """Fresh dict/list containers, shared immutable leaves."""
    if isinstance(t, dict):
        return {k: _copy_tree(v) for k, v in t.items()}
    if isinstance(t, list):
        return [_copy_tree(v) for v in t]
    if isinstance(t, tuple):
        return tuple(_copy_tree(v) for v in t)
    return t


@pytest.fixture(scope="session")
def smoke_params():
    """``get(arch, seed=0) -> (cfg, params)``: memoized smoke-config
    weight init. Distinct seeds stay distinct — tests that deliberately
    vary the init keep their draws."""
    memo = {}

    def get(arch, seed=0):
        key = (arch, seed)
        if key not in memo:
            cfg = configs.get_smoke(arch)
            memo[key] = (cfg, lm.init_params(cfg,
                                             jax.random.PRNGKey(seed)))
        cfg, params = memo[key]
        return cfg, _copy_tree(params)

    return get


@pytest.fixture(scope="session")
def plan_setup(smoke_params):
    """``get(arch, backend, seed) -> (cfg, plan, enc)``: memoized
    default-policy plan + encoded tree (the ``_setup`` previously local
    to test_int8_serving)."""
    memo = {}

    def get(arch="minitron-4b", backend="pallas", seed=0):
        key = (arch, backend, seed)
        if key not in memo:
            cfg, params = smoke_params(arch, seed)
            policy = protection.ProtectionPolicy(backend=backend)
            plan = protected.make_plan(params, policy)
            memo[key] = (cfg, plan, plan.encode_tree(params))
        cfg, plan, enc = memo[key]
        return cfg, plan, _copy_tree(enc)

    return get


@pytest.fixture(scope="session")
def trained_minitron(smoke_params):
    """(cfg, params) for the minitron-4b smoke config after 4 SGD steps —
    the trained-model substrate for the serve-identity acceptances
    (previously retrained inside each parametrized test)."""
    from repro.data import synthetic
    from repro.training import optim, train
    import jax.numpy as jnp

    cfg, params = smoke_params("minitron-4b")
    cfg = cfg.with_(microbatch=2)
    opt = optim.sgd_init(params)
    step = jax.jit(train.make_train_step(cfg, lr=5e-3, chunk=16))
    for s in range(4):
        b = synthetic.token_batch(cfg.vocab_padded, 2, 32, seed=5, step=s)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, _ = step(params, opt, b)

    def get():
        return cfg, _copy_tree(params)

    return get
