"""Numerics: chunked attention vs naive, SSD chunked vs sequential,
prefill-vs-decode agreement for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers as L
from repro.models import lm


def test_chunked_attention_matches_naive():
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(jax.random.PRNGKey(i), (2, 3, 72, 16))
               for i in range(3))
    out = L.chunked_causal_attention(q, k, v, chunk=32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(16)
    s = jnp.where(jnp.tril(jnp.ones((72, 72), bool)), s, -jnp.inf)
    ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_window_attention_matches_naive():
    q, k, v = (jax.random.normal(jax.random.PRNGKey(i), (1, 2, 96, 16))
               for i in range(3))
    out = L.chunked_causal_attention(q, k, v, chunk=24, window=24)
    pos = jnp.arange(96)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - 24)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(16)
    s = jnp.where(mask, s, -jnp.inf)
    ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_ssd_chunked_matches_sequential():
    """Mamba2 SSD chunked scan == naive per-step recurrence."""
    rng = np.random.default_rng(0)
    b, l, h, p, n = 2, 64, 4, 8, 16
    x = jnp.asarray(rng.normal(size=(b, l, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.1, 0.5, size=(b, l, h)).astype(np.float32))
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(b, l, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, l, n)).astype(np.float32))

    y_chunk, s_chunk = L._ssd_chunked(x, dt, A, B, C, chunk=16)

    # sequential reference
    s = np.zeros((b, h, p, n), np.float32)
    ys = []
    for t in range(l):
        da = np.exp(np.asarray(dt[:, t]) * np.asarray(A))  # (b,h)
        upd = np.einsum("bh,bhp,bn->bhpn", np.asarray(dt[:, t]),
                        np.asarray(x[:, t]), np.asarray(B[:, t]))
        s = s * da[:, :, None, None] + upd
        ys.append(np.einsum("bhpn,bn->bhp", s, np.asarray(C[:, t])))
    y_ref = np.stack(ys, axis=1)
    assert np.max(np.abs(np.asarray(y_chunk) - y_ref)) < 2e-4
    assert np.max(np.abs(np.asarray(s_chunk) - s)) < 2e-4


def test_rglru_scan_matches_sequential():
    rng = np.random.default_rng(1)
    b, l, w = 2, 32, 8
    x = jnp.asarray(rng.normal(size=(b, l, w)).astype(np.float32))
    ig = jnp.asarray(rng.uniform(0, 1, size=(b, l, w)).astype(np.float32))
    ag = jnp.asarray(rng.normal(size=(b, l, w)).astype(np.float32))
    ap = jnp.asarray(rng.uniform(1, 2, size=(w,)).astype(np.float32))
    h = L._rglru_scan(x, ig, ag, ap)
    # sequential
    log_a = -L._C_RGLRU * jax.nn.softplus(ap) * jax.nn.sigmoid(ag)
    a = np.exp(np.asarray(log_a))
    bt = np.sqrt(np.maximum(1 - a * a, 1e-12)) * np.asarray(ig * x)
    hh = np.zeros((b, w), np.float32)
    outs = []
    for t in range(l):
        hh = a[:, t] * hh + bt[:, t]
        outs.append(hh.copy())
    ref = np.stack(outs, axis=1)
    assert np.max(np.abs(np.asarray(h) - ref)) < 1e-5


@pytest.mark.parametrize("arch", ["deepseek-7b", "mamba2-2.7b",
                                  "recurrentgemma-2b", "qwen1.5-4b",
                                  "minitron-4b", "phi3-medium-14b"])
def test_prefill_decode_agree(arch):
    cfg = configs.get_smoke(arch).with_(remat=False, capacity_factor=8.0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_padded)
    full = lm.forward(cfg, params, tokens, dtype=jnp.float32, chunk=8)
    cache = lm.init_cache(cfg, B, 32, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, po: lm.decode_step(cfg, p, c, t, po,
                                                      dtype=jnp.float32))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, tokens[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - full))) < 1e-3
