"""Self-healing serving: background scrub, MILR repair, rolling plan
migration, and the v2 healing telemetry.

Layers of coverage:

* scrubber unit tests — write-back is bit-exact, clean leaves are
  no-ops, DUE leaves are never rewritten, budget cursors cover the whole
  tree round-robin, KV page scrub respects the busy set;
* the error-accumulation story — correctable singles pile up into DUEs
  without scrub, never with a per-round scrub;
* MILR repair — bit-exact row reconstruction from pinned (x, y)
  calibration, quarantine when the solve is under-determined, and the
  clean-tree precondition on kit pinning;
* plan diff / rolling migration — value-exact transcode mid-traffic with
  recompiles bounded by the promotion count;
* the end-to-end acceptance — a faulted serve loop (KV + weights at
  1e-3) drains with zero residual at-rest DUE and the healed tree
  produces logits bit-exact with the never-faulted twin;
* telemetry v2 — the ``healing`` roll-up, wall-field-free healing
  events, and v1 summary compatibility through ``load_summary``.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import protection
from repro.protection import repair
from repro.serving import frontend, kvcache, protected, scrubber, telemetry


def _flip(pt, idx, mask=0x01):
    """One bit-flip in a leaf's stored image (new frozen leaf)."""
    return dataclasses.replace(
        pt, enc=pt.enc.at[idx].set(pt.enc[idx] ^ np.uint8(mask)))


def _small_tree(seed=0, shapes=((16, 24), (24, 16), (16, 16))):
    """A tiny all-in-place-protected dict tree + its encoded twin."""
    rng = np.random.default_rng(seed)
    params = {f"w{i}": jnp.asarray(
        rng.integers(-50, 50, size=s).astype(np.float32) / 64.0)
        for i, s in enumerate(shapes)}
    policy = protection.ProtectionPolicy(
        predicate=lambda p, l: getattr(l, "ndim", 0) >= 2)
    enc = policy.encode_tree(params)
    return params, policy, enc


# ---------------------------------------------------------------------------
# scrubber: write-back semantics
# ---------------------------------------------------------------------------


def test_scrub_corrects_single_flip_bitexact():
    _, _, enc = _small_tree()
    clean = np.asarray(enc["w0"].enc).copy()
    enc["w0"] = _flip(enc["w0"], (3, 5))
    healed, stats = scrubber.scrub_tree(enc)
    assert stats["corrected"] >= 1 and stats["due"] == 0
    assert stats["scanned"] == stats["wrote"] == 3
    assert np.array_equal(np.asarray(healed["w0"].enc), clean)


def test_scrub_clean_tree_is_bit_level_noop():
    _, _, enc = _small_tree()
    before = {k: np.asarray(v.enc).copy() for k, v in enc.items()}
    healed, stats = scrubber.scrub_tree(enc)
    assert stats["corrected"] == 0 and stats["due"] == 0
    for k in enc:
        assert np.array_equal(np.asarray(healed[k].enc), before[k])


def test_scrub_never_writes_back_a_due_leaf():
    """Two hits in one 8-byte block -> DUE; re-encoding would recompute
    checks consistent with the corruption, so the scrubber must leave the
    bytes EXACTLY as it found them and report the leaf instead."""
    _, _, enc = _small_tree()
    dirty = _flip(_flip(enc["w1"], (0, 0), 0x01), (0, 1), 0x01)
    enc["w1"] = dirty
    dirty_bytes = np.asarray(dirty.enc).copy()
    healed, stats = scrubber.scrub_tree(enc)
    assert stats["due"] > 0
    assert stats["due_paths"] == ["w1"]
    assert stats["wrote"] == 2                     # the other two leaves
    assert np.array_equal(np.asarray(healed["w1"].enc), dirty_bytes)


def test_scrub_budget_cursor_covers_tree_round_robin():
    _, _, enc = _small_tree()
    cleans = {k: np.asarray(v.enc).copy() for k, v in enc.items()}
    for i, k in enumerate(enc):
        enc[k] = _flip(enc[k], (1, i))
    s = scrubber.Scrubber(leaves_per_step=1)
    total = 0
    for _ in range(3):                             # 3 calls x 1 leaf each
        enc, stats = s.scrub_weights(enc)
        assert stats["scanned"] == 1
        total += stats["corrected"]
    assert total == 3
    for k in enc:
        assert np.array_equal(np.asarray(enc[k].enc), cleans[k])


# ---------------------------------------------------------------------------
# scrubber: KV pages
# ---------------------------------------------------------------------------


@pytest.fixture()
def kv_rig(smoke_params):
    cfg, _ = smoke_params("deepseek-7b")
    kvp = kvcache.get_kv_policy("in-place")
    cache = kvcache.init_paged_cache(cfg, batch=2, max_len=32,
                                     policy=kvp, n_pages=6)
    return cfg, kvp, cache


def test_kv_scrub_corrects_live_page_and_skips_busy(kv_rig):
    _, kvp, cache = kv_rig
    pid = 3
    clean = np.asarray(cache["k_pages"][:, pid]).copy()
    cache["k_pages"] = cache["k_pages"].at[0, pid, 0, 0, 0].set(
        cache["k_pages"][0, pid, 0, 0, 0] ^ np.uint8(2))
    s = scrubber.Scrubber(pages_per_step=4)
    # busy pages are untouchable this pass
    skipped, stats = s.scrub_kv(cache, kvp, occupied=(pid,), busy=(pid,))
    assert stats["scanned"] == 0
    assert np.asarray(skipped["k_pages"][0, pid, 0, 0, 0]) != clean[0, 0, 0, 0]
    # off the busy list the flip is corrected and written back bit-exactly
    healed, stats = s.scrub_kv(cache, kvp, occupied=(pid,))
    assert stats["scanned"] == 1 and stats["corrected"] >= 1
    assert stats["due"] == 0
    assert np.array_equal(np.asarray(healed["k_pages"][:, pid]), clean)


def test_kv_scrub_skips_due_slab(kv_rig):
    _, kvp, cache = kv_rig
    pid = 1
    for d in (0, 1):                       # two hits, one 8-byte block
        cache["k_pages"] = cache["k_pages"].at[0, pid, 0, 0, d].set(
            cache["k_pages"][0, pid, 0, 0, d] ^ np.uint8(1))
    dirty = np.asarray(cache["k_pages"][0, pid]).copy()
    s = scrubber.Scrubber()
    healed, stats = s.scrub_kv(cache, kvp, occupied=(pid,), n=-1)
    assert stats["due"] > 0 and stats["due_slabs"] >= 1
    assert np.array_equal(np.asarray(healed["k_pages"][0, pid]), dirty)


def test_scrub_free_re_zeroes_even_due_patterns(kv_rig):
    _, kvp, cache = kv_rig
    alloc = kvcache.PageAllocator(6, reserved=2)
    live = alloc.alloc(1)                  # one live page, rest free
    free_pid = alloc.free_pages()[0]
    cache["k_pages"] = cache["k_pages"].at[0, free_pid].set(
        jnp.full_like(cache["k_pages"][0, free_pid], 255))
    cache["v_pages"] = cache["v_pages"].at[0, live[0], 0, 0, 0].set(7)
    s = scrubber.Scrubber()
    healed = s.scrub_free(cache, alloc)
    assert int(jnp.sum(healed["k_pages"][0, free_pid])) == 0
    assert int(healed["v_pages"][0, live[0], 0, 0, 0]) == 7   # live kept


# ---------------------------------------------------------------------------
# error accumulation: singles become DUEs only without scrub
# ---------------------------------------------------------------------------


def test_correctable_faults_accumulate_to_due_without_scrub():
    """The motivating failure mode: each round lands ONE correctable flip
    in the same 8-byte block. Unscrubbed, round two turns the resident
    single into a DUE; with a scrub between rounds every flip is healed
    while it is still correctable, so a DUE never forms."""
    flips = [((0, 0), 0x01), ((0, 1), 0x01)]      # same block, two rounds

    _, policy, enc = _small_tree()
    # without scrub: flips accumulate in memory
    for idx, mask in flips:
        enc["w0"] = _flip(enc["w0"], idx, mask)
    _, stats = scrubber.scrub_tree(enc)
    assert stats["due"] > 0 and stats["due_paths"] == ["w0"]

    _, policy, enc = _small_tree()
    # with a per-round scrub: each single is written back before the next
    total_cor = 0
    for idx, mask in flips:
        enc["w0"] = _flip(enc["w0"], idx, mask)
        enc, stats = scrubber.scrub_tree(enc)
        assert stats["due"] == 0
        total_cor += stats["corrected"]
    assert total_cor == len(flips)
    _, stats = scrubber.scrub_tree(enc)
    assert stats["due"] == 0 and stats["corrected"] == 0


def test_seeded_fault_stream_accumulates_without_scrub():
    """Statistical twin of the targeted test: a seeded per-round fault
    stream at a rate high enough to collide within 40 rounds produces
    DUEs when left alone, while the scrubbed twin (same stream) ends its
    run with zero residual DUE leaves."""
    def run(scrub):
        _, _, enc = _small_tree(seed=3)
        s = scrubber.Scrubber(leaves_per_step=0)
        for r in range(40):
            enc = protection.inject_tree_device(
                enc, 2e-4, jax.random.fold_in(jax.random.PRNGKey(17), r))
            if scrub:
                enc, st = s.scrub_weights(enc, n=-1)
        _, final = scrubber.scrub_tree(enc)
        return final["due"]

    assert run(scrub=False) > 0
    assert run(scrub=True) == 0


# ---------------------------------------------------------------------------
# MILR repair
# ---------------------------------------------------------------------------


def _corrupt_rows(pt, rows, n_hits=2):
    """Give each row in ``rows`` a DUE: n_hits flips in its first block."""
    for r in rows:
        for b in range(n_hits):
            pt = _flip(pt, (r, b), 0x01)
    return pt


def test_milr_repair_reconstructs_rows_bitexact():
    _, _, enc = _small_tree(seed=1)
    kit = repair.build_repair_kit(enc, seed=9, n_samples=8)
    assert "w0" in kit and kit.entries["w0"].solvable
    clean = np.asarray(enc["w0"].enc).copy()
    dirty = _corrupt_rows(enc["w0"], rows=(2, 11))
    q, double = repair.due_block_mask(dirty)
    assert double.any()
    fixed, rep = repair.repair_leaf(dirty, kit.entries["w0"], tol=kit.tol)
    assert rep["status"] == "repaired"
    assert rep["rows"] == 2 and rep["due_blocks"] == 2
    assert rep["residual"] is not None and rep["residual"] < 1e-9
    # the reconstruction is BIT-exact, not merely close
    assert np.array_equal(np.asarray(fixed.enc), clean)
    assert fixed.scheme_id == "in-place"


def test_milr_quarantines_when_underdetermined():
    """More corrupted rows than calibration samples: the solve cannot be
    determined, so the secded72 twin substitutes — and it decodes
    bit-equal to the clean image."""
    params, policy, enc = _small_tree(seed=2)
    kit = repair.build_repair_kit(enc, seed=9, n_samples=4)
    dirty = _corrupt_rows(enc["w2"], rows=tuple(range(6)))
    fixed, rep = repair.repair_leaf(dirty, kit.entries["w2"], tol=kit.tol,
                                    n_samples=4)
    assert rep["status"] == "quarantined"
    assert fixed.scheme_id == "secded72"
    qc, dc = repair.due_block_mask(enc["w2"])
    qf, df = repair.due_block_mask(fixed)
    assert not df.any()
    assert np.array_equal(qf, qc)


def test_milr_unrecoverable_without_twin():
    _, _, enc = _small_tree(seed=2)
    kit = repair.build_repair_kit(enc, seed=9, n_samples=4, twins=False)
    dirty = _corrupt_rows(enc["w2"], rows=tuple(range(6)))
    same, rep = repair.repair_leaf(dirty, kit.entries["w2"], tol=kit.tol,
                                   n_samples=4)
    assert rep["status"] == "unrecoverable"
    assert same is dirty


def test_repair_kit_requires_clean_tree_and_repair_tree_reports():
    _, _, enc = _small_tree(seed=4)
    enc["w1"] = _corrupt_rows(enc["w1"], rows=(0,))
    with pytest.raises(ValueError, match="clean tree"):
        repair.build_repair_kit(enc)
    _, _, clean_enc = _small_tree(seed=4)
    kit = repair.build_repair_kit(clean_enc, seed=9, n_samples=8)
    healed, reports = repair.repair_tree(enc, kit)
    assert [r["path"] for r in reports] == ["w1"]
    assert reports[0]["status"] == "repaired"
    # a second pass over the healed tree finds nothing to report
    _, again = repair.repair_tree(healed, kit)
    assert again == []


# ---------------------------------------------------------------------------
# plan diff + rolling migration
# ---------------------------------------------------------------------------


def test_plan_diff_and_migrate_step_value_exact():
    params, policy, enc = _small_tree(seed=6)
    plan = policy.plan(params)
    target = protection.ProtectionPolicy(
        default_scheme="secded72",
        predicate=lambda p, l: getattr(l, "ndim", 0) >= 2).plan(params)
    diff = plan.diff(target)
    assert set(diff.paths) == set(enc)
    assert diff.summary()["n_scheme_changes"] == len(enc)
    # secded72 buys its protection with stored check bytes
    assert diff.summary()["stored_bytes_delta"] > 0
    # promote ONE leaf; the rest keep their original scheme
    first = diff.paths[0]
    enc2, mixed_plan, recs = plan.migrate_step(enc, target, [first])
    assert [r["path"] for r in recs] == [first]
    assert recs[0]["from"] == "in-place" and recs[0]["to"] == "secded72"
    assert recs[0]["due"] == 0
    assert enc2[first].scheme_id == "secded72"
    assert mixed_plan.leaves[first].scheme_id == "secded72"
    others = [p for p in diff.paths if p != first]
    assert all(enc2[p].scheme_id == "in-place" for p in others)
    assert mixed_plan.diff(target).paths == tuple(others)
    # transcode is value-exact: both trees decode to identical weights
    dec_a = policy.decode_tree(enc, jnp.float32)
    dec_b = policy.decode_tree(enc2, jnp.float32)
    for k in params:
        assert np.array_equal(np.asarray(dec_a[k]), np.asarray(dec_b[k]))
    # unknown / non-protected paths are rejected loudly
    with pytest.raises(KeyError):
        plan.migrate_step(enc, target, ["nope"])


def test_plan_diff_rejects_mismatched_leaf_sets():
    params, policy, _ = _small_tree(seed=6)
    plan = policy.plan(params)
    other = policy.plan({k: params[k] for k in list(params)[:2]})
    with pytest.raises(ValueError):
        plan.diff(other)


def test_migration_mid_traffic_tokens_match_and_recompiles_bounded(
        plan_setup, smoke_params):
    """Live in-place -> secded72 migration while serving: token streams
    stay identical to the non-migrating twin (transcode is value-exact)
    and the jitted serve step retraces at most once per promotion batch
    plus the initial trace — no recompile churn beyond the planned
    promotions."""
    cfg, plan, enc = plan_setup(arch="deepseek-7b", backend="xla")
    _, params = smoke_params("deepseek-7b")
    target_policy = protection.ProtectionPolicy(default_scheme="secded72")
    target = protected.make_plan(params, target_policy)
    diff = plan.diff(target)
    n_changed = len(diff.paths)
    assert n_changed > 0
    assert all(e.to_scheme == "secded72" for e in diff.entries
               if e.scheme_changed)

    kvp = dataclasses.replace(kvcache.get_kv_policy("in-place"),
                              per_slot_flags=True)
    step = jax.jit(protected.make_serve_step(cfg, plan=plan,
                                             with_flags=True,
                                             kv_policy=kvp))
    waves = frontend.make_waves(seed=11, n_waves=2, wave_size=3,
                                vocab=cfg.vocab, prompt_len=(3, 6),
                                max_new=(2, 4), gap_steps=4)
    _, _, r_base = frontend.run_burst(cfg, enc, plan=plan, waves=waves,
                                      slots=2, max_len=32, kv_policy=kvp,
                                      serve_step=step)
    traces_before = step._cache_size()

    fe = frontend.ServingFrontend(cfg, enc, plan=plan, slots=2,
                                  max_len=32, kv_policy=kvp,
                                  serve_step=step)
    for req in waves:
        fe.submit(dataclasses.replace(req, arrival_step=0))
    mig = fe.start_migration(target, leaves_per_step=2, every=1)
    fe.run()
    assert fe.migration_done and mig.promoted == n_changed
    assert fe.results == r_base            # migration never changes tokens
    # every leaf of the live tree now decodes under the target scheme
    leaves = [l for l in jax.tree_util.tree_leaves(
        fe.enc_params, is_leaf=protection.is_protected_tensor)
        if protection.is_protected_tensor(l)]
    assert leaves and all(l.scheme_id == "secded72" for l in leaves)
    assert fe.plan.leaves[diff.paths[0]].scheme_id == "secded72"
    # recompile bound: one retrace per promotion batch, nothing more
    batches = -(-n_changed // 2)
    assert step._cache_size() - traces_before <= batches
    # telemetry: start + one promote record per leaf
    migs = [e for e in fe.telemetry.events if e["event"] == "migrate"]
    assert migs[0]["phase"] == "start" and migs[0]["pending"] == n_changed
    promotes = [m for m in migs if m["phase"] == "promote"]
    assert len(promotes) == n_changed
    assert promotes[-1]["pending"] == 0
    assert all(m["to"] == "secded72" for m in promotes)
    summ = telemetry.summarize(fe.telemetry.events)
    assert summ["healing"]["migrated_leaves"] == n_changed


def test_migration_guard_rails(plan_setup, smoke_params):
    cfg, plan, enc = plan_setup(arch="deepseek-7b", backend="xla")
    _, params = smoke_params("deepseek-7b")
    target = protected.make_plan(
        params, protection.ProtectionPolicy(default_scheme="secded72"))
    kvp = dataclasses.replace(kvcache.get_kv_policy("in-place"),
                              per_slot_flags=True)
    fe = frontend.ServingFrontend(cfg, enc, plan=plan, slots=2,
                                  max_len=32, kv_policy=kvp)
    fe.start_migration(target)
    with pytest.raises(RuntimeError, match="already in flight"):
        fe.start_migration(target)
    fe2 = frontend.ServingFrontend(cfg, enc, slots=2, max_len=32,
                                   kv_policy=kvp, serve_step=fe.serve_step)
    with pytest.raises(ValueError, match="without a plan"):
        fe2.start_migration(target)


# ---------------------------------------------------------------------------
# end-to-end: faulted serve loop heals to the bit-exact clean state
# ---------------------------------------------------------------------------


def _faulted_healing_run(cfg, plan, enc, kvp, step, kit, seed=5):
    """One drained burst with KV + weight faults at 1e-3 and the full
    healing loop on (scrub every step, MILR repair, final at-rest pass).
    Returns (frontend, events, final-scrub stats)."""
    col = telemetry.TelemetryCollector()
    fe = frontend.ServingFrontend(cfg, enc, plan=plan, slots=2,
                                  max_len=32, kv_policy=kvp,
                                  serve_step=step, collector=col,
                                  scrub_every=1, scrub_weight_leaves=2,
                                  repair_kit=kit)
    waves = frontend.make_waves(seed=11, n_waves=2, wave_size=3,
                                vocab=cfg.vocab, prompt_len=(3, 6),
                                max_new=(2, 4), gap_steps=4)
    pending = sorted(waves, key=lambda r: (r.arrival_step, r.rid))
    i = 0
    kv_key = jax.random.PRNGKey(seed)
    w_key = jax.random.PRNGKey(seed + 1_000_003)
    for _ in range(10_000):
        while i < len(pending) and pending[i].arrival_step <= fe.step_no:
            fe.submit(pending[i])
            i += 1
        if i >= len(pending) and not fe.queue.peek() and fe.active == 0:
            break
        if fe.active > 0 and fe.step_no % 4 == 0:
            tree = kvcache.as_protected_tree(fe.cache, fe.policy)
            dirty = protection.inject_tree_device(
                tree, 1e-3, jax.random.fold_in(kv_key, fe.step_no))
            fe.cache = kvcache.from_protected_tree(fe.cache, dirty)
            fe.enc_params = protection.inject_tree_device(
                fe.enc_params, 1e-3, jax.random.fold_in(w_key, fe.step_no))
        fe.step()
    final = fe.final_scrub()
    return fe, col.events, final


@pytest.fixture(scope="module")
def healing_rig(plan_setup):
    cfg, plan, enc = plan_setup(arch="deepseek-7b", backend="xla")
    kvp = dataclasses.replace(kvcache.get_kv_policy("in-place"),
                              per_slot_flags=True)
    step = jax.jit(protected.make_serve_step(cfg, plan=plan,
                                             with_flags=True,
                                             kv_policy=kvp))
    kit = repair.build_repair_kit(enc, seed=5)
    return cfg, plan, enc, kvp, step, kit


def test_faulted_serve_loop_heals_to_bitexact_logits(healing_rig,
                                                     plan_setup):
    """The acceptance: with KV + weight faults injected at 1e-3
    throughout, the serve loop drains, the final at-rest pass reports
    ZERO residual DUE, and the healed weight tree produces logits
    bit-exact with the never-faulted twin."""
    cfg, plan, enc, kvp, step, kit = healing_rig
    fe, events, final = _faulted_healing_run(cfg, plan, enc, kvp, step,
                                             kit)
    summ = telemetry.summarize(events)
    assert summ["requests"]["finished"] == summ["requests"]["submitted"]
    assert summ["pool"]["leaked_pages"] == 0
    assert final["w_due"] == 0 and final["kv_due"] == 0
    heal = summ["healing"]
    assert heal["scrub_passes"] > 0
    assert heal["w_corrected"] + final["w_corrected"] > 0
    assert heal["final_due"] == {"w": 0, "kv": 0,
                                 "w_corrected": final["w_corrected"],
                                 "kv_corrected": final["kv_corrected"],
                                 "w_repaired": final["w_repaired"]}
    # healed tree vs clean twin: bit-exact logits through the SAME step
    _, _, clean = plan_setup(arch="deepseek-7b", backend="xla")
    cache = kvcache.init_paged_cache(cfg, batch=2, max_len=32,
                                     policy=kvp,
                                     n_pages=fe.allocator.n_pages)
    tokens = jnp.ones((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    logits_clean, _, _ = step(clean, cache, tokens, pos)
    logits_healed, _, _ = step(fe.enc_params, cache, tokens, pos)
    assert jnp.array_equal(logits_clean, logits_healed)


def test_faulted_healing_run_is_bit_deterministic(healing_rig):
    """Healing events are pure functions of the logical step + the seeded
    fault streams: two identical runs agree on the FULL deterministic
    view (scrub/repair/migrate/final events included) and every token."""
    cfg, plan, enc, kvp, step, kit = healing_rig
    fe1, ev1, fin1 = _faulted_healing_run(cfg, plan, enc, kvp, step, kit)
    fe2, ev2, fin2 = _faulted_healing_run(cfg, plan, enc, kvp, step, kit)
    assert fe1.results == fe2.results
    assert fin1 == fin2
    assert telemetry.deterministic_view(ev1) == \
        telemetry.deterministic_view(ev2)
    # the determinism contract: healing events carry NO wall fields,
    # so they survive deterministic_view untouched
    healing = [e for e in ev1 if e["event"] in
               ("scrub", "scrub_final", "migrate", "repair")]
    assert healing
    for e in healing:
        assert not any(k.endswith(("_s", "_ms")) for k in e)


# ---------------------------------------------------------------------------
# telemetry v2
# ---------------------------------------------------------------------------


def test_summary_schema_v2_and_v1_compat(tmp_path):
    assert telemetry.SUMMARY_SCHEMA == "burst_sim/v2"
    v2 = tmp_path / "v2.json"
    summ = telemetry.summarize([])
    assert summ["schema"] == "burst_sim/v2"
    assert summ["healing"]["scrub_passes"] == 0
    assert summ["healing"]["final_due"] is None
    telemetry.write_summary(summ, str(v2))
    assert telemetry.load_summary(str(v2)) == summ
    # a pre-healing v1 summary still loads; healing is upgraded to None
    v1 = tmp_path / "v1.json"
    v1.write_text(json.dumps({"schema": "burst_sim/v1", "steps": 3}))
    old = telemetry.load_summary(str(v1))
    assert old["schema"] == "burst_sim/v1"
    assert old["healing"] is None
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": "burst_sim/v99"}))
    with pytest.raises(ValueError, match="unsupported"):
        telemetry.load_summary(str(bad))


def test_healing_rollup_counts_events():
    events = [
        {"event": "scrub", "step": 0, "w_scanned": 2, "w_corrected": 3,
         "w_due": 1, "kv_scanned": 4, "kv_corrected": 5, "kv_due": 0},
        {"event": "scrub", "step": 2, "w_scanned": 2, "w_corrected": 0,
         "w_due": 0, "kv_scanned": 4, "kv_corrected": 1, "kv_due": 0},
        {"event": "repair", "step": 0, "path": "a", "status": "repaired"},
        {"event": "repair", "step": 0, "path": "b",
         "status": "quarantined"},
        {"event": "migrate", "step": 1, "phase": "start", "pending": 2},
        {"event": "migrate", "step": 1, "phase": "promote", "path": "a",
         "pending": 1},
        {"event": "migrate", "step": 2, "phase": "promote", "path": "b",
         "pending": 0},
        {"event": "scrub_final", "step": 9, "w_scanned": 9,
         "w_corrected": 7, "w_repaired": 1, "w_due": 0, "kv_scanned": 2,
         "kv_corrected": 0, "kv_due": 0},
    ]
    heal = telemetry.summarize(events)["healing"]
    assert heal["scrub_passes"] == 2
    assert heal["w_corrected"] == 3 and heal["kv_corrected"] == 6
    assert heal["due_leaves_seen"] == 1
    assert heal["repairs"] == {"repaired": 1, "quarantined": 1}
    assert heal["migrated_leaves"] == 2
    assert heal["final_due"] == {"w": 0, "kv": 0, "w_corrected": 7,
                                 "kv_corrected": 0, "w_repaired": 1}
