"""Training loop (QATT), checkpointing, gradient compression, protected
serving — integration tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import quant, wot
from repro.data import synthetic
from repro.models import cnn, lm
from repro.serving import protected
from repro.training import checkpoint, compress, optim, train


class TestQATT:
    def test_cnn_qatt_learns_and_satisfies_constraint(self):
        """The paper's WOT claim at CPU scale: pretrain -> QAT+throttling
        keeps accuracy AND the deployed int8 weights meet the constraint."""
        from repro.training.cnn_experiments import (accuracy, large_count,
                                                    pretrain, wot_finetune)
        params, fwd, tmpl = pretrain("resnet18", steps=60)
        acc_pre = accuracy(params, fwd, tmpl, quantized=True)
        params, tmpl, _ = wot_finetune(params, fwd, tmpl, steps=15)
        acc_post = accuracy(params, fwd, tmpl, quantized=True)
        assert large_count(params) == 0
        assert acc_post >= acc_pre - 0.1  # paper: accuracy fully recovered
        # every deployable (quantize->weights) tensor satisfies WOT
        for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            if hasattr(leaf, "ndim") and leaf.ndim >= 2:
                q, _ = quant.quantize(leaf)
                assert wot.satisfies_constraint(q.reshape(-1)), path

    def test_lm_train_step_loss_decreases(self):
        cfg = configs.get_smoke("minitron-4b").with_(microbatch=2)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = optim.sgd_init(params)
        step = jax.jit(train.make_train_step(cfg, lr=5e-3, chunk=16))
        losses = []
        for s in range(8):
            b = synthetic.token_batch(cfg.vocab_padded, 4, 32, seed=1, step=s)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, loss = step(params, opt, b)
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_fused_momentum_matches_reference_sgd(self):
        """fused accumulate-into-momentum == accumulate-then-sgd_update."""
        cfg = configs.get_smoke("qwen1.5-4b").with_(microbatch=2,
                                                    remat=False)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = optim.sgd_init(params)
        b = synthetic.token_batch(cfg.vocab_padded, 4, 16, seed=2, step=0)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        lr, mu, wd = 1e-3, 0.9, 1e-4

        p1, o1, _ = jax.jit(train.make_train_step(
            cfg, lr=lr, mu=mu, wd=wd, wot_throttle=False, chunk=16,
            bf16_weights=False))(params, opt, b)

        # reference: mean grads over microbatches, then sgd_update
        wt = train.qat_wt
        lfn = lambda p, mb: lm.loss_fn(cfg, p, mb, wt=wt, chunk=16)
        g0 = jax.grad(lfn)(params, jax.tree.map(lambda x: x[:2], b))
        g1 = jax.grad(lfn)(params, jax.tree.map(lambda x: x[2:], b))
        g = jax.tree.map(lambda a, c: (a + c) / 2, g0, g1)
        p2, o2 = optim.sgd_update(params, g, opt, lr=lr, mu=mu, wd=wd)
        err = jax.tree.reduce(max, jax.tree.map(
            lambda a, c: float(jnp.max(jnp.abs(a - c))), p1, p2))
        assert err < 5e-6, err


class TestCheckpoint:
    def test_roundtrip_and_rotation(self, tmp_path):
        tree = {"a": jnp.arange(100, dtype=jnp.float32).reshape(10, 10),
                "b": {"c": jnp.ones((3,))}}
        for s in (1, 2, 3, 4):
            checkpoint.save(str(tmp_path), tree, step=s, keep=2)
        assert checkpoint.latest_step(str(tmp_path)) == 4
        assert len(os.listdir(tmp_path)) == 2  # rotation
        restored, step = checkpoint.restore(str(tmp_path), tree)
        assert step == 4
        assert (np.asarray(restored["a"]) == np.asarray(tree["a"])).all()

    def test_protected_checkpoint_quantization_error_bounded(self, tmp_path):
        rng = np.random.default_rng(0)
        tree = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
        checkpoint.save(str(tmp_path), tree, step=1, protected=True)
        restored, _ = checkpoint.restore(str(tmp_path), tree)
        scale = float(jnp.max(jnp.abs(tree["w"]))) / 127
        # int8 quantization + WOT throttle error bound
        err = np.abs(np.asarray(restored["w"]) - np.asarray(tree["w"]))
        assert err.max() <= scale * 64  # throttled worst case
        assert np.percentile(err, 95) <= scale  # bulk within one step

    def test_async_checkpointer(self, tmp_path):
        tree = {"w": jnp.ones((32, 32))}
        ck = checkpoint.AsyncCheckpointer(str(tmp_path))
        ck.save(tree, 1)
        ck.wait()
        assert checkpoint.latest_step(str(tmp_path)) == 1

    def test_resume_after_simulated_failure(self, tmp_path):
        """Train 4 steps w/ ckpt, 'crash', resume from step 2, agree at 4."""
        cfg = configs.get_smoke("deepseek-7b").with_(microbatch=1)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = optim.sgd_init(params)
        step = jax.jit(train.make_train_step(cfg, lr=1e-3, chunk=16))

        def run(params, opt, start, end):
            for s in range(start, end):
                b = synthetic.token_batch(cfg.vocab_padded, 2, 16, seed=3,
                                          step=s)
                b = {k: jnp.asarray(v) for k, v in b.items()}
                params, opt, _ = step(params, opt, b)
            return params, opt

        p, o = run(params, opt, 0, 2)
        checkpoint.save(str(tmp_path), (p, o), step=2)
        p_full, _ = run(p, o, 2, 4)                      # uninterrupted
        (p_res, o_res), s0 = checkpoint.restore(str(tmp_path), (p, o))
        p_resumed, _ = run(p_res, o_res, s0, 4)          # crash + resume
        err = jax.tree.reduce(max, jax.tree.map(
            lambda a, c: float(jnp.max(jnp.abs(a - c))), p_full, p_resumed))
        assert err < 1e-6


class TestCompression:
    def test_error_feedback_is_lossless_in_expectation(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
        res = jnp.zeros_like(g)
        total_sent = jnp.zeros_like(g)
        for _ in range(50):
            q, scale, res = compress.compress(g, res)
            total_sent = total_sent + compress.decompress(q, scale)
        # mean of sent updates converges to the true gradient
        err = float(jnp.max(jnp.abs(total_sent / 50 - g)))
        assert err < float(quant.compute_scale(g)) * 0.2

    def test_compress_bytes_are_4x_smaller(self):
        g = jnp.ones((1024,), jnp.float32)
        q, scale, _ = compress.compress(g, jnp.zeros_like(g))
        assert q.dtype == jnp.int8 and q.nbytes * 4 == g.nbytes


class TestProtectedServing:
    def test_encode_decode_roundtrip_error_bounded(self):
        cfg = configs.get_smoke("qwen1.5-4b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        enc = protected.encode_tree(params)
        dec = protected.decode_tree(enc, jnp.float32)
        for path, (a, b) in zip(
                jax.tree_util.tree_flatten_with_path(params)[0],
                zip(jax.tree.leaves(params), jax.tree.leaves(dec))):
            if a.ndim >= 2 and a.shape[-1] % 8 == 0:
                scale = float(jnp.max(jnp.abs(a))) / 127
                assert float(jnp.median(jnp.abs(np.asarray(a) -
                                                np.asarray(b)))) <= scale

    def test_serving_with_faults_matches_fault_free(self):
        """Single-bit faults in resident images are fully transparent."""
        from repro.launch.serve import inject_tree
        cfg = configs.get_smoke("minitron-4b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        enc = protected.encode_tree(params)
        serve = jax.jit(protected.make_serve_step(cfg))
        cache = lm.init_cache(cfg, 2, 32)
        tok = jnp.zeros((2, 1), jnp.int32)
        pos = jnp.zeros((2,), jnp.int32)
        clean, _ = serve(enc, cache, tok, pos)
        faulty = inject_tree(enc, 1e-5, seed=1)  # sparse singles
        dirty, _ = serve(faulty, cache, tok, pos)
        assert np.allclose(np.asarray(clean, np.float32),
                           np.asarray(dirty, np.float32), atol=1e-5)
