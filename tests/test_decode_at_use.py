"""Fused decode-at-use serving: kernel flags, per-leaf routing, and the
numerical-identity acceptance — decode-at-use logits == decode-per-step
baseline on a trained model, for mixed-scheme plans, on both backends."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, protection
from repro.core import ecc
from repro.data import synthetic
from repro.models import lm
from repro.serving import protected


def _wot_weights(rng, shape):
    w = rng.integers(-64, 64, size=shape).astype(np.int8)
    flat = w.reshape(-1)
    flat[7::8] = rng.integers(-128, 128, size=flat[7::8].size)
    return flat.reshape(shape)


def _enc(wq):
    k, n = wq.shape
    return np.asarray(ecc.encode64(jnp.asarray(
        wq.view(np.uint8).reshape(k, n // 8, 8)))).reshape(k, n)


# ---------------------------------------------------------------------------
# fused-kernel fault accounting (the flags _kernel used to drop)
# ---------------------------------------------------------------------------


def test_fused_kernel_counts_injected_doubles_and_singles():
    """Regression: the fused path must DETECT double-bit errors (DUE), not
    silently matmul through them — and count each corrected single."""
    from repro.kernels.ecc_qmatmul import ecc_qmatmul
    rng = np.random.default_rng(3)
    m, k, n = 32, 64, 128
    a = rng.integers(-127, 128, size=(m, k)).astype(np.int8)
    wenc = _enc(_wot_weights(rng, (k, n)))
    f = wenc.reshape(-1).copy()
    double_blocks, single_blocks = [1, 40, 777], [5, 123]
    for blk in double_blocks:
        f[blk * 8 + 2] ^= 0x06  # two flips in one 64-bit block
    for blk in single_blocks:
        f[blk * 8 + 4] ^= 0x20
    out, flags = ecc_qmatmul(jnp.asarray(a), jnp.asarray(f.reshape(k, n)),
                             bm=16, bn=64, bk=32, with_flags=True)
    assert int(flags[0]) == len(single_blocks)
    assert int(flags[1]) == len(double_blocks)
    # flag counting must not depend on the M grid (blocks counted once)
    _, flags2 = ecc_qmatmul(jnp.asarray(a), jnp.asarray(f.reshape(k, n)),
                            bm=8, bn=32, bk=64, with_flags=True)
    assert np.array_equal(np.asarray(flags), np.asarray(flags2))


def test_fused_kernel_edge_tiles_and_float_path():
    """No divisibility asserts: ragged (m, k) with tile sizes that don't
    divide, int8 exact vs the plain matmul; float path bit-identical to
    decode-then-matmul."""
    from repro.kernels.ecc_qmatmul import ecc_qmatmul
    rng = np.random.default_rng(7)
    m, k, n = 45, 100, 72
    wq = _wot_weights(rng, (k, n))
    wenc = _enc(wq)
    a = rng.integers(-127, 128, size=(m, k)).astype(np.int8)
    out = ecc_qmatmul(jnp.asarray(a), jnp.asarray(wenc), bm=32, bn=32, bk=64)
    assert (np.asarray(out) == a.astype(np.int32) @ wq.astype(np.int32)).all()

    scale = jnp.float32(0.02)
    x = jnp.asarray(rng.normal(size=(5, k)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    outf = ecc_qmatmul(x, jnp.asarray(wenc), scale)
    base = x @ (jnp.asarray(wq).astype(jnp.float32) * scale
                ).astype(jnp.bfloat16)
    assert np.array_equal(np.asarray(outf.astype(jnp.bfloat16), np.float32),
                          np.asarray(base, np.float32))


# ---------------------------------------------------------------------------
# autotune table: 4x-ratio boundary + v2 <-> v1 artifacts
# ---------------------------------------------------------------------------


def test_autotune_lookup_4x_ratio_boundary():
    t = protection.AutotuneTable(
        entries=[{"shape": [32, 256], "xla_us": 1.0, "pallas_us": 2.0,
                  "best": "xla"}])  # 1024 blocks
    assert t.lookup((8, 256)) == "xla"      # 256 blocks: ratio exactly 4.0
    assert t.lookup((8, 248)) is None       # 248 blocks: ratio 4.13 > 4
    assert t.lookup((512, 64)) == "xla"     # 4096 blocks: ratio exactly 0.25
    assert t.lookup((520, 64)) is None      # 4160 blocks: just beyond 0.25


def test_autotune_v3_tiles_and_v2_v1_backward_compat(tmp_path):
    v3 = {"schema": protection.BENCH_KERNELS_SCHEMA, "platform": "cpu",
          "entries": [{"shape": [256, 256], "xla_us": 5.0, "pallas_us": 3.0,
                       "best": "pallas", "tiles": [128, 128, 0],
                       "fused_us": 2.5, "int8_tiles": [64, 128, 0],
                       "fused_int8_us": 1.5}]}
    v2 = {"schema": protection.BENCH_KERNELS_SCHEMA_V2, "platform": "cpu",
          "entries": [{"shape": [256, 256], "xla_us": 5.0, "pallas_us": 3.0,
                       "best": "pallas", "tiles": [128, 128, 0],
                       "fused_us": 2.5}]}
    v1 = {"schema": protection.BENCH_KERNELS_SCHEMA_V1, "platform": "cpu",
          "entries": [{"shape": [256, 256], "xla_us": 5.0, "pallas_us": 3.0,
                       "best": "pallas"}]}
    p3, p2, p1 = tmp_path / "v3.json", tmp_path / "v2.json", tmp_path / "v1.json"
    p3.write_text(json.dumps(v3))
    p2.write_text(json.dumps(v2))
    p1.write_text(json.dumps(v1))
    t3 = protection.AutotuneTable.from_json(p3)
    assert t3.lookup((256, 256)) == "pallas"
    assert t3.lookup_tiles((256, 256)) == (128, 128, 0)
    assert t3.lookup_int8_tiles((256, 256)) == (64, 128, 0)
    assert t3.to_dict()["schema"] == protection.BENCH_KERNELS_SCHEMA
    # v2 artifacts still load: float tiles yes, int8 tiles no
    t2 = protection.AutotuneTable.from_json(p2)
    assert t2.lookup((256, 256)) == "pallas"
    assert t2.lookup_tiles((256, 256)) == (128, 128, 0)
    assert t2.lookup_tiles((128, 512)) == (128, 128, 0)  # nearest-by-blocks
    assert t2.lookup_int8_tiles((256, 256)) is None
    assert t2.to_dict()["schema"] == protection.BENCH_KERNELS_SCHEMA_V2
    # v1 artifacts still load: backend opinion yes, tile opinion no
    t1 = protection.AutotuneTable.from_json(p1)
    assert t1.lookup((256, 256)) == "pallas"
    assert t1.lookup_tiles((256, 256)) is None
    assert t1.to_dict()["schema"] == protection.BENCH_KERNELS_SCHEMA_V1
    # round-trip of a v3 table preserves both tile kinds
    rt = protection.AutotuneTable.from_dict(t3.to_dict())
    assert rt.lookup_tiles((256, 256)) == (128, 128, 0)
    assert rt.lookup_int8_tiles((256, 256)) == (64, 128, 0)


def test_autotune_tiles_nearest_fallback_with_source():
    """Tiles are hints, not routes: unseen shapes fall back to the nearest
    tile-bearing entry by block count with NO 4x cap (the backend lookup
    keeps its cap), and the source marker says when that happened."""
    t = protection.AutotuneTable(
        entries=[{"shape": [32, 256], "xla_us": 1.0, "pallas_us": 2.0,
                  "best": "xla", "tiles": [128, 256, 0],
                  "int8_tiles": [64, 64, 0]},
                 {"shape": [2048, 4096], "xla_us": 9.0, "pallas_us": 9.9,
                  "best": "xla", "tiles": [128, 512, 128]}])
    assert t.lookup_tiles_src((32, 256)) == ((128, 256, 0), "exact")
    # far beyond the 4x window: backend has no opinion, tiles still resolve
    assert t.lookup((9999, 9992)) is None
    assert t.lookup_tiles_src((9999, 9992)) == ((128, 512, 128), "nearest")
    # int8 tiles skip entries that don't carry them
    assert t.lookup_tiles_src((9999, 9992), key="int8_tiles") == \
        ((64, 64, 0), "nearest")
    # the plan surfaces the marker
    rng = np.random.default_rng(3)
    params = {"wq": jnp.asarray(rng.normal(size=(32, 256)).astype(np.float32)),
              "wo": jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))}
    policy = protection.ProtectionPolicy(
        predicate=lambda p, l: getattr(l, "ndim", 0) >= 2, autotune=t)
    plan = protection.make_plan(policy, params)
    assert plan["wq"].tiles == (128, 256, 0)
    assert plan["wq"].tiles_src == "exact"
    assert plan["wo"].tiles == (128, 256, 0)   # nearest by block count
    assert plan["wo"].tiles_src == "nearest"
    assert plan.summary()["tiles_src"] == {"exact": 1, "nearest": 1}


def test_checked_in_artifact_is_v3_with_tiles():
    import os
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "BENCH_kernels.json")
    t = protection.AutotuneTable.from_json(path)
    assert t.schema == protection.BENCH_KERNELS_SCHEMA
    assert any(t.lookup_tiles(e["shape"]) for e in t.entries)
    assert any(t.lookup_int8_tiles(e["shape"]) for e in t.entries)


# ---------------------------------------------------------------------------
# the acceptance: fused decode-at-use == decode-per-step, trained model,
# mixed-scheme plan, both backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_decode_at_use_matches_per_step_on_trained_model(backend,
                                                         trained_minitron):
    cfg, params = trained_minitron()  # session fixture: trained ONCE
    policy = protection.get_policy_preset("attn-inplace-mlp-secded",
                                          backend=backend)
    plan = protected.make_plan(params, policy)
    assert set(plan.summary()["by_scheme"]) == {"in-place", "secded72"}
    enc = plan.encode_tree(params)
    cache = lm.init_cache(cfg, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)

    at_use = jax.jit(protected.make_serve_step(cfg, plan=plan))
    per_step = jax.jit(protected.make_serve_step(cfg, plan=plan,
                                                 decode_at_use=False))
    l1, c1 = at_use(enc, cache, tok, pos)
    l2, c2 = per_step(enc, cache, tok, pos)
    assert np.array_equal(np.asarray(l1, np.float32),
                          np.asarray(l2, np.float32))
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))

    # prefill: same identity through lm.forward
    pre1 = jax.jit(protected.make_prefill(cfg, plan=plan, chunk=16))
    pre2 = jax.jit(protected.make_prefill(cfg, plan=plan, chunk=16,
                                          decode_at_use=False))
    toks = jnp.asarray(synthetic.token_batch(
        cfg.vocab_padded, 2, 16, seed=9, step=0)["tokens"])
    assert np.array_equal(
        np.asarray(pre1(enc, toks, {}), np.float32),
        np.asarray(pre2(enc, toks, {}), np.float32))


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "recurrentgemma-2b"])
def test_decode_at_use_prefill_conv_archs(arch):
    """ssm/hybrid regression: depthwise conv kernels are indexed elementwise
    by _causal_conv, so they must decode to arrays (not lazy views) — and
    prefill must still match the whole-tree decode bit-for-bit."""
    cfg = configs.get_smoke(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    policy = protection.ProtectionPolicy(backend="pallas")
    plan = protected.make_plan(params, policy)
    enc = plan.encode_tree(params)
    toks = jnp.zeros((2, 16), jnp.int32)
    pre1 = jax.jit(protected.make_prefill(cfg, plan=plan, chunk=16))
    pre2 = jax.jit(protected.make_prefill(cfg, plan=plan, chunk=16,
                                          decode_at_use=False))
    assert np.array_equal(np.asarray(pre1(enc, toks, {}), np.float32),
                          np.asarray(pre2(enc, toks, {}), np.float32))


def test_autotune_tiles_keep_serve_identity():
    """A plan with the checked-in autotune table (whose entries carry
    bk != 0 tiles) must still serve bit-identical to the per-step baseline:
    serving always uses full-K tiles for the float path."""
    import os
    cfg = configs.get_smoke("qwen1.5-4b")
    params = lm.init_params(cfg, jax.random.PRNGKey(4))
    bench = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                         "BENCH_kernels.json")
    policy = protection.ProtectionPolicy(backend="pallas", autotune=bench)
    plan = protected.make_plan(params, policy)
    enc = plan.encode_tree(params)
    cache = lm.init_cache(cfg, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    l1, _ = jax.jit(protected.make_serve_step(cfg, plan=plan))(
        enc, cache, tok, pos)
    l2, _ = jax.jit(protected.make_serve_step(cfg, plan=plan,
                                              decode_at_use=False))(
        enc, cache, tok, pos)
    assert np.array_equal(np.asarray(l1, np.float32),
                          np.asarray(l2, np.float32))


def test_serve_flags_count_head_faults():
    """The output head decodes after the layer scans — its flags must land
    in the 'top' row, not vanish."""
    import dataclasses
    cfg = configs.get_smoke("deepseek-7b")  # untied head
    params = lm.init_params(cfg, jax.random.PRNGKey(6))
    plan = protected.make_plan(params, protection.ProtectionPolicy())
    enc = plan.encode_tree(params)
    head = enc["head"]
    img = np.asarray(head.enc).copy()
    img.reshape(-1)[5] ^= 0x03  # double-bit error in the head image
    enc["head"] = dataclasses.replace(head, enc=jnp.asarray(img))
    serve = jax.jit(protected.make_serve_step(cfg, plan=plan,
                                              with_flags=True))
    cache = lm.init_cache(cfg, 2, 32)
    _, _, flags = serve(enc, cache, jnp.zeros((2, 1), jnp.int32),
                        jnp.zeros((2,), jnp.int32))
    assert int(np.asarray(flags["top"])[1]) == 1
    assert int(np.asarray(flags["layers"]).sum()) == 0


def test_serve_flags_count_injected_faults_per_layer():
    """Per-layer (corrected, DUE) accounting: singles land in 'corrected'
    of the right row, doubles in 'due', clean tree reports zeros."""
    cfg = configs.get_smoke("deepseek-7b")
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    policy = protection.ProtectionPolicy()  # all in-place
    plan = protected.make_plan(params, policy)
    enc = plan.encode_tree(params)
    serve = jax.jit(protected.make_serve_step(cfg, plan=plan,
                                              with_flags=True))
    cache = lm.init_cache(cfg, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    _, _, clean_flags = serve(enc, cache, tok, pos)
    assert set(clean_flags) == {"top", "layers"}
    assert clean_flags["layers"].shape == (lm.n_scan_layers(cfg), 2)
    assert all(int(np.asarray(v).sum()) == 0 for v in clean_flags.values())

    # one double-bit fault in layer 0's wq image, one single in the embed
    import dataclasses
    wq = enc["layers"]["attn"]["wq"]
    img = np.asarray(wq.enc).copy()
    img.reshape(-1)[3] ^= 0x03  # two flips, block 0 of layer 0
    enc["layers"]["attn"]["wq"] = dataclasses.replace(
        wq, enc=jnp.asarray(img))
    emb = enc["embed"]
    img = np.asarray(emb.enc).copy()
    img.reshape(-1)[8] ^= 0x10  # one flip
    enc["embed"] = dataclasses.replace(emb, enc=jnp.asarray(img))

    _, _, flags = serve(enc, cache, tok, pos)
    layers = np.asarray(flags["layers"])
    assert layers[0, 1] >= 1          # the DUE, attributed to layer 0
    assert layers[1:, 1].sum() == 0   # and only layer 0
    assert int(np.asarray(flags["top"])[0]) == 1  # embed single corrected


def test_due_campaign_consumes_flags():
    rng = np.random.default_rng(0)
    q = _wot_weights(rng, (64, 64)).astype(np.float32) * 0.01
    tree = {"w": jnp.asarray(q)}
    policy = protection.ProtectionPolicy(
        predicate=lambda p, l: getattr(l, "ndim", 0) >= 2)
    res = protection.due_campaign(tree, policy, rates=(0.0, 0.03), trials=2,
                                  key=jax.random.PRNGKey(20))
    assert res.metric == "due_count"
    assert res.clean == 0.0
    assert res.mean()[0] == 0.0          # zero rate -> zero DUE
    assert res.mean()[1] > 0.0           # 3% bit flips -> some doubles
    # corrected counts sweep too, and see even more events than DUEs
    corr = protection.due_campaign(tree, policy, rates=(0.03,), trials=2,
                                   key=jax.random.PRNGKey(21),
                                   what="corrected")
    assert corr.mean()[0] > 0.0
