"""ADMM WOT baseline (paper §4.1): mechanics + the paper's negative finding."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant, wot
from repro.training import admm


def test_admm_state_and_step_mechanics():
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(16, 16)).astype(np.float32))}

    def loss(p, batch):
        return jnp.sum(jnp.square(p["w"] @ batch))

    step = admm.make_admm_step(loss, lr=1e-3, gamma=1e-2)
    state = admm.admm_init(params)
    batch = jnp.ones((16, 4))
    p, state, l0 = step(params, state, batch)
    for _ in range(5):
        p, state, l = step(p, state, batch)
    assert np.isfinite(float(l)) and float(l) < float(l0)
    # z always satisfies the constraint (projection invariant)
    q, _ = quant.quantize(state.z["w"])
    assert wot.satisfies_constraint(q.reshape(-1))


def test_finalize_enforces_constraint():
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32) * 5)}
    out = admm.finalize(params)
    q, _ = quant.quantize(out["w"])
    assert wot.satisfies_constraint(q.reshape(-1))
