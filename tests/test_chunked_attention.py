"""Chunked online-softmax paged attention: the fp64-oracle tolerance
gates that replace the bit-identity contract the chunked kernel forfeits.

Four layers of acceptance:

* tolerance sweep — 3 KV schemes x ragged lengths (including a chunk-pad
  tail) x injected faults, chunked output vs ``oracle_page_attention``
  (integer-exact codec decode, fp64 softmax/PV), flags exact vs the
  strip kernel;
* short-length cross-check — chunked also tracks the strip reference
  itself, and per-slot flag rows attribute faults to the right request;
* beyond-VMEM lengths — at >= 2 context lengths past the strip kernel's
  16 MiB VMEM crossover (~8113 tokens @ hd=128, rep=2) the chunked
  kernel still meets the oracle tolerance while its own VMEM need stays
  bounded by the chunk;
* serving plumbing — the ``attention_impl="chunked"`` override on
  ``make_serve_step`` and the ``*-chunked`` presets route real decode
  steps through the chunked kernel with logits tracking the strip twin.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import paged_attention
from repro.models import lm
from repro.serving import kvcache, protected


def _randn(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def _strips(rng, b, s, kv, hd, scheme, faults=()):
    """Encoded K/V strips with optional injected bit flips in ke."""
    pol = kvcache.KVProtectionPolicy(scheme=scheme)
    ke, kch, ksc = kvcache._encode_kv(_randn(rng, (b, s, kv, hd)), pol)
    ve, vch, vsc = kvcache._encode_kv(_randn(rng, (b, s, kv, hd)), pol)
    if faults:
        flat = np.asarray(ke).copy()
        for bi, t, g, byte, bit in faults:
            flat[bi, t, g, byte] ^= 1 << bit
        ke = jnp.asarray(flat)
    return ke, kch, ksc, ve, vch, vsc


def _tol(oracle):
    """The shipped acceptance tolerance (same formula as kernel_bench)."""
    return 0.02 * (np.abs(oracle).max() + 1e-6)


@pytest.mark.parametrize("s,chunk", [(96, 64), (256, 64)])
@pytest.mark.parametrize("scheme", kvcache.KV_SCHEMES)
def test_chunked_matches_fp64_oracle(scheme, s, chunk):
    """Tolerance sweep: ragged positions, GQA rep=2, faults in tokens
    valid for both batch rows; (96, 64) exercises the zero-pad tail."""
    rng = np.random.default_rng(7)
    b, kv, hd, rep = 2, 2, 16, 2
    strips = _strips(rng, b, s, kv, hd, scheme,
                     faults=[(0, 1, 0, 3, 2), (1, 5, 1, 0, 6)])
    q = _randn(rng, (b, kv * rep, 1, hd), jnp.bfloat16)
    pos = jnp.asarray([s - 1, s // 3], jnp.int32)

    o, fl = paged_attention.chunked_page_attention(
        q, *strips, pos, scheme=scheme, chunk_tokens=chunk)
    oracle = paged_attention.oracle_page_attention(
        q, *strips, pos, scheme=scheme)
    err = np.abs(np.asarray(o, np.float64) - oracle).max()
    assert err <= _tol(oracle), (scheme, s, chunk, err)
    # flag counts are exact, not tolerance-gated: cross-check vs strip
    _, fl_ref = paged_attention.fused_page_attention(
        q, *strips, pos, scheme=scheme)
    assert np.array_equal(np.asarray(fl), np.asarray(fl_ref))
    if scheme != "faulty":
        assert int(fl[0]) == 2          # one repaired flip per row


@pytest.mark.parametrize("scheme", kvcache.KV_SCHEMES)
def test_chunked_tracks_strip_reference_at_short_length(scheme):
    """Short-length cross-check: chunked vs the bit-exact strip kernel
    stays inside the same oracle tolerance, flags identical; per-slot
    rows keep the injected fault attributed to sequence 0 only."""
    rng = np.random.default_rng(9)
    b, s, kv, hd, rep = 2, 32, 2, 16, 2
    strips = _strips(rng, b, s, kv, hd, scheme, faults=[(0, 1, 0, 3, 2)])
    q = _randn(rng, (b, kv * rep, 1, hd), jnp.bfloat16)
    pos = jnp.asarray([s - 1, s // 2], jnp.int32)

    o_c, fl_c = paged_attention.chunked_page_attention(
        q, *strips, pos, scheme=scheme, chunk_tokens=16)
    o_f, fl_f = paged_attention.fused_page_attention(
        q, *strips, pos, scheme=scheme)
    oracle = paged_attention.oracle_page_attention(
        q, *strips, pos, scheme=scheme)
    tol = _tol(oracle)
    assert np.abs(np.asarray(o_c, np.float64) - oracle).max() <= tol
    assert np.abs(np.asarray(o_c, np.float64)
                  - np.asarray(o_f, np.float64)).max() <= tol
    assert np.array_equal(np.asarray(fl_c), np.asarray(fl_f))

    o_p, fl_p = paged_attention.chunked_page_attention(
        q, *strips, pos, scheme=scheme, chunk_tokens=16, per_slot=True)
    assert np.array_equal(np.asarray(o_p), np.asarray(o_c))
    assert fl_p.shape == (2, b)
    assert np.array_equal(np.asarray(fl_p).sum(axis=1), np.asarray(fl_c))
    if scheme != "faulty":
        assert int(fl_p[0, 0]) == 1 and int(fl_p[0, 1]) == 0


@pytest.mark.parametrize("scheme", kvcache.KV_SCHEMES)
def test_chunked_beyond_strip_vmem_budget(scheme):
    """The long-context acceptance: two context lengths past the strip
    kernel's VMEM crossover, all three schemes, fault injected — chunked
    meets the oracle tolerance with chunk-bounded VMEM."""
    b, kv, hd, rep, chunk = 1, 1, 128, 2, 2048
    xo = paged_attention.strip_vmem_crossover(hd, rep, scheme)
    assert (paged_attention.chunked_vmem_bytes(chunk, hd, rep, scheme)
            <= paged_attention.VMEM_BUDGET_BYTES)
    for s in (10240, 12288):
        assert s > xo
        assert (paged_attention.strip_vmem_bytes(s, hd, rep, scheme)
                > paged_attention.VMEM_BUDGET_BYTES)
        rng = np.random.default_rng(s)
        strips = _strips(rng, b, s, kv, hd, scheme,
                         faults=[(0, 7, 0, 1, 4)])
        q = _randn(rng, (b, kv * rep, 1, hd), jnp.bfloat16)
        pos = jnp.asarray([s - 1], jnp.int32)
        o, fl = paged_attention.chunked_page_attention(
            q, *strips, pos, scheme=scheme, chunk_tokens=chunk)
        oracle = paged_attention.oracle_page_attention(
            q, *strips, pos, scheme=scheme)
        err = np.abs(np.asarray(o, np.float64) - oracle).max()
        assert err <= _tol(oracle), (scheme, s, err)
        if scheme != "faulty":
            assert int(fl[0]) == 1 and int(fl[1]) == 0


def test_serve_step_attention_impl_override(plan_setup):
    """``make_serve_step(..., attention_impl="chunked")`` routes decode
    through the chunked kernel on the SAME encoded cache: logits track
    the strip twin closely, KV flags stay clean, and the knob is
    validated (needs a kv_policy; bogus impl names rejected)."""
    cfg, plan, enc = plan_setup(arch="deepseek-7b", backend="xla")
    kvp = kvcache.get_kv_policy("in-place")
    mk = lambda **kw: jax.jit(protected.make_serve_step(
        cfg, plan=plan, with_flags=True, kv_policy=kvp, **kw))
    step_s, step_c = mk(), mk(attention_impl="chunked")

    # both twins eat the SAME token stream (greedy over random-init
    # weights has near-tie logits, so per-stream greedy would fork);
    # logits then stay within a few bf16 quanta of each other
    caches = [kvcache.init_cache(cfg, 1, 32, kv_policy=kvp)
              for _ in range(2)]
    toks = jnp.zeros((1, 1), jnp.int32)
    for t in range(4):
        pos = jnp.full((1,), t, jnp.int32)
        outs = []
        for i, step in enumerate((step_s, step_c)):
            logits, caches[i], flags = step(enc, caches[i], toks, pos)
            assert int(np.asarray(flags["layers_kv"]).sum()) == 0
            outs.append(np.asarray(logits, np.float64))
        a, b = outs
        assert np.isfinite(b).all()
        assert np.abs(a - b).max() <= 0.05 * (np.abs(a).max() + 1e-6)
        toks = jnp.argmax(jnp.asarray(a), axis=-1).astype(jnp.int32)

    with pytest.raises(ValueError, match="attention_impl"):
        protected.make_serve_step(cfg, plan=plan,
                                  attention_impl="chunked")
    with pytest.raises(ValueError, match="attention_impl"):
        protected.make_serve_step(cfg, plan=plan, kv_policy=kvp,
                                  attention_impl="flash")
    with pytest.raises(ValueError, match="attention_impl"):
        protected.make_prefill(cfg, plan=plan,
                               attention_impl="chunked")


def test_chunked_preset_through_paged_gqa_decode(smoke_params):
    """The ``in-place-chunked`` preset drives ``lm.decode_step`` through
    ``paged_gqa_decode``'s chunked route: logits track the strip-preset
    twin on the same token stream."""
    cfg, params = smoke_params("deepseek-7b")
    pol_c = kvcache.get_kv_policy("in-place-chunked")
    assert pol_c.attention_impl == "chunked" and pol_c.fused
    assert pol_c.chunk_pages * pol_c.page_size >= 1

    caches = {name: kvcache.init_cache(cfg, 1, 32, kv_policy=name)
              for name in ("in-place", "in-place-chunked")}
    toks = jnp.zeros((1, 1), jnp.int32)
    for t in range(3):
        pos = jnp.full((1,), t, jnp.int32)
        outs = {}
        for name in caches:
            logits, caches[name] = lm.decode_step(
                cfg, params, caches[name], toks, pos, kv_policy=name)
            outs[name] = np.asarray(logits, np.float64)
        a, b = outs["in-place"], outs["in-place-chunked"]
        assert np.isfinite(b).all()
        assert np.abs(a - b).max() <= 0.02 * (np.abs(a).max() + 1e-6)
        toks = jnp.argmax(jnp.asarray(outs["in-place"]),
                          axis=-1).astype(jnp.int32)


def test_chunked_policy_replace_revalidates():
    """``dataclasses.replace`` re-runs the policy validators — the same
    path the serve-step override uses."""
    kvp = kvcache.get_kv_policy("in-place")
    with pytest.raises(ValueError, match="attention_impl"):
        dataclasses.replace(kvp, attention_impl="flash")
    with pytest.raises(ValueError, match="chunk_pages"):
        dataclasses.replace(kvp, chunk_pages=0)
