"""Request-level serving front-end: lifecycle, page-pool accounting,
telemetry, and bit-determinism.

Two layers of coverage:

* hypothesis property tests over the HOST-side state machine (queue +
  allocator + lifecycle accounting, no model) — random admission/finish
  interleavings can never leak pages, evicted slots are re-usable. These
  skip cleanly where hypothesis isn't installed (CI has it).
* deterministic real-model tests through one jitted serve step — a
  seeded burst replay is bit-identical across two runs, pool accounting
  is exact after drain, and per-request KV fault attribution surfaces in
  the telemetry.
"""
import dataclasses
import json

import jax
import pytest

from repro.serving import frontend, kvcache, protected, telemetry


# ---------------------------------------------------------------------------
# host-side unit tests (no model)
# ---------------------------------------------------------------------------


def test_request_validation_and_queue_rejects():
    with pytest.raises(ValueError, match="empty"):
        frontend.Request(rid=0, prompt=(), max_new=2)
    with pytest.raises(ValueError, match="max_new"):
        frontend.Request(rid=0, prompt=(1,), max_new=0)
    q = frontend.RequestQueue(max_total_tokens=32, max_pages=2,
                              page_size=16)
    ok = frontend.Request(rid=1, prompt=(1, 2, 3), max_new=4)
    assert q.push(ok) is None and len(q) == 1
    too_long = frontend.Request(rid=2, prompt=tuple(range(1, 31)),
                                max_new=8)
    assert "max_len" in q.push(too_long)
    q2 = frontend.RequestQueue(max_total_tokens=64, max_pages=2,
                               page_size=16)
    too_wide = frontend.Request(rid=3, prompt=tuple(range(1, 41)),
                                max_new=20)
    assert "allocatable" in q2.push(too_wide)
    assert len(q2) == 0 and q.pop() is ok


def test_percentile_and_deterministic_view():
    assert telemetry.percentile([], 99) is None
    assert telemetry.percentile([5.0], 50) == 5.0
    xs = list(range(1, 101))
    assert telemetry.percentile(xs, 50) == 50
    assert telemetry.percentile(xs, 99) == 99
    assert telemetry.percentile(xs, 100) == 100
    ev = [{"event": "step", "step": 0, "step_ms": 1.23, "ttft_s": 9.9,
           "pool_free": 4}]
    assert telemetry.deterministic_view(ev) == [
        {"event": "step", "step": 0, "pool_free": 4}]


def test_collector_streams_jsonl(tmp_path):
    path = tmp_path / "t.jsonl"
    with telemetry.TelemetryCollector(str(path)) as col:
        col.emit("enqueue", rid=0, step=0, prompt_len=3, max_new=2)
        col.emit("step", step=0, pool_free=4, step_ms=0.5)
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert lines == col.events and len(lines) == 2
    assert lines[0]["event"] == "enqueue"


# ---------------------------------------------------------------------------
# hypothesis: the lifecycle state machine never leaks pages
# ---------------------------------------------------------------------------

try:
    import hypothesis as hyp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:     # local images may lack it; CI installs it
    HAVE_HYPOTHESIS = False


class _LifecycleSim:
    """Host-side mirror of the front-end's accounting: FIFO queue,
    slot admission, page alloc at admit, free+park at finish. No model —
    'decode' just counts steps, so hypothesis can hammer interleavings."""

    def __init__(self, slots, n_pages, page_size, max_len):
        self.alloc = kvcache.PageAllocator(n_pages, reserved=slots)
        self.queue = frontend.RequestQueue(max_len,
                                           self.alloc.free_count,
                                           page_size)
        self.page_size = page_size
        self.slots = [None] * slots
        self.slot_history = [0] * slots
        self.finished = []

    def submit(self, req):
        return self.queue.push(req)

    def admit(self):
        while self.queue.peek() is not None:
            free = next((i for i, s in enumerate(self.slots)
                         if s is None), None)
            if free is None:
                return
            need = kvcache.pages_needed(self.queue.peek().total_tokens,
                                        self.page_size)
            if not self.alloc.can(need):
                return
            req = self.queue.pop()
            self.slots[free] = (req, self.alloc.alloc(need))
            self.slot_history[free] += 1

    def finish(self, slot):
        req, pages = self.slots[slot]
        self.alloc.free(pages)
        self.slots[slot] = None
        self.finished.append(req.rid)


def _never_leak_body(lengths, rnd):
    """Property body: for ANY request mix and ANY finish order, after the
    last request drains the allocator's free count equals its initial
    value, and no admission ever double-books a page."""
    sim = _LifecycleSim(slots=3, n_pages=9, page_size=8, max_len=32)
    initial_free = sim.alloc.free_count
    reqs = [frontend.Request(rid=i, prompt=tuple(range(1, pl + 1)),
                             max_new=mn)
            for i, (pl, mn) in enumerate(lengths)]
    submitted = [r for r in reqs if sim.submit(r) is None]
    n_done = 0
    while n_done < len(submitted):
        sim.admit()
        live = [i for i, s in enumerate(sim.slots) if s is not None]
        assert live or sim.queue.peek() is None, "deadlock with work queued"
        # occupancy never exceeds the pool, reserved pages never leave
        in_flight = [p for i in live for p in sim.slots[i][1]]
        assert len(in_flight) == len(set(in_flight)), "double-booked page"
        assert all(p >= 3 for p in in_flight), "parking page allocated"
        assert sim.alloc.free_count == initial_free - len(in_flight)
        sim.finish(rnd.choice(live))
        n_done += 1
    assert sim.alloc.free_count == initial_free        # nothing leaked
    assert sorted(sim.finished) == sorted(r.rid for r in submitted)


def _slot_reuse_body(rnd):
    """Property body: slots cycle — with more requests than slots and
    random finish order, every slot hosts multiple tenants."""
    sim = _LifecycleSim(slots=2, n_pages=8, page_size=8, max_len=32)
    for i in range(8):
        assert sim.submit(frontend.Request(
            rid=i, prompt=(1, 2, 3), max_new=2)) is None
    done = 0
    while done < 8:
        sim.admit()
        live = [i for i, s in enumerate(sim.slots) if s is not None]
        sim.finish(rnd.choice(live))
        done += 1
    assert all(h >= 2 for h in sim.slot_history), sim.slot_history
    assert sim.alloc.free_count == 6


if HAVE_HYPOTHESIS:

    @hyp.given(
        st.lists(st.tuples(st.integers(1, 24), st.integers(1, 12)),
                 min_size=1, max_size=24),
        st.randoms(use_true_random=False))
    @hyp.settings(max_examples=60, deadline=None)
    def test_random_interleavings_never_leak_pages(lengths, rnd):
        _never_leak_body(lengths, rnd)

    @hyp.given(st.randoms(use_true_random=False))
    @hyp.settings(max_examples=25, deadline=None)
    def test_evicted_slots_are_reusable(rnd):
        _slot_reuse_body(rnd)

else:   # keep one seeded spot-check of each invariant without hypothesis

    def test_random_interleavings_never_leak_pages():
        import random
        rnd = random.Random(7)
        lengths = [(rnd.randint(1, 24), rnd.randint(1, 12))
                   for _ in range(16)]
        _never_leak_body(lengths, rnd)

    def test_evicted_slots_are_reusable():
        import random
        _slot_reuse_body(random.Random(13))


# ---------------------------------------------------------------------------
# real-model: one jitted step, burst replay, fault attribution
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def burst_rig(plan_setup):
    cfg, plan, enc = plan_setup(arch="deepseek-7b", backend="xla")
    kvp = dataclasses.replace(kvcache.get_kv_policy("in-place"),
                              per_slot_flags=True)
    step = jax.jit(protected.make_serve_step(cfg, plan=plan,
                                             with_flags=True,
                                             kv_policy=kvp))
    return cfg, plan, enc, kvp, step


def _small_waves(cfg, seed=11):
    return frontend.make_waves(seed=seed, n_waves=2, wave_size=3,
                               vocab=cfg.vocab, prompt_len=(3, 6),
                               max_new=(2, 4), gap_steps=4)


def test_burst_drains_with_exact_pool_accounting(burst_rig):
    cfg, plan, enc, kvp, step = burst_rig
    events, summ, results = frontend.run_burst(
        cfg, enc, plan=plan, waves=_small_waves(cfg), slots=2,
        max_len=32, kv_policy=kvp, serve_step=step)
    assert summ["requests"]["finished"] == summ["requests"]["submitted"] == 6
    assert summ["pool"]["leaked_pages"] == 0
    assert summ["pool"]["final_free"] == summ["pool"]["initial_free"]
    assert summ["due"]["total"] == 0                  # no faults injected
    assert summ["gen_tokens"] == sum(len(v) for v in results.values())
    # lifecycle ordering per request: enqueue <= admit < first <= finish
    by_rid = {}
    for e in events:
        if "rid" in e:
            by_rid.setdefault(e["rid"], {})[e["event"]] = e
    assert len(by_rid) == 6
    for rid, evs in by_rid.items():
        assert set(evs) == {"enqueue", "admit", "first_token", "finish"}
        assert (evs["enqueue"]["step"] <= evs["admit"]["step"]
                < evs["first_token"]["step"] <= evs["finish"]["step"])
        assert len(results[rid]) == evs["enqueue"]["max_new"]
        assert isinstance(evs["first_token"]["ttft_steps"], int)
        assert evs["first_token"]["ttft_steps"] >= 0


def test_seeded_burst_replay_is_bit_deterministic(burst_rig):
    """The acceptance: same seed, same compiled step -> identical token
    streams AND identical deterministic telemetry views, twice."""
    cfg, plan, enc, kvp, step = burst_rig
    runs = [frontend.run_burst(cfg, enc, plan=plan,
                               waves=_small_waves(cfg), slots=2,
                               max_len=32, kv_policy=kvp, serve_step=step)
            for _ in range(2)]
    (ev1, s1, r1), (ev2, s2, r2) = runs
    assert r1 == r2
    assert telemetry.deterministic_view(ev1) == \
        telemetry.deterministic_view(ev2)
    # and the workload itself is seed-stable
    w1 = _small_waves(cfg)
    w2 = _small_waves(cfg)
    assert w1 == w2
    assert _small_waves(cfg, seed=12) != w1


def test_faulty_burst_attributes_due_per_request(burst_rig):
    """Injected KV faults surface as per-request (corrected, DUE) counts
    in finish events — and the faulted replay is ALSO deterministic."""
    cfg, plan, enc, kvp, step = burst_rig
    kw = dict(plan=plan, waves=_small_waves(cfg), slots=2, max_len=32,
              kv_policy=kvp, serve_step=step, fault_rate=2e-3,
              fault_seed=3)
    ev1, s1, r1 = frontend.run_burst(cfg, enc, **kw)
    ev2, s2, r2 = frontend.run_burst(cfg, enc, **kw)
    assert r1 == r2
    assert telemetry.deterministic_view(ev1) == \
        telemetry.deterministic_view(ev2)
    assert s1["due"]["corrected_total"] > 0   # in-place corrects singles
    assert s1["pool"]["leaked_pages"] == 0    # faults never leak pages
    fin = [e for e in ev1 if e["event"] == "finish"]
    assert sum(f["kv_corrected"] for f in fin) == s1["due"]["corrected_total"]


def test_summary_and_csv_roundtrip(burst_rig, tmp_path):
    cfg, plan, enc, kvp, step = burst_rig
    tpath = tmp_path / "telemetry.jsonl"
    events, summ, _ = frontend.run_burst(
        cfg, enc, plan=plan, waves=_small_waves(cfg), slots=2, max_len=32,
        kv_policy=kvp, serve_step=step, telemetry_path=str(tpath))
    streamed = [json.loads(l) for l in tpath.read_text().splitlines()]
    assert streamed == events
    assert summ["schema"] == telemetry.SUMMARY_SCHEMA
    for k in ("p50", "p95", "p99"):
        assert summ["ttft_steps"][k] is not None
        assert summ["per_token_ms"][k] is not None
    csv_path = tmp_path / "requests.csv"
    telemetry.write_requests_csv(events, str(csv_path))
    rows = csv_path.read_text().splitlines()
    assert len(rows) == 1 + summ["requests"]["submitted"]
    assert rows[0].startswith("rid,enqueue_step,prompt_len")
    jpath = tmp_path / "summary.json"
    telemetry.write_summary(summ, str(jpath))
    assert json.loads(jpath.read_text()) == summ


def test_per_slot_flags_supported_on_every_attention_path():
    """PR 7 forced per-slot attribution onto the reference path only (the
    fused kernel reduced flags to scalars in-grid); the kernels now emit
    per-row flags, so every policy accepts — and the front-end forces —
    ``per_slot_flags``."""
    for name in ("in-place", "in-place-fused", "in-place-chunked"):
        p = dataclasses.replace(kvcache.get_kv_policy(name),
                                per_slot_flags=True)
        assert p.per_slot_flags


# ---------------------------------------------------------------------------
# prefix sharing: refcount state machine + real-model CoW
# ---------------------------------------------------------------------------


class _SharingSim:
    """Host-side mirror of the sharing accounting: slots hold page
    references, a prefix index holds its OWN references, and random
    fork (retain) / publish / evict / finish interleavings must keep the
    allocator conserved — no leaks, no double frees."""

    def __init__(self, slots, n_pages, reserved):
        self.alloc = kvcache.PageAllocator(n_pages, reserved=reserved)
        self.allocatable = self.alloc.free_count
        self.slots = [None] * slots         # slot -> list of held pids
        self.index = []                     # pids the cache holds a ref on

    def check(self):
        # conservation + exact refcounts: each page's count equals the
        # number of mappings (slot holdings + index pins) that exist
        assert self.alloc.free_count + self.alloc.live_count \
            == self.allocatable
        held: dict = {}
        for pages in self.slots:
            for p in pages or ():
                held[p] = held.get(p, 0) + 1
        for p in self.index:
            held[p] = held.get(p, 0) + 1
        for p, n in held.items():
            assert self.alloc.refcount(p) == n, (p, n)
        assert self.alloc.live_count == len(held)

    def admit(self, free_slot, n_fresh, n_shared):
        shared = self.index[:n_shared]
        if not self.alloc.can(n_fresh):
            return
        fresh = self.alloc.alloc(n_fresh)
        self.alloc.retain(shared)
        self.slots[free_slot] = list(shared) + list(fresh)

    def publish(self, slot, j):
        pid = self.slots[slot][j]
        if pid in self.index:
            return
        self.alloc.retain([pid])
        self.index.append(pid)

    def evict(self, j):
        pid = self.index.pop(j)
        self.alloc.free([pid])

    def finish(self, slot):
        self.alloc.free(self.slots[slot])
        self.slots[slot] = None


def _sharing_refcount_body(rnd):
    sim = _SharingSim(slots=3, n_pages=12, reserved=2)
    for _ in range(60):
        ops = []
        free_slots = [i for i, s in enumerate(sim.slots) if s is None]
        live = [i for i, s in enumerate(sim.slots) if s is not None]
        if free_slots:
            ops.append(("admit", free_slots))
        if live:
            ops.append(("publish", live))
            ops.append(("finish", live))
        if sim.index:
            ops.append(("evict", None))
        op, arg = rnd.choice(ops)
        if op == "admit":
            sim.admit(rnd.choice(arg), rnd.randint(1, 3),
                      rnd.randint(0, len(sim.index)))
        elif op == "publish":
            slot = rnd.choice(arg)
            sim.publish(slot, rnd.randrange(len(sim.slots[slot])))
        elif op == "evict":
            sim.evict(rnd.randrange(len(sim.index)))
        elif op == "finish":
            sim.finish(rnd.choice(arg))
        sim.check()
    # drain: finish every slot, drop the cache -> everything comes back
    for i, s in enumerate(sim.slots):
        if s is not None:
            sim.finish(i)
    while sim.index:
        sim.evict(0)
    sim.check()
    assert sim.alloc.free_count == sim.allocatable
    assert sim.alloc.live_count == 0
    # and the pool rejects a stale free explicitly
    with pytest.raises(ValueError, match="double free"):
        sim.alloc.free([2])


if HAVE_HYPOTHESIS:

    @hyp.given(st.randoms(use_true_random=False))
    @hyp.settings(max_examples=40, deadline=None)
    def test_sharing_interleavings_never_leak_or_double_free(rnd):
        _sharing_refcount_body(rnd)

else:

    def test_sharing_interleavings_never_leak_or_double_free():
        import random
        _sharing_refcount_body(random.Random(29))


def _cow_waves(cfg, seed=11):
    """Three staggered single-request waves over ONE 16-token prompt
    (page_size 16 -> one full shared page, prompt ends exactly on the
    page boundary so every sharer takes the CoW path). The gap outlasts
    the first request's prefill, so its published page is in the index
    before the next admission."""
    return frontend.make_waves(seed=seed, n_waves=3, wave_size=1,
                               vocab=cfg.vocab, prompt_len=(0, 0),
                               max_new=(2, 4), gap_steps=20,
                               shared_prefix_len=16)


def _savings_waves(cfg, seed=11):
    """One publisher, then TWO concurrent sharers over a 32-token (two
    full pages) shared prefix plus a 1-2 token per-request suffix — the
    suffix keeps the first write off the shared pages (no CoW), so each
    sharer's budget is 1 fresh page instead of 3."""
    reqs = frontend.make_waves(seed=seed, n_waves=3, wave_size=1,
                               vocab=cfg.vocab, prompt_len=(1, 2),
                               max_new=(2, 4), gap_steps=40,
                               shared_prefix_len=32)
    # rebase into publisher @0 + a simultaneous sharer pair @40
    return [reqs[0]] + [dataclasses.replace(r, arrival_step=40)
                        for r in reqs[1:]]


def test_prefix_sharing_is_bit_identical_and_saves_pages(burst_rig):
    """The sharing acceptance: identical token streams with sharing on
    vs off, measured page savings for concurrent shared-prefix requests,
    zero leaked pages, and a bit-deterministic replay."""
    cfg, plan, enc, kvp, step = burst_rig
    waves = _savings_waves(cfg)
    kw = dict(plan=plan, waves=waves, slots=2, max_len=48, kv_policy=kvp,
              serve_step=step)
    ev_solo, s_solo, r_solo = frontend.run_burst(cfg, enc, **kw)
    ev_sh, s_sh, r_sh = frontend.run_burst(cfg, enc, prefix_sharing=True,
                                           **kw)
    assert r_sh == r_solo                  # sharing never changes tokens
    assert s_sh["pool"]["leaked_pages"] == 0
    assert s_solo["sharing"]["pages_shared"] == 0
    sh = s_sh["sharing"]
    assert sh["pages_shared"] == 4         # 2 sharers x 2 full pages
    assert sh["tokens_reused"] == 64
    assert sh["cow_copies"] == 0           # suffix starts off-page
    assert sh["pages_allocated_total"] < sh["solo_pages_total"]
    # the headline: two concurrent sharers peak below the solo twin
    assert (s_sh["pool"]["peak_pages_in_use"]
            < s_solo["pool"]["peak_pages_in_use"])
    assert s_sh["steps"] < s_solo["steps"]  # reused prefill = fewer steps
    # cached pages are pinned on purpose, not leaked
    assert s_sh["pool"]["cached_pages"] > 0
    ev2, s2, r2 = frontend.run_burst(cfg, enc, prefix_sharing=True, **kw)
    assert r2 == r_sh
    assert telemetry.deterministic_view(ev2) == \
        telemetry.deterministic_view(ev_sh)
    admits = [e for e in ev_sh if e["event"] == "admit"]
    assert admits[0]["pages_shared"] == 0
    assert all(a["pages_shared"] == 2 and a["cow_copied"] == 0
               for a in admits[1:])


def test_cow_on_fully_shared_prompt(burst_rig, tmp_path):
    """A prompt that IS a published prefix (ends on the page boundary)
    re-consumes its last token, so the last shared page gets a private
    CoW clone — tokens still bit-identical to the no-sharing run; the
    sharing fields survive the JSONL stream and the per-request CSV."""
    cfg, plan, enc, kvp, step = burst_rig
    waves = _cow_waves(cfg)
    kw = dict(plan=plan, waves=waves, slots=2, max_len=32, kv_policy=kvp,
              serve_step=step)
    _, s_solo, r_solo = frontend.run_burst(cfg, enc, **kw)
    tpath = tmp_path / "telemetry.jsonl"
    ev_sh, s_sh, r_sh = frontend.run_burst(cfg, enc, prefix_sharing=True,
                                           telemetry_path=str(tpath),
                                           **kw)
    assert [json.loads(l) for l in tpath.read_text().splitlines()] == ev_sh
    csv_path = tmp_path / "requests.csv"
    telemetry.write_requests_csv(ev_sh, str(csv_path))
    rows = csv_path.read_text().splitlines()
    header = rows[0].split(",")
    for col in ("pages_shared", "tokens_reused", "cow_copied"):
        assert col in header
    shared_col = [r.split(",")[header.index("pages_shared")]
                  for r in rows[1:]]
    assert shared_col == ["0", "1", "1"]
    assert r_sh == r_solo
    assert s_sh["pool"]["leaked_pages"] == 0
    admits = [e for e in ev_sh if e["event"] == "admit"]
    assert admits[0]["pages_shared"] == 0
    assert all(a["pages_shared"] == 1 and a["cow_copied"] == 1
               for a in admits[1:])
    cows = [e for e in ev_sh if e["event"] == "cow"]
    assert len(cows) == len(admits) - 1 == s_sh["sharing"]["cow_copies"]
    # the clone is a PRIVATE page: src is the cached page, dst fresh
    assert all(c["src"] != c["dst"] for c in cows)
    assert s_sh["sharing"]["tokens_reused"] == 15 * (len(admits) - 1)


def test_prefix_cache_evicts_lru_by_hit_keeping_hot_prefix(burst_rig):
    """Eviction is LRU-by-*hit*: under pool pressure the prefix that was
    published first but hit most recently SURVIVES, while the
    never-re-hit one is evicted — publication order alone must not decide
    (the regression: an insertion-order eviction would drop the hot
    prefix here)."""
    cfg, plan, enc, kvp, step = burst_rig
    ps = kvp.page_size
    fe = frontend.ServingFrontend(cfg, enc, plan=plan, slots=2,
                                  max_len=2 * ps, n_pages=5,
                                  kv_policy=kvp, serve_step=step,
                                  prefix_sharing=True)
    hot = tuple(range(1, ps + 1))          # published FIRST (oldest)
    cold = tuple(range(101, 101 + ps))     # published second
    for rid, prompt in ((0, hot), (1, cold)):
        fe.submit(frontend.Request(rid=rid, prompt=prompt, max_new=2))
        fe.run()
    assert set(fe._prefix_index) == {hot, cold}
    # re-hit the old prefix: a sharer maps its cached page
    fe.submit(frontend.Request(rid=2, prompt=hot + (7, 8, 9), max_new=2))
    fe.run()
    admit = [e for e in fe.telemetry.events
             if e["event"] == "admit" and e["rid"] == 2]
    assert admit[0]["pages_shared"] == 1
    # now force pressure: 2 fresh pages wanted, 1 free -> one eviction
    assert fe.allocator.free_count == 1
    # 15-token prompt + 4 generated spans 2 pages but never completes a
    # page inside the prompt, so it cannot publish a prefix of its own
    fe.submit(frontend.Request(rid=3, prompt=tuple(range(200, 200 + ps - 1)),
                               max_new=4))
    fe.run()
    assert hot in fe._prefix_index         # recently hit -> survives
    assert cold not in fe._prefix_index    # least recently hit -> evicted
    assert len(fe._prefix_index) == 1
    # eviction released exactly the cold page; accounting stays exact
    assert fe.drop_prefix_cache() == 1
    assert fe.allocator.live_count == 0


def test_prefix_cache_drop_releases_pages(burst_rig):
    cfg, plan, enc, kvp, step = burst_rig
    fe = frontend.ServingFrontend(cfg, enc, plan=plan, slots=2,
                                  max_len=32, kv_policy=kvp,
                                  serve_step=step, prefix_sharing=True)
    for req in _cow_waves(cfg):
        fe.submit(dataclasses.replace(req, arrival_step=0))
    fe.run()
    free_with_cache = fe.allocator.free_count
    dropped = fe.drop_prefix_cache()
    assert dropped > 0
    assert fe.allocator.free_count == free_with_cache + dropped
    assert fe.allocator.live_count == 0
    assert fe.drop_prefix_cache() == 0     # idempotent
