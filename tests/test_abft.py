"""ABFT in the fused kernel: zero false positives at fault rate 0 on both
backends, guaranteed detection of injected accumulator flips on the exact
int8 paths, activation-range clamp semantics, the flags channel through the
serve step, and the compute-fault campaign."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, protection
from repro.core import ecc, quant
from repro.kernels import ref
from repro.kernels.ecc_qmatmul import ecc_qmatmul
from repro.models import lm
from repro.serving import protected


def _wot_weights(rng, shape):
    w = rng.integers(-64, 64, size=shape).astype(np.int8)
    flat = w.reshape(-1)
    flat[7::8] = rng.integers(-128, 128, size=flat[7::8].size)
    return flat.reshape(shape)


def _enc(wq):
    k, n = wq.shape
    return np.asarray(ecc.encode64(jnp.asarray(
        wq.view(np.uint8).reshape(k, n // 8, 8)))).reshape(k, n)


# ---------------------------------------------------------------------------
# kernel: zero false positives at fault rate 0
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (32, 64, 128, 16, 64, 0),    # clean tiles, full-K
    (45, 100, 72, 16, 32, 0),    # ragged everything (edge-tile masking)
    (16, 256, 64, 16, 32, 64),   # decode-once multi-K-strip grid
])
def test_float_abft_zero_false_positives(m, k, n, bm, bn, bk):
    """Clean weights, clean accumulator: the float-path tolerance check
    never fires, and the guarded kernel's output is bit-identical to the
    unguarded one (the checksums are extra outputs, not a value change)."""
    rng = np.random.default_rng(m + n)
    wenc = jnp.asarray(_enc(_wot_weights(rng, (k, n))))
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w_scale = jnp.float32(0.01)
    out, (rows, col_mm) = ecc_qmatmul(a, wenc, w_scale, bm=bm, bn=bn, bk=bk,
                                      with_abft=True)
    assert rows.shape == (m, 2)
    assert int(rows.sum()) == 0 and int(col_mm) == 0
    plain = ecc_qmatmul(a, wenc, w_scale, bm=bm, bn=bn, bk=bk)
    assert np.array_equal(np.asarray(out), np.asarray(plain))


@pytest.mark.parametrize("m,k,n,bm,bn", [
    (32, 64, 128, 16, 64),
    (45, 100, 72, 16, 32),       # masked edge tiles
])
def test_int8_paths_abft_zero_false_positives(m, k, n, bm, bn):
    """The int8 accumulator and requantize-epilogue checks compare int32
    modular sums bit-exactly — zero false positives by construction, and
    the guarded outputs equal the unguarded ones bit for bit."""
    rng = np.random.default_rng(m * n)
    wenc = jnp.asarray(_enc(_wot_weights(rng, (k, n))))
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
    out, (rows, col_mm) = ecc_qmatmul(a, wenc, bm=bm, bn=bn, with_abft=True)
    assert int(rows[:, 0].sum()) == 0 and int(col_mm) == 0
    assert np.array_equal(np.asarray(out),
                          np.asarray(ecc_qmatmul(a, wenc, bm=bm, bn=bn)))
    a_scale = jnp.asarray(rng.uniform(0.005, 0.05, size=(m, 1))
                          .astype(np.float32))
    w_scale = jnp.float32(0.013)
    out, (rows, col_mm) = ecc_qmatmul(a, wenc, w_scale, a_scale=a_scale,
                                      bm=bm, bn=bn, with_abft=True)
    assert int(rows[:, 0].sum()) == 0 and int(col_mm) == 0
    plain = ecc_qmatmul(a, wenc, w_scale, a_scale=a_scale, bm=bm, bn=bn)
    assert np.array_equal(np.asarray(out, np.float32),
                          np.asarray(plain, np.float32))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("mode", [None, "dynamic", "static"])
def test_protected_weight_abft_clean_on_both_backends(backend, mode):
    """ProtectedWeight's guarded routes — fused kernel AND the XLA
    ``ref.abft_counts`` mirror, float AND int8 — record (0, 0) on clean
    weights, and the value path is bit-identical to the unguarded view."""
    from repro.protection.fused import ProtectedWeight
    rng = np.random.default_rng(17)
    k, n = 64, 128
    w = jnp.asarray(_wot_weights(rng, (k, n)).astype(np.float32) * 0.01)
    pt = protection.ProtectionPolicy().encode_leaf(w, "in-place")
    x = jnp.asarray(rng.normal(size=(3, k)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    seen = []

    def record_abft(mm, hits):
        seen.append((int(np.asarray(mm).sum()), int(np.asarray(hits).sum())))

    kw = dict(act_quant=mode, a_scale=0.02 if mode == "static" else None)
    guarded = ProtectedWeight(pt, backend, abft=True,
                              record_abft=record_abft, **kw).matmul(x)
    plain = ProtectedWeight(pt, backend, **kw).matmul(x)
    assert seen and all(s == (0, 0) for s in seen)
    assert np.array_equal(np.asarray(guarded, np.float32),
                          np.asarray(plain, np.float32))


# ---------------------------------------------------------------------------
# kernel: injected accumulator faults are detected
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bit", [0, 1, 7, 15, 23, 30])
def test_single_flip_accumulator_fault_always_detected_int8(bit):
    """A single bit flipped into the int32 accumulator (any position) must
    trip the bit-exact checksums on BOTH exact paths — raw int8 and the
    requantize epilogue — and land on the faulted row."""
    rng = np.random.default_rng(bit)
    m, k, n = 16, 64, 64
    wenc = jnp.asarray(_enc(_wot_weights(rng, (k, n))))
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
    _, (rows, col_mm) = ecc_qmatmul(a, wenc, bm=8, bn=32, with_abft=True,
                                    fault_bits=1 << bit)
    assert int(rows[0, 0]) >= 1, "row checksum missed the (0,0) flip"
    assert int(col_mm) >= 1, "column checksum missed the (0,0) flip"
    assert int(rows[1:, 0].sum()) == 0, "mismatch attributed to clean rows"
    _, (rows, col_mm) = ecc_qmatmul(a, wenc, jnp.float32(0.01),
                                    a_scale=jnp.float32(0.02), bm=8, bn=32,
                                    with_abft=True, fault_bits=1 << bit)
    assert int(rows[0, 0]) >= 1 and int(col_mm) >= 1


@pytest.mark.parametrize("bit", [23, 27, 30])
def test_high_bit_float_accumulator_fault_detected(bit):
    """Float-path detection is tolerance-gated, so only magnitude-visible
    corruption is promised: exponent-region flips must fire."""
    rng = np.random.default_rng(bit)
    m, k, n = 16, 64, 64
    wenc = jnp.asarray(_enc(_wot_weights(rng, (k, n))))
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    _, (rows, col_mm) = ecc_qmatmul(a, wenc, jnp.float32(0.01), bm=8, bn=32,
                                    with_abft=True, fault_bits=1 << bit)
    assert int(rows[:, 0].sum()) + int(col_mm) >= 1


def test_fault_injection_is_a_test_hook_not_a_value_change():
    """fault_bits corrupts the accumulator the checksums watch — the
    returned product must carry the fault (that's what detection means)."""
    rng = np.random.default_rng(5)
    m, k, n = 8, 64, 64
    wenc = jnp.asarray(_enc(_wot_weights(rng, (k, n))))
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
    clean = np.asarray(ecc_qmatmul(a, wenc, bm=8, bn=32))
    dirty, _ = ecc_qmatmul(a, wenc, bm=8, bn=32, with_abft=True,
                           fault_bits=1 << 7)
    dirty = np.asarray(dirty)
    assert dirty[0, 0] == clean[0, 0] ^ (1 << 7)
    assert np.array_equal(dirty.reshape(-1)[1:], clean.reshape(-1)[1:])


# ---------------------------------------------------------------------------
# activation-range clamps
# ---------------------------------------------------------------------------


def test_clamp_matches_reference_and_counts_hits():
    """The fused epilogue's clamp equals ``ref.clamp_counts`` on the f32
    epilogue output — same clipped values, same per-row hit counts — and
    rides the ABFT rows channel even with the checksums off."""
    rng = np.random.default_rng(21)
    m, k, n = 16, 64, 64
    wq = _wot_weights(rng, (k, n))
    wenc = jnp.asarray(_enc(wq))
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
    a_scale, w_scale = jnp.float32(0.02), jnp.float32(0.013)
    y = (ref.ecc_qmatmul_ref(a, wenc).astype(jnp.float32)
         * (a_scale * w_scale))
    c = float(np.quantile(np.abs(np.asarray(y)), 0.9))  # force real hits
    out, (rows, col_mm) = ecc_qmatmul(a, wenc, w_scale, a_scale=a_scale,
                                      bm=8, bn=32, clamp=c)
    want, hits = ref.clamp_counts(y, c)
    assert int(np.asarray(hits).sum()) > 0
    assert np.array_equal(np.asarray(rows[:, 1]), np.asarray(hits))
    assert int(rows[:, 0].sum()) == 0 and int(col_mm) == 0
    assert np.array_equal(np.asarray(out, np.float32),
                          np.asarray(want.astype(jnp.bfloat16), np.float32))


def test_clamp_rejected_on_raw_int8_path():
    rng = np.random.default_rng(22)
    wenc = jnp.asarray(_enc(_wot_weights(rng, (64, 64))))
    a = jnp.zeros((4, 64), jnp.int8)
    with pytest.raises(ValueError, match="clamp"):
        ecc_qmatmul(a, wenc, clamp=1.0)


def test_plan_with_abft_knobs_and_summary():
    """plan.with_abft marks exactly the >=2-D protected leaves, carries
    per-leaf clamp bounds, and the summary counts both."""
    cfg = configs.get_smoke("minitron-4b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    plan = protected.make_plan(params, protection.ProtectionPolicy())
    assert plan.summary()["n_abft"] == 0
    guarded = plan.with_abft()
    s = guarded.summary()
    n_mat = sum(1 for lp in guarded if lp.protected and len(lp.shape) >= 2)
    assert s["n_abft"] == n_mat > 0 and s["n_clamped"] == 0
    some = next(p for p, lp in guarded.leaves.items() if lp.abft)
    clamped = guarded.with_abft(clamps={some: 3.5})
    assert clamped.leaves[some].clamp == 3.5
    assert clamped.summary()["n_clamped"] == 1
    off = clamped.with_abft(False)
    assert off.summary()["n_abft"] == 0
    assert off.leaves[some].clamp == 3.5  # clamps survive the abft toggle


# ---------------------------------------------------------------------------
# serve step: the flags channel
# ---------------------------------------------------------------------------


def test_serve_step_abft_flags_channel_and_identity():
    """An ABFT-guarded serve step emits the ``layers_abft``/``top_abft``
    flags channel (all zeros at fault rate 0), its logits are bit-identical
    to the unguarded step, and an unguarded plan emits NO abft keys."""
    cfg = configs.get_smoke("minitron-4b")
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    plan = protected.make_plan(params, protection.ProtectionPolicy())
    enc = plan.encode_tree(params)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    base = jax.jit(protected.make_serve_step(cfg, plan=plan,
                                             with_flags=True))
    logits0, _, flags0 = base(enc, lm.init_cache(cfg, 2, 32), tok, pos)
    assert not any(k.endswith("_abft") for k in flags0)
    step = jax.jit(protected.make_serve_step(cfg, plan=plan.with_abft(),
                                             with_flags=True))
    logits, _, flags = step(enc, lm.init_cache(cfg, 2, 32), tok, pos)
    ab, top = flags["layers_abft"], flags["top_abft"]
    assert ab.ndim == 2 and ab.shape[1] == 2  # (L, 2) scalar channel
    assert top.shape == (2,)
    assert int(jnp.sum(ab)) == 0 and int(jnp.sum(top)) == 0
    assert np.array_equal(np.asarray(logits, np.float32),
                          np.asarray(logits0, np.float32))


def test_prefill_abft_flags_channel():
    cfg = configs.get_smoke("minitron-4b")
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    plan = protected.make_plan(params, protection.ProtectionPolicy())
    enc = plan.encode_tree(params)
    pre = jax.jit(protected.make_prefill(cfg, plan=plan.with_abft(),
                                         chunk=16, with_flags=True))
    toks = jnp.zeros((2, 16), jnp.int32)
    _, flags = pre(enc, toks)
    assert "top_abft" in flags and int(jnp.sum(flags["top_abft"])) == 0
    assert int(jnp.sum(flags["layers_abft"])) == 0


# ---------------------------------------------------------------------------
# compute-fault campaign
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("target", ["acc", "wdec"])
def test_compute_campaign_coverage_and_zero_false_positives(target):
    """Injected compute faults are detected (full coverage on the exact
    int8 path for accumulator flips; >0 for decoded-weight corruption) and
    the rate-0 cell fires NO checksums — the CI acceptance."""
    cfg = configs.get_smoke("minitron-4b")
    params = lm.init_params(cfg, jax.random.PRNGKey(3))
    res = protection.compute_campaign(params, rates=(1e-3, 1e-2), trials=2,
                                      key=jax.random.PRNGKey(7),
                                      target=target)
    assert res.metric == "abft_coverage" and res.target == "compute"
    assert float(res.clean) == 0.0, "checksum false positives at rate 0"
    means = res.mean()
    assert all(m > 0 for m in means), means
    if target == "acc":
        assert all(m == 1.0 for m in means), "accumulator flip escaped"
    # tiny leaves may draw zero injections at the sampled rate; whatever
    # WAS injected must be accounted (and fully caught on the exact path)
    assert res.coverage_rows
    assert any(inj > 0 for _, _, inj in res.coverage_rows)
    assert all(det <= inj for _, det, inj in res.coverage_rows)
    if target == "acc":
        assert all(det == inj for _, det, inj in res.coverage_rows)
    d = res.to_dict()
    rt = protection.CampaignResult.from_dict(d)
    assert rt.coverage_rows == res.coverage_rows
    assert rt.mean() == res.mean() and rt.clean == res.clean


def test_compute_campaign_scan_matches_vmap_grid_shape():
    cfg = configs.get_smoke("minitron-4b")
    params = lm.init_params(cfg, jax.random.PRNGKey(4))
    a = protection.compute_campaign(params, rates=(1e-3,), trials=2,
                                    key=jax.random.PRNGKey(9), batch="vmap")
    b = protection.compute_campaign(params, rates=(1e-3,), trials=2,
                                    key=jax.random.PRNGKey(9), batch="scan")
    assert np.asarray(a.grid).shape == np.asarray(b.grid).shape
    assert a.clean == b.clean == 0.0


# ---------------------------------------------------------------------------
# telemetry: the additive abft roll-up
# ---------------------------------------------------------------------------


def test_telemetry_abft_rollup_additive():
    from repro.serving import telemetry
    t = telemetry.TelemetryCollector()
    base_s = dict(pool_free=8, queue_depth=0)
    base_f = dict(n_generated=4, kv_due=0, kv_corrected=0)
    t.emit("step", step=0, abft_mismatches=2, clamp_hits=1, step_ms=1.0,
           **base_s)
    t.emit("step", step=1, step_ms=1.0, **base_s)  # abft-less steps roll up
    t.emit("finish", rid=0, abft_mismatches=2, clamp_hits=1, **base_f)
    t.emit("finish", rid=1, **base_f)
    s = telemetry.summarize(t.events)
    ab = s["abft"]
    assert ab["mismatches_total"] == 2 and ab["clamp_hits_total"] == 1
    assert ab["max_per_request"] == 2
    assert ab["requests_with_mismatch"] == 1
    assert ab["requests_with_clamp"] == 1
    # the two count fields carry no wall-clock suffix: deterministic view
    dv = telemetry.deterministic_view(t.events)
    assert any("abft_mismatches" in e for e in dv)


def test_telemetry_v2_summary_without_abft_still_loads(tmp_path):
    """Older summary.json files predate the roll-up: load_summary must
    surface abft=None instead of KeyError — the additive-extension rule."""
    import json

    from repro.serving import telemetry
    t = telemetry.TelemetryCollector()
    t.emit("step", step=0, step_ms=1.0, pool_free=8, queue_depth=0)
    s = telemetry.summarize(t.events)
    s.pop("abft")
    p = tmp_path / "summary.json"
    p.write_text(json.dumps(s))
    loaded = telemetry.load_summary(p)
    assert loaded["abft"] is None
