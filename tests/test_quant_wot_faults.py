"""Quantization, WOT throttling, and fault-injection invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import faults, quant, wot


class TestQuant:
    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
        q, s = quant.quantize(x)
        err = jnp.abs(quant.dequantize(q, s) - x)
        assert float(jnp.max(err)) <= float(s) / 2 + 1e-7
        assert q.dtype == jnp.int8
        assert int(jnp.max(jnp.abs(q))) <= 127

    def test_paper_eq1(self):
        # X^q = round(X * 127 / max|X|)
        x = jnp.asarray([-2.0, -1.0, 0.0, 0.5, 4.0])
        q, s = quant.quantize(x)
        expected = np.round(np.asarray(x) * 127 / 4.0)
        assert (np.asarray(q) == expected).all()

    def test_fake_quant_gradient_is_identity(self):
        x = jnp.asarray([0.3, -0.7, 1.2])
        g = jax.grad(lambda v: jnp.sum(quant.fake_quant(v) * 2.0))(x)
        assert np.allclose(np.asarray(g), 2.0)

    def test_per_channel(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(4, 64)) * np.array([[1], [10], [100], [1000]]))
        q, s = quant.quantize(x, axis=1)
        assert s.shape == (4, 1)
        assert float(jnp.max(jnp.abs(quant.dequantize(q, s) - x) / s)) <= 0.5 + 1e-6


class TestWot:
    def test_throttle_q_invariant(self):
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.integers(-128, 128, size=4096).astype(np.int8))
        t = wot.throttle_q(q)
        assert wot.satisfies_constraint(t)
        # position 7 untouched
        assert (np.asarray(t)[7::8] == np.asarray(q)[7::8]).all()
        # idempotent
        assert (np.asarray(wot.throttle_q(t)) == np.asarray(t)).all()

    def test_throttle_only_moves_large(self):
        q = jnp.asarray(np.array([10, -64, 63, 100, -100, 5, 0, 127], np.int8))
        t = np.asarray(wot.throttle_q(q))
        assert t.tolist() == [10, -64, 63, 63, -64, 5, 0, 127]

    def test_deploy_pipeline_satisfies_constraint(self):
        # quantize -> throttle == the deployable weights (always compliant)
        rng = np.random.default_rng(3)
        w = jnp.asarray(rng.normal(size=(333,)).astype(np.float32) * 7)
        q, s = quant.quantize(w)
        assert wot.satisfies_constraint(wot.throttle_q(q))

    def test_census(self):
        q = jnp.asarray(np.array([100, 0, 0, 0, 0, 0, 0, 0] * 10, np.int8))
        assert int(wot.count_large_in_protected(q)) == 10
        hist = np.asarray(wot.large_position_histogram(q))
        assert hist[0] == 10 and hist[1:].sum() == 0

    def test_range_percentages(self):
        q = np.array([0, 10, 40, 70, -80, -5, 33, 64], np.int8)
        p = wot.range_percentages(q)
        assert abs(p["[0,32)"] - 37.5) < 1e-6
        assert abs(p["[32,64)"] - 25.0) < 1e-6
        assert abs(p["[64,128]"] - 37.5) < 1e-6

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 600))
    def test_property_throttle(self, seed, n):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.integers(-128, 128, size=n).astype(np.int8))
        t = wot.throttle_q(q)
        assert t.shape == q.shape
        assert wot.satisfies_constraint(t)


class TestFaults:
    def test_flip_count_within_collision_bound(self):
        # with-replacement sampling: colliding draws XOR-cancel pairwise, so
        # the flip count sits in [n - 2*collisions, n]; the birthday bound
        # puts expected collisions at n^2 / (2 * n_bits) = 0.5 here
        stored = np.zeros(125000, np.uint8)  # 1e6 bits
        out = faults.inject(stored, 1e-3, seed=0)
        flipped = np.unpackbits(out).sum()
        assert 0.98 * 1000 <= flipped <= 1000

    def test_deterministic(self):
        stored = np.arange(256, dtype=np.uint8)
        a = faults.inject(stored, 0.01, seed=7)
        b = faults.inject(stored, 0.01, seed=7)
        c = faults.inject(stored, 0.01, seed=8)
        assert (a == b).all() and not (a == c).all()

    def test_zero_rate_noop(self):
        stored = np.arange(64, dtype=np.uint8)
        assert (faults.inject(stored, 0.0, seed=0) == stored).all()

    def test_jax_path_flips_expected_count(self):
        stored = jnp.zeros(12500, jnp.uint8)
        out = faults.inject_jax(stored, 1e-2, jax.random.PRNGKey(0))
        n = int(np.unpackbits(np.asarray(out)).sum())
        expected = faults.n_faults(12500 * 8, 1e-2)
        assert 0.9 * expected <= n <= expected  # collisions only reduce
