"""Host-side protection schemes (``repro.protection.host``): overheads,
roundtrips, fault-trial pipeline. Paper Table-2 row names ("zero", "ecc")
resolve as aliases."""
import numpy as np
import pytest

from repro import protection


def wot_q(rng, n):
    q = rng.integers(-64, 64, size=n).astype(np.int8)
    q[7::8] = rng.integers(-128, 128, size=q[7::8].size)
    return q


@pytest.mark.parametrize("name,overhead,hw", [
    ("faulty", 0.0, False), ("zero", 0.125, False),
    ("ecc", 0.125, True), ("in-place", 0.0, True)])
def test_scheme_metadata_and_roundtrip(name, overhead, hw):
    rng = np.random.default_rng(0)
    q = wot_q(rng, 4096)
    sch = protection.get_host_scheme(name)
    st = sch.encode(q)
    assert abs(sch.space_overhead(st) - overhead) < 1e-9
    assert sch.needs_ecc_hw == hw
    assert (sch.decode(st) == q).all()


def test_inplace_single_fault_per_block_fully_corrected():
    rng = np.random.default_rng(1)
    q = wot_q(rng, 8 * 512)
    sch = protection.get_host_scheme("in-place")
    st = sch.encode(q)
    data = st.data.copy()
    for blk in range(0, 512, 3):  # 1 flip in every 3rd block
        data[blk * 8 + (blk % 8)] ^= np.uint8(1 << (blk % 8))
    out = sch.decode(protection.Stored(data, None, st.n_weights))
    assert (out == q).all()


def test_ecc_vs_inplace_equivalent_correction_strength():
    """Paper's headline: in-place == standard SEC-DED correction capability
    (single error per 64-bit block), at 0 vs 12.5% overhead."""
    rng = np.random.default_rng(2)
    q = wot_q(rng, 80000)
    rate = 1e-4
    for seed in range(3):
        bad_counts = {}
        for name in ("ecc", "in-place"):
            out = protection.run_fault_trial(name, q, rate, seed=seed)
            bad_counts[name] = int((out != q).sum())
        # both should correct the overwhelming majority of faults
        n_flips = int(round(q.size * 8 * rate))
        assert bad_counts["ecc"] <= n_flips * 0.2
        assert bad_counts["in-place"] <= n_flips * 0.2


def test_faulty_scheme_passes_faults_through():
    rng = np.random.default_rng(3)
    q = wot_q(rng, 8000)
    out = protection.run_fault_trial("faulty", q, 1e-3, 0)
    assert (out != q).sum() > 0


def test_zero_scheme_zeroes_detected():
    rng = np.random.default_rng(4)
    q = wot_q(rng, 8000)
    sch = protection.get_host_scheme("zero")
    st = sch.encode(q)
    data = st.data.copy()
    data[100] ^= 0x80  # single flip -> parity catches it
    out = sch.decode(protection.Stored(data, st.checks, st.n_weights))
    assert out[100] == 0
    assert (np.delete(out, 100) == np.delete(q, 100)).all()


def test_encoded_weights_differ_only_in_checkbit_positions():
    """In-place encoding touches ONLY bit 6 of bytes 0..6 per block."""
    rng = np.random.default_rng(5)
    q = wot_q(rng, 4096)
    st = protection.get_host_scheme("in-place").encode(q)
    diff = st.data ^ q.view(np.uint8)
    pos = np.arange(diff.size) % 8
    assert (diff[pos == 7] == 0).all()
    assert np.isin(diff[pos != 7], [0, 0x40]).all()


def test_core_protect_shim_is_gone():
    """ROADMAP said "remove next release"; this is that release."""
    with pytest.raises(ImportError):
        import repro.core.protect  # noqa: F401
    import repro.core
    assert not hasattr(repro.core, "protect")
