"""End-to-end behaviour of the paper's system: pretrain -> WOT fine-tune ->
quantize -> in-place-ECC encode -> inject faults -> evaluate; protection
ordering matches Table 2 qualitatively (in-place ~= ecc >= zero >= faulty)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.training.cnn_experiments import (accuracy, eval_with_scheme,
                                            large_count, train_cnn_wot)

ROOT = os.path.dirname(os.path.dirname(__file__))


@pytest.fixture(scope="module")
def trained():
    params, fwd, tmpl = train_cnn_wot("resnet18", pre_steps=80, wot_steps=25)
    return params, fwd, tmpl


@pytest.mark.slow
def test_wot_model_learns_and_satisfies_constraint(trained):
    params, fwd, tmpl = trained
    assert accuracy(params, fwd, tmpl, quantized=True) > 0.6
    assert large_count(params) == 0


@pytest.mark.slow
def test_protection_ordering_matches_paper(trained):
    params, fwd, tmpl = trained
    clean, _ = eval_with_scheme(params, fwd, tmpl, "faulty", 0.0, 0)
    rate = 3e-3  # amplified so small-scale effects are measurable
    accs = {}
    for name in ("faulty", "zero", "ecc", "in-place"):
        accs[name] = np.mean([
            eval_with_scheme(params, fwd, tmpl, name, rate, 1000 * s + 1)[0]
            for s in range(3)])
    # paper Table 2 ordering (with tolerance for small-model noise)
    assert abs(accs["in-place"] - accs["ecc"]) < 0.08, accs
    assert accs["in-place"] >= accs["faulty"] - 0.02, accs
    assert accs["ecc"] >= accs["zero"] - 0.05, accs
    assert clean >= accs["faulty"] - 0.02, accs


@pytest.mark.slow
def test_zero_space_overhead(trained):
    params, fwd, tmpl = trained
    _, ovh_inplace = eval_with_scheme(params, fwd, tmpl, "in-place", 0.0, 0)
    _, ovh_ecc = eval_with_scheme(params, fwd, tmpl, "ecc", 0.0, 0)
    assert ovh_inplace == 0.0
    assert abs(ovh_ecc - 0.125) < 1e-6


def test_quickstart_example_runs():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "examples/quickstart.py"],
                       capture_output=True, text=True, env=env, cwd=ROOT,
                       timeout=1800)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "zero-space" in r.stdout


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """The multi-pod dry-run entry point works end to end (smallest cell)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-base",
         "--shape", "decode_32k", "--out", "/tmp/dryrun_test.jsonl"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=1800)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "-> ok" in r.stdout
