"""Compiled on-device fault campaigns (``repro.protection.campaign``):
zero-rate == clean, vmap/scan agreement, JSON round-trip, fidelity metric,
and device<->host statistical parity on a trained CNN (the pytest-marked
quick campaign whose output CI uploads as BENCH_campaign.json)."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import protection
from repro.data import synthetic

ROOT = pathlib.Path(__file__).resolve().parents[1]
N_CLASSES, IMG, BATCH = 4, 8, 128


@pytest.fixture(scope="module")
def linear_model():
    """Template-correlator classifier: no training, instant eval, and the
    same encode/inject/decode pipeline as the real CNNs."""
    _, tmpl = synthetic.image_batch(N_CLASSES, BATCH, IMG, seed=3, step=0)
    w = tmpl.reshape(N_CLASSES, -1).T / np.sqrt(tmpl[0].size)
    params = {"fc": {"w": jnp.asarray(w, jnp.float32)}}
    fwd = lambda p, x: x.reshape(x.shape[0], -1) @ p["fc"]["w"]
    return params, fwd, tmpl


def _run(params, fwd, tmpl, scheme, *, key, **kw):
    # every test pins its own key — no silent PRNGKey(0) sharing across
    # tests (seeding audit: distinct tests get distinct fault draws)
    kw.setdefault("n_classes", N_CLASSES)
    kw.setdefault("img", IMG)
    kw.setdefault("eval_batch", BATCH)
    return protection.run_campaign(params, fwd, tmpl, scheme, key=key, **kw)


def test_zero_rate_campaign_equals_clean(linear_model):
    params, fwd, tmpl = linear_model
    for scheme in ("in-place", "secded72"):
        res = _run(params, fwd, tmpl, scheme, rates=(0.0,), trials=2,
                   key=jax.random.PRNGKey(40))
        assert res.grid == ((res.clean, res.clean),), scheme
        assert res.drop() == (0.0,)


def test_vmap_and_scan_grids_identical(linear_model):
    """Same key -> the two batching modes must produce the exact same grid
    (same per-cell key assignment), on a metric that actually degrades."""
    params, _fwd, _tmpl = linear_model
    kw = dict(rates=(1e-3, 1e-2), trials=2, key=jax.random.PRNGKey(7))
    vmap = protection.fidelity_campaign(params, "faulty", batch="vmap", **kw)
    scan = protection.fidelity_campaign(params, "faulty", batch="scan", **kw)
    assert vmap.grid == scan.grid
    assert min(min(row) for row in vmap.grid) < 1.0  # non-trivial agreement
    assert vmap.batch == "vmap" and scan.batch == "scan"


def test_campaign_result_json_roundtrip(linear_model):
    params, fwd, tmpl = linear_model
    res = _run(params, fwd, tmpl, "secded72", rates=(1e-4, 1e-3), trials=2,
               key=jax.random.PRNGKey(41))
    s = res.to_json()
    back = protection.CampaignResult.from_json(s)
    assert back == res
    d = json.loads(s)
    assert d["metric"] == "accuracy" and d["scheme"] == "secded72"
    assert abs(d["space_overhead"] - 0.125) < 1e-9
    assert d["derived"]["drop"] == list(res.drop())
    assert len(res.row()) == 2 and res.trials == 2
    # file round-trip too
    path = ROOT / "tests" / "_campaign_tmp.json"
    try:
        res.save(path)
        assert protection.CampaignResult.load(path) == res
    finally:
        path.unlink(missing_ok=True)


def test_fidelity_campaign_inplace_corrects_singles(linear_model):
    """At a rate giving exactly one flip per image, in-place decodes every
    weight back (single-error correction); faulty never does."""
    params, _fwd, _tmpl = linear_model
    kw = dict(rates=(2e-4,), trials=2, key=jax.random.PRNGKey(1))
    inplace = protection.fidelity_campaign(params, "in-place", **kw)
    faulty = protection.fidelity_campaign(params, "faulty", **kw)
    assert inplace.grid == ((1.0, 1.0),)
    assert max(faulty.grid[0]) < 1.0
    assert inplace.metric == "fidelity"


def test_fidelity_campaign_rejects_unprotected_tree():
    with pytest.raises(ValueError, match="no protected leaves"):
        protection.fidelity_campaign({"b": jnp.zeros((8,))}, "in-place")


def test_host_sampler_accepts_numpy_integer_seeds():
    from repro.core import faults
    img = np.arange(64, dtype=np.uint8)
    a = faults.inject(img, 1e-2, np.int64(7))
    b = faults.inject(img, 1e-2, 7)
    assert np.array_equal(a, b)


def test_fidelity_campaign_accepts_encoded_tree(linear_model):
    """Serving path: campaign over an already-encoded tree, mixed schemes."""
    params, _fwd, _tmpl = linear_model
    policy = protection.ProtectionPolicy(
        default_scheme="in-place", rules=[("fc", "secded72")],
        predicate=lambda p, l: getattr(l, "ndim", 0) >= 2)
    enc = policy.encode_tree(params)
    res = protection.fidelity_campaign(enc, policy, rates=(0.0,), trials=1,
                                       key=jax.random.PRNGKey(2))
    assert res.scheme == "secded72"
    assert res.grid == ((1.0,),)


# ---------------------------------------------------------------------------
# the quick campaign: trained CNN, device vs host oracle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def quick_cnn():
    from repro.training.cnn_experiments import train_cnn_wot
    return train_cnn_wot("resnet18", pre_steps=40, wot_steps=10, scale=0.125,
                         img=16)


@pytest.mark.campaign
def test_quick_campaign_device_host_parity(quick_cnn):
    """2 rates x 2 trials on a WOT-trained CNN: the compiled device campaign
    and the host-path oracle must agree statistically (same grid, independent
    RNG streams), and the result lands in BENCH_campaign.json for CI."""
    from repro.training.cnn_experiments import (_norm, eval_policy,
                                                run_scheme_campaign)
    params, fwd, tmpl = quick_cnn
    rates, trials = (1e-3, 1e-2), 2

    dev = run_scheme_campaign(params, fwd, tmpl, "in-place", rates=rates,
                              trials=trials, img=16, batch="scan",
                              key=jax.random.PRNGKey(0))
    host = protection.run_campaign_host(
        params, lambda p, x: fwd(p, _norm(x)), tmpl, eval_policy("in-place"),
        rates=rates, trials=trials, seed=0, img=16)

    # identical encode + eval batch -> identical clean accuracy
    assert abs(dev.clean - host.clean) < 1e-6
    assert dev.clean > 0.6  # the tiny model actually learned
    # statistical parity per rate (trial-mean drops, independent streams)
    for r, d_dev, d_host in zip(rates, dev.drop(), host.drop()):
        assert abs(d_dev - d_host) <= 0.25, (r, d_dev, d_host)
    # the paper's scheme keeps the drop small at the realistic rate
    assert dev.drop()[0] <= 0.15 and host.drop()[0] <= 0.15
    assert dev.space_overhead == 0.0 == host.space_overhead
    assert dev.compile_s > 0.0 and host.compile_s == 0.0

    (ROOT / "BENCH_campaign.json").write_text(json.dumps({
        "resnet18-quick/in-place/device": dev.to_dict(),
        "resnet18-quick/in-place/host": host.to_dict(),
    }, indent=2) + "\n")
