"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, bit-exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ecc, faults
from repro.kernels import ops, ref
from repro.kernels.ecc_decode import ecc_decode
from repro.kernels.ecc_qmatmul import ecc_qmatmul
from repro.kernels.throttle import throttle


def _wot_weights(rng, shape):
    w = rng.integers(-64, 64, size=shape).astype(np.int8)
    flat = w.reshape(-1)
    flat[7::8] = rng.integers(-128, 128, size=flat[7::8].size)
    return flat.reshape(shape)


@pytest.mark.parametrize("nblk,blk_n", [(64, 64), (1024, 256), (4096, 4096),
                                        (8192, 2048)])
def test_ecc_decode_sweep(nblk, blk_n):
    rng = np.random.default_rng(nblk)
    w = _wot_weights(rng, (nblk, 8))
    enc = np.asarray(ecc.encode64(jnp.asarray(w.view(np.uint8))))
    fenc = jnp.asarray(faults.inject(enc, 1e-4, seed=nblk))
    d_k, f_k = ecc_decode(fenc, blk_n=blk_n)
    d_r, f_r = ref.ecc_decode_ref(fenc)
    assert (np.asarray(d_k) == np.asarray(d_r)).all()
    assert (np.asarray(f_k) == np.asarray(f_r)).all()


def test_ecc_decode_corrects_all_singles():
    rng = np.random.default_rng(0)
    w = _wot_weights(rng, (64, 8))
    enc = np.asarray(ecc.encode64(jnp.asarray(w.view(np.uint8))))
    f = enc.copy()
    for i in range(64):  # one flip per block, all 64 positions covered
        f[i, i // 8] ^= np.uint8(1 << (i % 8))
    d_k, flags = ecc_decode(jnp.asarray(f), blk_n=64)
    assert (np.asarray(d_k).view(np.int8) == w).all()
    assert (np.asarray(flags) == 1).all()


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (128, 256, 512, 64, 128, 128),
    (256, 512, 256, 128, 64, 256),
    (64, 64, 64, 64, 64, 64),
])
def test_ecc_qmatmul_sweep(m, k, n, bm, bn, bk):
    rng = np.random.default_rng(m + k + n)
    a = rng.integers(-127, 128, size=(m, k)).astype(np.int8)
    wq = _wot_weights(rng, (k, n))
    wenc = np.asarray(ecc.encode64(
        jnp.asarray(wq.view(np.uint8).reshape(k, n // 8, 8)))).reshape(k, n)
    out_k = ecc_qmatmul(jnp.asarray(a), jnp.asarray(wenc), bm=bm, bn=bn, bk=bk)
    out_r = ref.ecc_qmatmul_ref(jnp.asarray(a), jnp.asarray(wenc))
    plain = a.astype(np.int32) @ wq.astype(np.int32)
    assert (np.asarray(out_k) == np.asarray(out_r)).all()
    assert (np.asarray(out_k) == plain).all()  # bit-exact vs unprotected


def test_ecc_qmatmul_corrects_faults():
    """Faulty encoded weights in HBM -> fused kernel returns the exact
    unfaulted matmul (single-bit faults fully corrected in VMEM)."""
    rng = np.random.default_rng(5)
    m, k, n = 64, 128, 256
    a = rng.integers(-127, 128, size=(m, k)).astype(np.int8)
    wq = _wot_weights(rng, (k, n))
    wenc = np.asarray(ecc.encode64(
        jnp.asarray(wq.view(np.uint8).reshape(k, n // 8, 8)))).reshape(k, n)
    # inject exactly one flip in a handful of distinct blocks
    f = wenc.reshape(-1).copy()
    for blk in [0, 77, 1000, 4095]:
        f[blk * 8 + 3] ^= 0x04
    f = f.reshape(k, n)
    out = ecc_qmatmul(jnp.asarray(a), jnp.asarray(f), bm=64, bn=128, bk=128)
    plain = a.astype(np.int32) @ wq.astype(np.int32)
    assert (np.asarray(out) == plain).all()


@pytest.mark.parametrize("nblk", [64, 1000, 4096])
def test_throttle_sweep(nblk):
    rng = np.random.default_rng(nblk)
    q = jnp.asarray(rng.integers(-128, 128, size=(nblk, 8)).astype(np.int8))
    blk = min(nblk, 512)
    if nblk % blk:
        blk = nblk
    t_k = throttle(q, blk_n=blk)
    assert (np.asarray(t_k) == np.asarray(ref.throttle_ref(q))).all()


def test_ops_wrappers():
    rng = np.random.default_rng(9)
    w = _wot_weights(rng, (2048,))
    enc = np.asarray(ecc.encode64(jnp.asarray(w.view(np.uint8).reshape(-1, 8))))
    dec, flags = ops.decode_weights(jnp.asarray(enc.reshape(-1)))
    assert (np.asarray(dec) == w).all()
    q = jnp.asarray(rng.integers(-128, 128, size=(4096,)).astype(np.int8))
    t = ops.throttle_flat(q)
    from repro.core import wot
    assert wot.satisfies_constraint(t)
