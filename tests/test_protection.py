"""The unified ``repro.protection`` API: scheme round-trips on both backends,
ProtectedTensor pytree behaviour, policy rules, and coverage reporting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import protection

SCHEME_IDS = ("faulty", "parity-zero", "secded72", "in-place")
BACKENDS = ("xla", "pallas")


def wot_q(rng, n):
    """WOT-compliant int8 vector with full quantization range (max |q|=127,
    nothing below -127 so symmetric int8 quantization round-trips exactly)."""
    q = rng.integers(-64, 64, size=n).astype(np.int8)
    q[7::8] = rng.integers(-127, 128, size=q[7::8].size)
    q[7] = 127  # pin the range so compute_scale round-trips exactly
    return q


def wot_params(rng, shape=(16, 64)):
    """fp32 weights that quantize exactly back to a WOT-compliant q."""
    q = wot_q(rng, int(np.prod(shape))).reshape(shape)
    scale = np.float32(0.01)
    return jnp.asarray(q.astype(np.float32) * scale), q, scale


# ---------------------------------------------------------------------------
# scheme round-trips: encode -> inject(rate=0) -> decode == identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sid", SCHEME_IDS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_scheme_roundtrip_identity_both_backends(sid, backend):
    rng = np.random.default_rng(0)
    q = wot_q(rng, 4096).reshape(8, 512)
    scheme = protection.get_scheme(sid)
    enc, checks = scheme.encode(jnp.asarray(q), backend)
    pt = protection.ProtectedTensor(enc=enc, checks=checks,
                                    scale=jnp.float32(1.0), scheme_id=sid,
                                    orig_shape=q.shape)
    pt0 = jax.tree_util.tree_leaves(
        protection.inject_tree({"w": pt}, rate=0.0, seed=0),
        is_leaf=protection.is_protected_tensor)[0]
    dec = scheme.decode(pt0.enc, pt0.checks, backend)
    assert np.array_equal(np.asarray(dec), q), sid


@pytest.mark.parametrize("sid", SCHEME_IDS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_policy_tree_roundtrip_exact(sid, backend):
    """Full tree pipeline on-device: encode_tree -> inject(0) -> decode_tree
    reproduces the weights bit-exactly (WOT-compliant fp inputs)."""
    rng = np.random.default_rng(1)
    w, _q, _scale = wot_params(rng)
    params = {"blk": {"wq": w}}
    policy = protection.ProtectionPolicy(
        default_scheme=sid, backend=backend,
        predicate=lambda p, l: getattr(l, "ndim", 0) >= 2)
    enc = policy.encode_tree(params)
    enc = protection.inject_tree(enc, rate=0.0, seed=3)
    dec = policy.decode_tree(enc, jnp.float32)
    assert np.array_equal(np.asarray(dec["blk"]["wq"]), np.asarray(w)), sid


@pytest.mark.parametrize("sid", SCHEME_IDS)
def test_host_trial_pipeline_matches_identity_at_rate0(sid):
    rng = np.random.default_rng(2)
    q = wot_q(rng, 8000)
    out = protection.run_fault_trial(sid, q, rate=0.0, seed=0)
    assert np.array_equal(out, q)


def test_inplace_zero_space_secded_overhead():
    rng = np.random.default_rng(3)
    q = wot_q(rng, 4096)
    expected = {"faulty": 0.0, "parity-zero": 0.125, "secded72": 0.125,
                "in-place": 0.0}
    for sid, ovh in expected.items():
        sch = protection.get_host_scheme(sid)
        st = sch.encode(q)
        assert abs(sch.space_overhead(st) - ovh) < 1e-9, sid


def test_inplace_corrects_singles_through_policy():
    rng = np.random.default_rng(4)
    w, _q, _ = wot_params(rng, (32, 64))
    policy = protection.ProtectionPolicy(
        predicate=lambda p, l: getattr(l, "ndim", 0) >= 2)
    enc = policy.encode_tree({"w": w})
    dirty = protection.inject_tree(enc, rate=1e-5, seed=7)  # sparse singles
    dec = policy.decode_tree(dirty, jnp.float32)
    assert np.array_equal(np.asarray(dec["w"]), np.asarray(w))


# ---------------------------------------------------------------------------
# ProtectedTensor pytree behaviour
# ---------------------------------------------------------------------------


def _example_pt(rng, sid="in-place"):
    w, _, _ = wot_params(rng)
    policy = protection.ProtectionPolicy(
        default_scheme=sid, predicate=lambda p, l: True)
    return policy.encode_leaf(w, sid), w


def test_protected_tensor_flatten_unflatten_preserves_aux():
    pt, _w = _example_pt(np.random.default_rng(5))
    leaves, treedef = jax.tree_util.tree_flatten(pt)
    assert len(leaves) == 2  # enc + scale (checks is None for in-place)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.scheme_id == pt.scheme_id
    assert back.orig_shape == pt.orig_shape
    assert np.array_equal(np.asarray(back.enc), np.asarray(pt.enc))


def test_protected_tensor_survives_tree_map():
    pt, _w = _example_pt(np.random.default_rng(6), "secded72")
    mapped = jax.tree.map(lambda x: x, {"a": pt})
    assert protection.is_protected_tensor(mapped["a"])
    assert mapped["a"].checks is not None


def test_protected_tensor_through_jit_and_eval_shape():
    pt, w = _example_pt(np.random.default_rng(7))

    @jax.jit
    def roundtrip(p):
        return protection.decode_leaf(p, jnp.float32)

    assert np.array_equal(np.asarray(roundtrip(pt)), np.asarray(w))
    sds = jax.eval_shape(roundtrip, pt)
    assert sds.shape == w.shape
    # jit with a ProtectedTensor OUTPUT too
    enc_fn = jax.jit(lambda x: dataclasses.replace(pt, scale=x))
    out = enc_fn(jnp.float32(2.0))
    assert protection.is_protected_tensor(out)
    assert float(out.scale) == 2.0


def test_spec_tree_inherits_weight_spec_for_same_shape_images():
    from jax.sharding import PartitionSpec as P
    rng = np.random.default_rng(8)
    w, _, _ = wot_params(rng, (16, 64))
    odd = jnp.asarray(rng.normal(size=(4, 13)), jnp.float32)
    policy = protection.ProtectionPolicy(
        predicate=lambda p, l: getattr(l, "ndim", 0) >= 2)
    enc = policy.encode_tree({"wq": w, "odd": odd})
    specs = protection.spec_tree(enc, lambda path, leaf: P("model", "data"))
    assert specs["wq"].enc == P("model", "data")   # inherits
    assert specs["wq"].scale == P()                # replicated
    assert specs["odd"].enc == P()                 # flat-padded: replicated


# ---------------------------------------------------------------------------
# policy: rules, padding, coverage
# ---------------------------------------------------------------------------


def test_policy_rules_mix_schemes_per_layer():
    rng = np.random.default_rng(9)
    w1, _, _ = wot_params(rng, (8, 32))
    w2, _, _ = wot_params(rng, (8, 32))
    w3, _, _ = wot_params(rng, (8, 32))
    params = {"attn": {"wq": w1}, "mlp": {"w_up": w2}, "head": {"out": w3}}
    policy = protection.ProtectionPolicy(
        default_scheme="in-place",
        rules=[("attn/", "secded72"), ("head/", "none")],
        predicate=lambda p, l: True)
    enc = policy.encode_tree(params)
    assert enc["attn"]["wq"].scheme_id == "secded72"
    assert enc["mlp"]["w_up"].scheme_id == "in-place"
    assert not protection.is_protected_tensor(enc["head"]["out"])
    # mixed tree decodes in one call
    dec = policy.decode_tree(enc, jnp.float32)
    assert np.array_equal(np.asarray(dec["attn"]["wq"]), np.asarray(w1))
    assert np.array_equal(np.asarray(dec["mlp"]["w_up"]), np.asarray(w2))


def test_unaligned_tensor_padded_and_protected_by_default():
    rng = np.random.default_rng(10)
    odd = jnp.asarray(rng.normal(size=(6, 13)), jnp.float32)  # 78 elems
    policy = protection.ProtectionPolicy(predicate=lambda p, l: True)
    enc = policy.encode_tree({"odd": odd})
    pt = enc["odd"]
    assert protection.is_protected_tensor(pt)
    assert pt.is_flat and pt.enc.shape == (80,)  # padded to block multiple
    dec = policy.decode_tree(enc, jnp.float32)["odd"]
    assert dec.shape == odd.shape
    scale = float(jnp.max(jnp.abs(odd))) / 127
    # WOT throttle may clamp large values; the bulk stays within one step
    assert float(jnp.median(jnp.abs(dec - odd))) <= scale


def test_coverage_report_counts_and_bytes():
    """The old silent `last-dim % 8` gate must be visible: every skipped
    tensor shows up in the report with a count and byte size."""
    rng = np.random.default_rng(11)
    aligned = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    odd = jnp.asarray(rng.normal(size=(6, 13)), jnp.float32)
    norm = jnp.ones((64,), jnp.float32)
    params = {"wq": aligned, "odd": odd, "scale_vec": norm}
    pred = lambda p, l: getattr(l, "ndim", 0) >= 2

    padding = protection.ProtectionPolicy(predicate=pred, pad=True)
    rep = padding.coverage(params)
    assert rep.n_protected == 2 and rep.n_unprotected == 1
    assert rep.pad_bytes == (-6 * 13) % 8
    assert rep.unprotected_weight_bytes == 0  # nothing silently skipped

    gating = protection.ProtectionPolicy(predicate=pred, pad=False)
    rep = gating.coverage(params)
    assert rep.n_protected == 1 and rep.n_unprotected == 2
    gaps = [e for e in rep.unprotected if e.reason == "unaligned"]
    assert len(gaps) == 1 and gaps[0].path == "odd"
    assert rep.unprotected_weight_bytes == 6 * 13 * 4  # fp32 bytes, reported
    assert "WARNING" in rep.summary() and "odd" in rep.summary()

    # encode honours the same plan as the report
    enc = gating.encode_tree(params)
    assert not protection.is_protected_tensor(enc["odd"])
    assert protection.is_protected_tensor(enc["wq"])


def test_space_overhead_over_tree():
    rng = np.random.default_rng(12)
    w, _, _ = wot_params(rng, (16, 64))
    pred = lambda p, l: getattr(l, "ndim", 0) >= 2
    for sid, expect in (("in-place", 0.0), ("secded72", 0.125)):
        policy = protection.ProtectionPolicy(default_scheme=sid,
                                             predicate=pred)
        enc = policy.encode_tree({"w": w})
        assert abs(protection.space_overhead(enc) - expect) < 1e-9


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


def test_pallas_backend_matches_xla_decode_with_tile_padding():
    rng = np.random.default_rng(13)
    q = wot_q(rng, 8 * 10)  # 10 blocks vs blk_n=4 exercises the pad path
    xla = protection.get_backend("xla")
    pallas = protection.PallasBackend(blk_n=4)
    scheme = protection.get_scheme("in-place")
    enc, _ = scheme.encode(jnp.asarray(q), xla)
    blocks = enc.reshape(-1, 8)
    dx, sx, _ = xla.decode64(blocks)
    dp, sp, _ = pallas.decode64(blocks)
    assert np.array_equal(np.asarray(dx), np.asarray(dp))
    assert np.array_equal(np.asarray(sx), np.asarray(sp))
    ex = xla.encode64(jax.lax.bitcast_convert_type(
        jnp.asarray(q), jnp.uint8).reshape(-1, 8))
    ep = pallas.encode64(jax.lax.bitcast_convert_type(
        jnp.asarray(q), jnp.uint8).reshape(-1, 8))
    assert np.array_equal(np.asarray(ex), np.asarray(ep))


def test_qmatmul_backend_equivalence():
    rng = np.random.default_rng(14)
    w, _, _ = wot_params(rng, (32, 64))
    policy = protection.ProtectionPolicy(predicate=lambda p, l: True)
    pt = policy.encode_leaf(w, "in-place")
    a = jnp.asarray(rng.integers(-8, 8, size=(16, 32)), jnp.int8)
    out_x = protection.qmatmul(a, pt, jnp.float32(0.5), backend="xla")
    out_p = protection.qmatmul(a, pt, jnp.float32(0.5), backend="pallas")
    assert np.allclose(np.asarray(out_x), np.asarray(out_p))
    with pytest.raises(ValueError):
        bad = dataclasses.replace(pt, scheme_id="faulty")
        protection.qmatmul(a, bad, jnp.float32(1.0))


def test_device_injection_rate0_is_identity_and_jittable():
    rng = np.random.default_rng(15)
    w, _, _ = wot_params(rng)
    policy = protection.ProtectionPolicy(
        default_scheme="secded72", predicate=lambda p, l: True)
    enc = policy.encode_tree({"w": w})
    out = protection.inject_tree_device(enc, 0.0, jax.random.PRNGKey(0))
    assert np.array_equal(np.asarray(out["w"].enc), np.asarray(enc["w"].enc))
    hit = jax.jit(lambda t, k: protection.inject_tree_device(t, 1e-3, k))(
        enc, jax.random.PRNGKey(1))
    image = np.concatenate([np.asarray(enc["w"].enc).reshape(-1),
                            np.asarray(enc["w"].checks).reshape(-1)])
    dirty = np.concatenate([np.asarray(hit["w"].enc).reshape(-1),
                            np.asarray(hit["w"].checks).reshape(-1)])
    assert (image != dirty).any()


# ---------------------------------------------------------------------------
# traced-rate device injection (the compiled-campaign mechanism)
# ---------------------------------------------------------------------------


def test_device_injection_traced_rate_matches_static_budget():
    """With max_rate set, rate may be a traced scalar: rate == max_rate flips
    the full budget, rate == 0 flips nothing, in one compiled program."""
    rng = np.random.default_rng(16)
    w, _, _ = wot_params(rng)
    policy = protection.ProtectionPolicy(
        default_scheme="faulty", predicate=lambda p, l: True)
    enc = policy.encode_tree({"w": w})

    @jax.jit
    def inj(rate, key):
        return protection.inject_tree_device(enc, rate, key, max_rate=1e-2)

    key = jax.random.PRNGKey(3)
    zero = inj(jnp.float32(0.0), key)
    assert np.array_equal(np.asarray(zero["w"].enc), np.asarray(enc["w"].enc))
    full = inj(jnp.float32(1e-2), key)
    static = protection.inject_tree_device(enc, 1e-2, key)
    assert np.array_equal(np.asarray(full["w"].enc),
                          np.asarray(static["w"].enc))
