"""Int8 end-to-end fused serving: the requantize epilogue, the decode-once
grid, activation calibration, and the acceptance — int8 at-use serving is
bit-exact vs the quantize->decode->matmul reference on both backends."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, protection
from repro.core import ecc, quant
from repro.kernels import ref
from repro.kernels.ecc_qmatmul import ecc_qmatmul
from repro.models import lm
from repro.serving import protected


def _wot_weights(rng, shape):
    w = rng.integers(-64, 64, size=shape).astype(np.int8)
    flat = w.reshape(-1)
    flat[7::8] = rng.integers(-128, 128, size=flat[7::8].size)
    return flat.reshape(shape)


def _enc(wq):
    k, n = wq.shape
    return np.asarray(ecc.encode64(jnp.asarray(
        wq.view(np.uint8).reshape(k, n // 8, 8)))).reshape(k, n)


# ---------------------------------------------------------------------------
# kernel: the fused requantize epilogue
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,bm,bn", [
    (32, 64, 128, 16, 64),     # clean tiles
    (45, 100, 72, 16, 32),     # ragged everything (edge-tile masking)
])
def test_epilogue_bit_exact_vs_requantize_reference(m, k, n, bm, bn):
    """int8 a + a_scale -> (acc * a_scale*w_scale) cast bf16 in VMEM, equal
    BIT FOR BIT to the XLA quantize->decode->matmul->rescale sequence (the
    int32 accumulation is one exact MXU pass)."""
    rng = np.random.default_rng(m + n)
    wq = _wot_weights(rng, (k, n))
    wenc = jnp.asarray(_enc(wq))
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
    w_scale = jnp.float32(0.013)
    # per-row (dynamic per-token) scales AND a scalar (static) scale
    for a_scale in (jnp.asarray(rng.uniform(0.005, 0.05, size=(m, 1))
                                .astype(np.float32)),
                    jnp.float32(0.02)):
        out = ecc_qmatmul(a, wenc, w_scale, a_scale=a_scale, bm=bm, bn=bn)
        assert out.dtype == jnp.bfloat16
        acc = ref.ecc_qmatmul_ref(a, wenc)
        want = (acc.astype(jnp.float32) * (a_scale * w_scale)
                ).astype(jnp.bfloat16)
        assert np.array_equal(np.asarray(out, np.float32),
                              np.asarray(want, np.float32))


def test_epilogue_int32_bias_add():
    rng = np.random.default_rng(9)
    m, k, n = 16, 64, 64
    wq = _wot_weights(rng, (k, n))
    wenc = jnp.asarray(_enc(wq))
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
    bias = jnp.asarray(rng.integers(-5000, 5000, size=(n,)).astype(np.int32))
    a_scale = jnp.float32(0.01)
    w_scale = jnp.float32(0.02)
    out = ecc_qmatmul(a, wenc, w_scale, a_scale=a_scale, bias=bias,
                      bm=8, bn=32)
    acc = ref.ecc_qmatmul_ref(a, wenc) + bias[None, :]
    want = (acc.astype(jnp.float32) * (a_scale * w_scale)).astype(jnp.bfloat16)
    assert np.array_equal(np.asarray(out, np.float32),
                          np.asarray(want, np.float32))


def test_epilogue_out_dtype_and_guards():
    rng = np.random.default_rng(2)
    k, n = 32, 32
    wenc = jnp.asarray(_enc(_wot_weights(rng, (k, n))))
    a = jnp.asarray(rng.integers(-127, 128, size=(4, k)).astype(np.int8))
    out = ecc_qmatmul(a, wenc, jnp.float32(0.1), a_scale=jnp.float32(0.1),
                      out_dtype=jnp.float32)
    assert out.dtype == jnp.float32
    with pytest.raises(ValueError, match="requantize epilogue needs w_scale"):
        ecc_qmatmul(a, wenc, a_scale=jnp.float32(0.1))
    with pytest.raises(ValueError, match="bias"):
        ecc_qmatmul(a, wenc, bias=jnp.zeros((n,), jnp.int32))
    with pytest.raises(ValueError, match="a_scale"):
        ecc_qmatmul(a.astype(jnp.bfloat16), wenc, jnp.float32(0.1),
                    a_scale=jnp.float32(0.1))


# ---------------------------------------------------------------------------
# kernel: the decode-once (M-innermost, VMEM scratch) grid
# ---------------------------------------------------------------------------


def test_decode_once_flags_tied_to_single_decode():
    """Flag counting lives inside the same predicated block as the decode
    into the VMEM scratch, so exact flag totals across MANY M tiles are a
    runtime witness that each weight tile decodes once per (N, K) tile —
    re-decoding per M tile would multiply the counts by ceil(M/BM)."""
    rng = np.random.default_rng(4)
    m, k, n = 128, 64, 128
    wq = _wot_weights(rng, (k, n))
    f = _enc(wq).reshape(-1).copy()
    double_blocks, single_blocks = [0, 33, 500], [7, 250, 900]
    for blk in double_blocks:
        f[blk * 8 + 1] ^= 0x05
    for blk in single_blocks:
        f[blk * 8 + 6] ^= 0x40
    fenc = jnp.asarray(f.reshape(k, n))
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
    # 16 M tiles x 4 N tiles x 2 K tiles — heavy M grid
    out, flags = ecc_qmatmul(a, fenc, bm=8, bn=32, bk=32, with_flags=True)
    assert int(flags[0]) == len(single_blocks)
    assert int(flags[1]) == len(double_blocks)
    # and the scratch reuse path (i > 0) computes the right values: singles
    # corrected, so all M rows equal the unfaulted matmul
    plain = np.asarray(a).astype(np.int32) @ wq.astype(np.int32)
    doubles_cols = set()
    for blk in double_blocks:  # columns touched by uncorrectable blocks
        doubles_cols.update(range(blk % (n // 8) * 8, blk % (n // 8) * 8 + 8))
    clean_cols = [c for c in range(n) if c not in doubles_cols]
    assert np.array_equal(np.asarray(out)[:, clean_cols], plain[:, clean_cols])


def test_decode_once_matches_reference_across_m_grids():
    """Same output and flags for 1, 2, and 9 M tiles (scratch-reuse
    regression extending the PR 4 M-grid independence test)."""
    rng = np.random.default_rng(5)
    m, k, n = 72, 96, 64
    wq = _wot_weights(rng, (k, n))
    wenc = jnp.asarray(_enc(wq))
    a = jnp.asarray(rng.integers(-127, 128, size=(m, k)).astype(np.int8))
    plain = np.asarray(a).astype(np.int32) @ wq.astype(np.int32)
    ref_flags = None
    for bm in (128, 64, 8):
        out, flags = ecc_qmatmul(a, wenc, bm=bm, bn=32, bk=32,
                                 with_flags=True)
        assert np.array_equal(np.asarray(out), plain)
        if ref_flags is None:
            ref_flags = np.asarray(flags)
        assert np.array_equal(np.asarray(flags), ref_flags)


# ---------------------------------------------------------------------------
# ProtectedWeight: int8 routes are bit-identical, fused vs inline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["dynamic", "static"])
def test_protected_weight_int8_fused_equals_inline(mode):
    from repro.protection.fused import ProtectedWeight
    rng = np.random.default_rng(6)
    k, n = 64, 128
    w = jnp.asarray(_wot_weights(rng, (k, n)).astype(np.float32) * 0.01)
    policy = protection.ProtectionPolicy()
    pt = policy.encode_leaf(w, "in-place")
    x = jnp.asarray(rng.normal(size=(3, 5, k)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    kw = dict(act_quant=mode, a_scale=0.02 if mode == "static" else None)
    out_fused = ProtectedWeight(pt, "pallas", **kw).matmul(x)
    out_inline = ProtectedWeight(pt, "xla", **kw).matmul(x)
    assert out_fused.shape == (3, 5, n)
    assert np.array_equal(np.asarray(out_fused, np.float32),
                          np.asarray(out_inline, np.float32))
    # and both equal the explicit quantize->decode->matmul reference
    x2 = x.reshape(-1, k).astype(jnp.float32)
    if mode == "static":
        a_scale = jnp.float32(0.02)
    else:
        a_scale = quant.compute_scale(x2, axis=1)
    q = jnp.clip(jnp.round(x2 / a_scale), -127, 127).astype(jnp.int8)
    acc = ref.ecc_qmatmul_ref(q, pt.enc)
    want = (acc.astype(jnp.float32) * (a_scale * pt.scale)
            ).astype(jnp.bfloat16).reshape(3, 5, n)
    assert np.array_equal(np.asarray(out_fused, np.float32),
                          np.asarray(want, np.float32))


def test_protected_weight_raw_int8_needs_static_scale():
    from repro.protection.fused import ProtectedWeight
    rng = np.random.default_rng(7)
    w = jnp.asarray(_wot_weights(rng, (32, 32)).astype(np.float32) * 0.01)
    pt = protection.ProtectionPolicy().encode_leaf(w, "in-place")
    q = jnp.ones((2, 32), jnp.int8)
    with pytest.raises(TypeError, match="static a_scale"):
        ProtectedWeight(pt, "pallas").matmul(q)
    out = ProtectedWeight(pt, "pallas", act_quant="static",
                          a_scale=0.05).matmul(q)
    assert out.dtype == jnp.bfloat16 and out.shape == (2, 32)


def test_proj_bias_not_truncated_on_int8_activations():
    """layers._proj must add the bias at the OUTPUT dtype: raw int8
    activations through a biased projection produce float y, and the bias
    (here 500.0, unrepresentable in int8) must survive."""
    from repro.models.layers import _proj
    from repro.protection.fused import ProtectedWeight
    rng = np.random.default_rng(8)
    w = jnp.asarray(_wot_weights(rng, (32, 32)).astype(np.float32) * 0.01)
    pt = protection.ProtectionPolicy().encode_leaf(w, "in-place")
    view = ProtectedWeight(pt, "pallas", act_quant="static", a_scale=0.05)
    q = jnp.ones((2, 32), jnp.int8)
    b = jnp.full((32,), 500.0, jnp.float32)
    y = _proj(q, view, b)
    assert np.allclose(np.asarray(y - view.matmul(q), np.float32), 500.0,
                       atol=2.0)  # bf16 rounding, not int8 wraparound


def test_calibration_floors_zero_activation_scale():
    """A projection whose calibration activations are all zero must not
    bake a_scale=0 (divide-by-zero at serve time) — same 1e-12 floor as
    quant.compute_scale."""
    cfg = configs.get_smoke("minitron-4b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    # zero the embedding: every hidden state (and thus every projection
    # input) in the calibration forward is exactly zero
    params["embed"] = jnp.zeros_like(params["embed"])
    plan = protected.make_plan(params, protection.ProtectionPolicy())
    enc = plan.encode_tree(params)
    toks = jnp.zeros((2, 16), jnp.int32)
    scales = protected.calibrate_act_scales(cfg, enc, toks, plan=plan,
                                            chunk=16)
    assert scales and all(s > 0 for s in scales.values())
    plan_q = plan.with_act_quant("static", scales)
    step = jax.jit(protected.make_serve_step(cfg, plan=plan_q,
                                             act_quant="plan"))
    cache = lm.init_cache(cfg, 2, 32)
    logits, _ = step(enc, cache, jnp.zeros((2, 1), jnp.int32),
                     jnp.zeros((2,), jnp.int32))
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


# ---------------------------------------------------------------------------
# end-to-end: int8 at-use serving, calibration, plan decisions
# ---------------------------------------------------------------------------


def test_int8_at_use_serving_bit_exact_on_both_backends(plan_setup):
    """The acceptance: the fused int8 MXU path (Pallas epilogue) serves
    end-to-end and its logits equal the XLA quantize->decode->matmul
    reference route bit for bit — decode step AND prefill."""
    outs = {}
    for backend in ("xla", "pallas"):
        cfg, plan, enc = plan_setup(backend=backend)
        cache = lm.init_cache(cfg, 2, 32)
        tok = jnp.zeros((2, 1), jnp.int32)
        pos = jnp.zeros((2,), jnp.int32)
        step = jax.jit(protected.make_serve_step(cfg, plan=plan,
                                                 act_quant="dynamic"))
        logits, _ = step(enc, cache, tok, pos)
        pre = jax.jit(protected.make_prefill(cfg, plan=plan, chunk=16,
                                             act_quant="dynamic"))
        toks = jnp.zeros((2, 16), jnp.int32)
        outs[backend] = (np.asarray(logits, np.float32),
                         np.asarray(pre(enc, toks, {}), np.float32))
    assert np.array_equal(outs["xla"][0], outs["pallas"][0])
    assert np.array_equal(outs["xla"][1], outs["pallas"][1])


def test_calibrate_then_static_serving(plan_setup):
    """calibrate_act_scales -> plan.with_act_quant('static') -> act_quant
    'plan' serves the calibrated set; static logits match across backends
    and the plan summary reports the decisions."""
    toks = jnp.zeros((2, 16), jnp.int32)
    outs, n_static = {}, None
    for backend in ("xla", "pallas"):
        cfg, plan, enc = plan_setup(backend=backend)
        scales = protected.calibrate_act_scales(cfg, enc, toks, plan=plan,
                                                chunk=16)
        assert scales and all(s > 0 for s in scales.values())
        assert "layers/attn/wq" in scales and "head" in scales
        plan_q = plan.with_act_quant("static", scales)
        s = plan_q.summary()
        assert s["act_quant"].get("static") == len(scales)
        if n_static is None:
            n_static = s["act_quant"]["static"]
        assert s["act_quant"]["static"] == n_static  # same set per backend
        cache = lm.init_cache(cfg, 2, 32)
        step = jax.jit(protected.make_serve_step(cfg, plan=plan_q,
                                                 act_quant="plan"))
        logits, _ = step(enc, cache, jnp.zeros((2, 1), jnp.int32),
                         jnp.zeros((2,), jnp.int32))
        outs[backend] = np.asarray(logits, np.float32)
    assert np.array_equal(outs["xla"], outs["pallas"])


def test_with_act_quant_modes_and_guards(plan_setup):
    cfg, plan, _ = plan_setup()
    dyn = plan.with_act_quant("dynamic")
    assert dyn.summary()["act_quant"].get("dynamic", 0) > 0
    # original plan untouched
    assert not plan.summary()["act_quant"]
    with pytest.raises(ValueError, match="calibrated"):
        plan.with_act_quant("static")
    with pytest.raises(ValueError, match="mode"):
        plan.with_act_quant("sometimes")
    with pytest.raises(ValueError, match="decode-at-use"):
        protected.make_serve_step(cfg, plan=plan, decode_at_use=False,
                                  act_quant="dynamic")
    with pytest.raises(ValueError, match="decode-at-use"):
        protected.make_prefill(cfg, plan=plan, decode_at_use=False,
                               act_quant="dynamic")


def test_int8_serving_flags_still_attribute_faults(plan_setup):
    """The epilogue path keeps the per-layer (corrected, DUE) accounting: a
    double-bit fault in layer 0's wq surfaces in layer 0's DUE row when
    serving int8."""
    cfg, plan, enc = plan_setup(arch="deepseek-7b")
    wq = enc["layers"]["attn"]["wq"]
    img = np.asarray(wq.enc).copy()
    img.reshape(-1)[3] ^= 0x03
    enc["layers"]["attn"]["wq"] = dataclasses.replace(
        wq, enc=jnp.asarray(img))
    serve = jax.jit(protected.make_serve_step(cfg, plan=plan,
                                              act_quant="dynamic",
                                              with_flags=True))
    cache = lm.init_cache(cfg, 2, 32)
    _, _, flags = serve(enc, cache, jnp.zeros((2, 1), jnp.int32),
                        jnp.zeros((2,), jnp.int32))
    layers = np.asarray(flags["layers"])
    assert layers[0, 1] >= 1
    assert layers[1:, 1].sum() == 0


def test_int8_conv_arch_prefill_runs():
    """ssm arch: conv kernels keep decoding to arrays, matmul projections
    quantize — the int8 prefill must still run end-to-end."""
    cfg = configs.get_smoke("mamba2-2.7b")
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    plan = protected.make_plan(params,
                               protection.ProtectionPolicy(backend="pallas"))
    enc = plan.encode_tree(params)
    toks = jnp.zeros((2, 16), jnp.int32)
    pre = jax.jit(protected.make_prefill(cfg, plan=plan, chunk=16,
                                         act_quant="dynamic"))
    out = pre(enc, toks, {})
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
